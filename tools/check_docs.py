#!/usr/bin/env python
"""Docs sanity gate: every fenced code block in docs/*.md and README.md
must at least be well-formed.

  * ```python blocks must parse (compile(..., "exec")) — stale example
    code that drifted from the API at least stays syntactically honest,
    and import-path typos in snippets are caught by a lightweight
    import-name scan against src/repro.
  * ```bash / ```sh blocks must be non-empty.
  * other/untagged blocks (ASCII diagrams, JSON, math) are counted but
    not checked.

Exits non-zero with a per-block report on failure.  CI runs this after
the test suite.
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE = re.compile(r"^```(\w*)\s*$")


def blocks(path):
    """Yield (lang, first_line_no, source) per fenced block."""
    lang, start, buf = None, 0, []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            m = FENCE.match(line.strip())
            if m and lang is None:
                lang, start, buf = m.group(1) or "", i, []
            elif line.strip() == "```" and lang is not None:
                yield lang, start, "".join(buf)
                lang = None
            elif lang is not None:
                buf.append(line)
    if lang is not None:
        raise SyntaxError(f"{path}:{start}: unclosed code fence")


def check_python(src: str, where: str, errors: list):
    try:
        compile(src, where, "exec")
    except SyntaxError as e:
        errors.append(f"{where}: python block does not parse: {e}")
        return
    # imports of repro.* must name real modules
    for m in re.finditer(r"^\s*from\s+(repro[\w.]*)\s+import|"
                         r"^\s*import\s+(repro[\w.]*)", src, re.M):
        mod = (m.group(1) or m.group(2)).replace(".", "/")
        base = os.path.join(REPO, "src", mod)
        if not (os.path.isdir(base) or os.path.exists(base + ".py")):
            errors.append(f"{where}: snippet imports missing module "
                          f"{(m.group(1) or m.group(2))!r}")


def main() -> int:
    paths = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    paths.append(os.path.join(REPO, "README.md"))
    errors, counted = [], 0
    for path in paths:
        rel = os.path.relpath(path, REPO)
        try:
            for lang, line, src in blocks(path):
                counted += 1
                where = f"{rel}:{line}"
                if lang == "python":
                    check_python(src, where, errors)
                elif lang in ("bash", "sh") and not src.strip():
                    errors.append(f"{where}: empty {lang} block")
        except SyntaxError as e:
            errors.append(str(e))
    if errors:
        print(f"[check_docs] {len(errors)} problem(s) in {counted} blocks:")
        for e in errors:
            print("  ", e)
        return 1
    print(f"[check_docs] OK: {counted} code blocks across "
          f"{len(paths)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
