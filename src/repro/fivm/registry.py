"""Pinned-view registry: one ring, many models.

Interactive analyses over the same evolving dataset should not each
maintain a private gram matrix — the ring's views are model-agnostic
(λ enters at read, coefficients live in per-model slots), so every
regression and clustering job over the same :class:`RingSpec` can share
ONE maintained ring.  :class:`RingRegistry` keys live rings by their
spec, pins them while any analysis holds them (pin-counted acquire /
release — an unpinned ring is dropped, a pinned one survives every
release but the last), hands out model slots to named solvers, and
passes one shared :class:`repro.plan.TriggerCache` to every engine it
builds so same-shape rings never re-jit their triggers.

The fleet face of the same idea: :meth:`RingRegistry.tenant_spec`
wraps a ring program as a :class:`repro.fleet.TenantSpec`, so a
multi-tenant deployment hosts per-dataset rings under the scheduler's
lease/SLO machinery, and :func:`submit_event` feeds labeled
insert/delete events through the fleet's admission path using exactly
the carriers :meth:`Ring.apply` fires locally (bit-identical replay).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.data.updates import LabeledUpdate
from .ring import (Ring, RingSpec, build_ring_program, event_carriers,
                   initial_ring_inputs)


@dataclass
class _Entry:
    ring: Ring
    pins: int = 0
    models: Dict[str, object] = field(default_factory=dict)


class RingRegistry:
    """Process-local registry of live, pinned rings (see module doc)."""

    def __init__(self, trigger_cache=None):
        if trigger_cache is None:
            from repro.plan import global_trigger_cache
            trigger_cache = global_trigger_cache()
        self.trigger_cache = trigger_cache
        self._entries: Dict[RingSpec, _Entry] = {}
        self.evictions = 0

    # -- pinning -----------------------------------------------------------

    def acquire(self, spec: RingSpec, **ring_opts) -> Ring:
        """The shared ring for ``spec`` (built on first acquire; pinned
        +1).  ``ring_opts`` (order, guard, …) apply only to the build —
        a second acquirer shares the first ring as-is."""
        e = self._entries.get(spec)
        if e is None:
            ring = Ring(spec, trigger_cache=self.trigger_cache,
                        **ring_opts)
            e = self._entries[spec] = _Entry(ring=ring)
        e.pins += 1
        return e.ring

    def release(self, spec: RingSpec) -> int:
        """Unpin; at zero pins the ring (and its models) is dropped.
        Returns the remaining pin count."""
        e = self._entries.get(spec)
        if e is None:
            raise KeyError(f"no ring for {spec}")
        e.pins -= 1
        if e.pins <= 0:
            del self._entries[spec]
            self.evictions += 1
            return 0
        return e.pins

    def get(self, spec: RingSpec) -> Ring:
        """The live ring for ``spec`` without pinning (raises if not
        held by anyone)."""
        return self._entries[spec].ring

    def pinned(self) -> List[RingSpec]:
        return sorted(self._entries, key=repr)

    # -- models ------------------------------------------------------------

    def model(self, spec: RingSpec, name: str, kind: str = "ridge",
              **solver_opts):
        """A named solver over the shared ring (create on first call,
        shared thereafter): ``kind`` ∈ {"ridge", "ols", "kmeans"}.
        Regression models each claim their own coefficient slot —
        one ring, many models."""
        e = self._entries[spec]
        if name in e.models:
            return e.models[name]
        from .solvers import KMeansSolver, OLSSolver, RidgeSolver
        if kind == "ridge":
            solver = RidgeSolver(e.ring, **solver_opts)
        elif kind == "ols":
            solver = OLSSolver(e.ring, **solver_opts)
        elif kind == "kmeans":
            solver = KMeansSolver(e.ring, **solver_opts)
        else:
            raise ValueError(f"unknown model kind {kind!r}")
        e.models[name] = solver
        return solver

    def models(self, spec: RingSpec) -> Dict[str, object]:
        return dict(self._entries[spec].models)

    def stats(self) -> Dict[str, object]:
        return {
            "rings": len(self._entries),
            "pins": {repr(s): e.pins for s, e in self._entries.items()},
            "models": {repr(s): sorted(e.models)
                       for s, e in self._entries.items()},
            "evictions": self.evictions,
            "trigger_cache": self.trigger_cache.stats(),
        }

    # -- fleet face --------------------------------------------------------

    def tenant_spec(self, spec: RingSpec, tenant_id: str, *,
                    slo_s: float = 1.0, guarded: bool = True,
                    **tenant_kw):
        """A :class:`repro.fleet.TenantSpec` hosting this ring shape:
        the ring program and its per-input update ranks — fleet ring
        tenants live under lease-claimed refresh and SLO staleness
        accounting like any other tenant, and same-shape ring tenants
        share compiled triggers through the fleet's own cache."""
        from repro.fleet import TenantSpec
        ranks: Dict[str, int] = {"X": 1, "Y": 1, "W": 1}
        for j in range(spec.model_slots):
            ranks[f"B{j}"] = spec.targets
        return TenantSpec(tenant_id, build_ring_program(spec),
                          update_ranks=ranks, slo_s=slo_s,
                          guarded=guarded, **tenant_kw)

    def add_fleet_tenant(self, scheduler, spec: RingSpec, tenant_id: str,
                         **tenant_kw):
        """Register a ring tenant on a running fleet scheduler, its
        inputs initialized to the empty ring."""
        inputs = initial_ring_inputs(spec, tenant_kw.pop("seed", 0))
        return scheduler.add_tenant(
            self.tenant_spec(spec, tenant_id, **tenant_kw), inputs)


def submit_event(scheduler, tenant_id: str, capacity: int,
                 ev: LabeledUpdate) -> List[str]:
    """Feed one labeled insert/delete through the fleet admission path
    as the same three row carriers :meth:`Ring.apply` fires locally.
    Returns the three admission decisions (X, Y, W)."""
    return [scheduler.submit(tenant_id, name, carrier)
            for name, carrier in event_carriers(ev, capacity)]
