"""The maintained covariance/gram ring (F-IVM, arXiv 1703.07484).

A labeled dataset living in ``capacity`` row slots of a design matrix
``X`` (and target matrix ``Y``, occupancy indicator ``W``) is summarized
by the ring of aggregates

    c  = WᵀW   (live-example count)
    s  = XᵀW   (feature sums Σxᵢ)
    G  = XᵀX   (gram / scatter matrix)
    XY = XᵀY   (feature–target cross moments)
    YY = YᵀY   (target moments)

— every statistic a normal-equation learner needs, registered as
*views* in the LINVIEW compiler and maintained by its factored
triggers.  An insert of example ``(x, y)`` at slot ``i`` is the rank-1
row carrier ``ΔX = eᵢxᵀ`` (and ``ΔY = eᵢyᵀ``, ``ΔW = eᵢ``); a delete is
the **same stored payload with weight −1** — the negative-weight
downdate that makes deletion "an insertion with weight −1", and makes
insert-then-delete restore the ring bit-near-identically (the carriers
cancel exactly in the factor algebra; float summation order is the only
residual).

Model coefficients are inputs too: slot ``j`` holds ``Bⱼ`` with the
maintained view ``grad{j} = G·Bⱼ − XY`` (the λ-term is added at read so
one ring serves every regularization strength).  :meth:`Ring.set_model`
turns a solver's new coefficients into a rank-``targets`` factored
delta via :func:`repro.train.grad_compression.compress_leaf` — the
PowerSGD-shaped factors double as exact IVM deltas because ``ΔB`` has
rank ≤ ``targets`` — so gradient computation stays a maintained view,
never a recompute.

With ``order=2`` the engine's deferred cascade banks every firing in
factored form and folds at the next read — the decoupled-refresh serve
contract (docs/fivm.md): ingest cost per event is O(rank) bookkeeping,
model-refresh cost is paid by the reader.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (IncrementalEngine, Program, dim, matmul,
                        row_delta_carrier, sub, transpose)
from repro.data.updates import LabeledUpdate


@dataclass(frozen=True)
class RingSpec:
    """Shape contract of one maintained ring (hashable: the registry
    keys shared rings by it).

    ``model_slots`` coefficient inputs are pre-allocated so several
    models (different λ, different solver) share one ring;
    ``proj_dim > 0`` adds a random projection input ``R`` and the view
    ``XP = X·R`` — the one ring view the compiler proves *row-local*
    (gram-side views widen row support through the transpose), so
    row-carrier containment has a genuine target."""

    features: int
    targets: int = 1
    capacity: int = 256
    model_slots: int = 1
    proj_dim: int = 0

    def __post_init__(self):
        if self.features < 1 or self.targets < 1 or self.capacity < 1:
            raise ValueError(f"bad ring spec {self}")
        if self.model_slots < 0 or self.proj_dim < 0:
            raise ValueError(f"bad ring spec {self}")


def build_ring_program(spec: RingSpec) -> Program:
    """The ring as a LINVIEW program: inputs X/Y/W (+ B-slots, + R),
    views c/s/G/XY/YY (+ grad{j}, + XP)."""
    prog = Program(name=f"fivm_ring_f{spec.features}_t{spec.targets}"
                        f"_c{spec.capacity}_b{spec.model_slots}"
                        f"_d{spec.proj_dim}")
    M, N, P, ONE = dim("m"), dim("n"), dim("p"), dim("one")
    X = prog.input("X", (M, N))
    Y = prog.input("Y", (M, P))
    W = prog.input("W", (M, ONE))
    G = prog.let("G", matmul(transpose(X), X))
    XY = prog.let("XY", matmul(transpose(X), Y))
    prog.let("s", matmul(transpose(X), W))
    prog.let("c", matmul(transpose(W), W))
    prog.let("YY", matmul(transpose(Y), Y))
    outputs = ["G", "XY", "s", "c", "YY"]
    for j in range(spec.model_slots):
        B = prog.input(f"B{j}", (N, P))
        prog.let(f"grad{j}", sub(matmul(G, B), XY))
        outputs.append(f"grad{j}")
    binding = dict(m=spec.capacity, n=spec.features, p=spec.targets, one=1)
    if spec.proj_dim > 0:
        D = dim("d")
        R = prog.input("R", (N, D))
        prog.let("XP", matmul(X, R))   # row-local: ΔX·R keeps row support
        outputs.append("XP")
        binding["d"] = spec.proj_dim
    prog.outputs = outputs
    prog.bind_dims(**binding)
    return prog


def initial_ring_inputs(spec: RingSpec, seed: int = 0
                        ) -> Dict[str, np.ndarray]:
    """The empty ring: zero data/occupancy/models, seeded projection."""
    inputs: Dict[str, np.ndarray] = {
        "X": np.zeros((spec.capacity, spec.features), np.float32),
        "Y": np.zeros((spec.capacity, spec.targets), np.float32),
        "W": np.zeros((spec.capacity, 1), np.float32),
    }
    for j in range(spec.model_slots):
        inputs[f"B{j}"] = np.zeros((spec.features, spec.targets),
                                   np.float32)
    if spec.proj_dim > 0:
        rng = np.random.default_rng(seed + 7)
        inputs["R"] = (rng.normal(size=(spec.features, spec.proj_dim))
                       / np.sqrt(spec.proj_dim)).astype(np.float32)
    return inputs


def event_carriers(ev: LabeledUpdate, capacity: int
                   ) -> List[Tuple[str, object]]:
    """One labeled event as the three row carriers it fires: ``(input
    name, RowLocalCarrier)`` for X, Y, W.  Deletes ride the same path
    with ``weight=−1`` (the downdate).  Shared by :meth:`Ring.apply`
    and the fleet submission path so both fire bit-identical deltas."""
    w = ev.weight
    x = np.asarray(ev.x, dtype=np.float32).reshape(-1)
    y = np.asarray(ev.y, dtype=np.float32).reshape(-1)
    return [
        ("X", row_delta_carrier(ev.slot, x, capacity, weight=w)),
        ("Y", row_delta_carrier(ev.slot, y, capacity, weight=w)),
        ("W", row_delta_carrier(ev.slot, np.ones(1, np.float32),
                                capacity, weight=w)),
    ]


class Ring:
    """One maintained ring: the engine, its event log, and the model
    slots.  See the module docstring for the view algebra.

    ``order=2`` (any int/dict the engine accepts) turns on deferred
    maintenance — updates bank, reads fold — which is the serve mode;
    ``guard``/``chaos``/``plan``/``trigger_cache`` pass straight
    through to :class:`repro.core.IncrementalEngine`.
    """

    def __init__(self, spec: RingSpec, *, seed: int = 0, jit: bool = True,
                 order=None, fold_window: int = 8, guard=None, chaos=None,
                 plan=None, trigger_cache=None, **engine_opts):
        self.spec = spec
        self.program = build_ring_program(spec)
        ranks: Dict[str, int] = {"X": 1, "Y": 1, "W": 1}
        for j in range(spec.model_slots):
            ranks[f"B{j}"] = spec.targets
        self.update_ranks = ranks
        self.engine = IncrementalEngine(
            self.program, ranks, jit=jit, order=order,
            fold_window=fold_window, guard=guard, chaos=chaos, plan=plan,
            trigger_cache=trigger_cache, **engine_opts)
        self._seed = seed
        # grow-only host-side log of (weight, x) gram events — solvers
        # keep cursors into it for Cholesky update/downdate replay
        self.event_log: List[Tuple[float, np.ndarray]] = []
        self.events_applied = 0
        # per-slot applied coefficients + compress_leaf warm-start state
        self._models: Dict[int, np.ndarray] = {}
        self._model_err: Dict[int, np.ndarray] = {}
        self._slots_claimed = 0
        self.initialize()

    # -- lifecycle ---------------------------------------------------------

    def initial_inputs(self) -> Dict[str, np.ndarray]:
        return initial_ring_inputs(self.spec, self._seed)

    def initialize(self) -> None:
        """(Re)start from the empty ring: zero data, zero models."""
        self.engine.initialize(self.initial_inputs())
        self.event_log = []
        self.events_applied = 0
        self._models = {}
        self._model_err = {}

    def bootstrap(self, X, Y=None) -> None:
        """Load an existing labeled dataset in ONE full evaluation
        (rows of ``X`` occupy slots ``0..len(X)-1``), replacing the
        ring's contents — how an interactive analysis starts from a
        table that already exists instead of replaying its history as
        events.  Models and the event log reset with the data."""
        s = self.spec
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != s.features \
                or X.shape[0] > s.capacity:
            raise ValueError(f"bootstrap X {X.shape} does not fit "
                             f"({s.capacity}, {s.features})")
        m = X.shape[0]
        inputs = self.initial_inputs()
        inputs["X"][:m] = X
        if Y is not None:
            inputs["Y"][:m] = np.asarray(Y, np.float32).reshape(
                m, s.targets)
        inputs["W"][:m] = 1.0
        self.engine.initialize(inputs)
        self.event_log = []
        self.events_applied = 0
        self._models = {}
        self._model_err = {}

    def claim_slot(self) -> int:
        """Allocate the next free model slot (registry bookkeeping)."""
        if self._slots_claimed >= self.spec.model_slots:
            raise RuntimeError(
                f"ring has only {self.spec.model_slots} model slots; "
                f"build the spec with more model_slots to share further")
        j = self._slots_claimed
        self._slots_claimed += 1
        return j

    # -- data path ---------------------------------------------------------

    def apply(self, ev: LabeledUpdate) -> None:
        """Fire one labeled insert/delete through the ring triggers."""
        for name, carrier in event_carriers(ev, self.spec.capacity):
            self.engine.apply_update(name, carrier)
        self.event_log.append(
            (ev.weight, np.asarray(ev.x, np.float32).reshape(-1).copy()))
        self.events_applied += 1

    def apply_events(self, events) -> int:
        n = 0
        for ev in events:
            self.apply(ev)
            n += 1
        return n

    @property
    def log_version(self) -> int:
        """Monotone ring version: solvers diff their cursor against it
        to know how many gram events their cached factor is behind."""
        return len(self.event_log)

    # -- read path ---------------------------------------------------------

    def read(self, *names: str) -> Dict[str, np.ndarray]:
        """Read views (folds any deferred windows first — on an
        ``order>=2`` ring this is where banked updates materialize)."""
        self.engine.output()
        if not names:
            names = tuple(self.program.output_names())
        return {n: np.asarray(self.engine.views[n]) for n in names}

    def view(self, name: str) -> np.ndarray:
        return self.read(name)[name]

    def gram(self) -> np.ndarray:
        return self.view("G")

    def xty(self) -> np.ndarray:
        return self.view("XY")

    def count(self) -> float:
        return float(self.view("c").reshape(()))

    def sum_x(self) -> np.ndarray:
        return self.view("s").reshape(-1)

    def mean_x(self) -> np.ndarray:
        c = max(self.count(), 1.0)
        return self.sum_x() / c

    def live_data(self) -> Tuple[np.ndarray, np.ndarray]:
        """The live examples ``(X_live, Y_live)`` read straight from
        the maintained X/Y/W input views (slot order)."""
        self.engine.output()
        X = np.asarray(self.engine.views["X"])
        Y = np.asarray(self.engine.views["Y"])
        W = np.asarray(self.engine.views["W"]).reshape(-1)
        live = W > 0.5
        return X[live], Y[live]

    # -- model slots (gradient as a maintained view) -----------------------

    def model(self, slot: int) -> np.ndarray:
        """The coefficients the ring currently maintains for ``slot``
        (the applied low-rank approximations, matching input ``B{slot}``
        in the engine up to the carried compression residual)."""
        z = np.zeros((self.spec.features, self.spec.targets), np.float32)
        return self._models.get(slot, z).copy()

    def set_model(self, slot: int, B_new: np.ndarray) -> None:
        """Move slot ``slot`` to ``B_new`` by firing the factored delta
        through the ``B{slot}`` trigger, keeping ``grad{slot}`` a
        maintained view.

        ``ΔB = B_new − B_applied`` has rank ≤ ``targets``, so the
        rank-``targets`` ``compress_leaf`` factors (warm-started on the
        identity right basis, with error feedback) are exact up to
        float — reused verbatim as the IVM delta.
        """
        from repro.train.grad_compression import compress_leaf
        if not (0 <= slot < self.spec.model_slots):
            raise IndexError(f"model slot {slot} out of range "
                             f"[0, {self.spec.model_slots})")
        s = self.spec
        B_new = np.asarray(B_new, np.float32).reshape(s.features, s.targets)
        B_cur = self._models.get(
            slot, np.zeros((s.features, s.targets), np.float32))
        err = self._model_err.get(
            slot, np.zeros((s.features, s.targets), np.float32))
        delta = B_new - B_cur
        if not np.any(delta) and not np.any(err):
            return
        q0 = np.eye(s.targets, dtype=np.float32)
        P, Q, new_err = compress_leaf(delta, q0, err)
        P, Q = np.asarray(P, np.float32), np.asarray(Q, np.float32)
        self.engine.apply_update(f"B{slot}", P, Q)
        self._models[slot] = B_cur + P @ Q.T
        self._model_err[slot] = np.asarray(new_err, np.float32)

    def gradient(self, slot: int, lam: float = 0.0) -> np.ndarray:
        """``∇ = G·B − XY + λ·B`` — the maintained ``grad{slot}`` view
        plus the read-time λ-term (one ring, every λ)."""
        g = self.view(f"grad{slot}")
        if lam:
            g = g + np.float32(lam) * self._models.get(
                slot, np.zeros_like(g))
        return g

    # -- introspection -----------------------------------------------------

    @property
    def stats(self):
        return self.engine.stats

    def __repr__(self) -> str:
        s = self.spec
        return (f"Ring(features={s.features}, targets={s.targets}, "
                f"capacity={s.capacity}, slots={s.model_slots}, "
                f"events={self.events_applied})")
