"""Solvers over the maintained ring (LINVIEW §5; F-IVM regression /
clustering).

The ring keeps ``G = XᵀX`` and ``XY = XᵀY`` exact under inserts and
deletes; a solver's job reduces to the normal-equation solve
``(G + λI)·B = XY``.  :class:`RidgeSolver` (λ=0 ⇒ OLS) caches the
Cholesky factor of ``G + λI`` and, on refresh, prices the two ways of
catching up with the ring's event log — ``k`` rank-one Cholesky
update/downdates (``2kn²``) versus refactoring from the maintained gram
(``n³/3``) — through :func:`repro.plan.solver_resolve_strategy`, the §7
incremental-vs-reeval crossover transplanted to the solver layer
(crossing at ``k ≈ n/6``).  A downdate that breaks positive
definiteness (numerically drained direction after delete-heavy churn)
falls back to the refactor arm.

Fitted coefficients are pushed back through :meth:`Ring.set_model`, so
``grad = G·B − XY`` stays a *maintained view*: reading the gradient
after more data arrives costs a view read, not an ``O(M·n·p)``
recompute.

:class:`KMeansSolver` reads the same ring: live rows from the
maintained ``X``/``W`` input views, seeded deterministically (so the
incremental fit is bit-comparable to batch retrain on the same data),
Lloyd steps on the live set.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core import solver_crossover_rank  # noqa: F401 (re-export)
from .ring import Ring


# ---------------------------------------------------------------------------
# Cholesky rank-1 update / downdate
# ---------------------------------------------------------------------------


class DowndateError(RuntimeError):
    """A rank-1 downdate left ``G + λI`` numerically non-PD; the caller
    falls back to refactoring from the maintained gram."""


def chol_rank1_update(L: np.ndarray, x: np.ndarray,
                      sign: float = 1.0) -> np.ndarray:
    """In-place lower-Cholesky rank-1 update: ``LLᵀ ± xxᵀ`` (Golub &
    Van Loan §6.5.4; ``sign=−1`` is the downdate, the delete path).

    ``O(n²)`` with vectorized column tails — the per-event arm of the
    §7 solver crossover.  Raises :class:`DowndateError` when a downdate
    pivot goes non-positive instead of fabricating a factor.
    """
    L = np.asarray(L)
    x = np.asarray(x, dtype=L.dtype).reshape(-1).copy()
    n = L.shape[0]
    sign = float(sign)
    for k in range(n):
        Lkk = L[k, k]
        r2 = Lkk * Lkk + sign * x[k] * x[k]
        if r2 <= 0.0 or not np.isfinite(r2):
            raise DowndateError(
                f"pivot {k} went non-positive ({r2:.3e}) during "
                f"{'downdate' if sign < 0 else 'update'}")
        r = np.sqrt(r2)
        c, s = r / Lkk, x[k] / Lkk
        L[k, k] = r
        if k + 1 < n:
            tail = L[k + 1:, k]
            tail += sign * s * x[k + 1:]
            tail /= c
            x[k + 1:] = c * x[k + 1:] - s * tail
    return L


def _solve_from_chol(L: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    from scipy.linalg import solve_triangular  # type: ignore
    z = solve_triangular(L, rhs, lower=True)
    return solve_triangular(L.T, z, lower=False)


def _solve_from_chol_np(L: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    # numpy-only back-substitution (scipy is not a baked-in dep)
    n = L.shape[0]
    z = np.zeros_like(rhs)
    for i in range(n):
        z[i] = (rhs[i] - L[i, :i] @ z[:i]) / L[i, i]
    b = np.zeros_like(rhs)
    for i in range(n - 1, -1, -1):
        b[i] = (z[i] - L[i + 1:, i] @ b[i + 1:]) / L[i, i]
    return b


def solve_cholesky(L: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """``(LLᵀ)⁻¹ rhs`` by two triangular solves (scipy when present,
    pure numpy otherwise — the container may not ship scipy)."""
    try:
        return _solve_from_chol(L, rhs)
    except ImportError:
        return _solve_from_chol_np(L, rhs)


# ---------------------------------------------------------------------------
# batch (retrain-from-scratch) baselines — the bench/test oracles
# ---------------------------------------------------------------------------


def batch_ridge(X: np.ndarray, Y: np.ndarray, lam: float = 0.0
                ) -> np.ndarray:
    """Retrain-from-scratch: build ``XᵀX`` from the raw live rows,
    factor, solve.  ``O(M·n² + n³/3)`` — what the ring's maintained-G
    refresh is benchmarked against."""
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    n = X.shape[1]
    A = X.T @ X + float(lam) * np.eye(n)
    L = np.linalg.cholesky(A)
    return solve_cholesky(L, X.T @ Y).astype(np.float32)


def batch_kmeans(X: np.ndarray, k: int, *, iters: int = 10,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded Lloyd on a raw data matrix → ``(centroids, labels)``.
    Deterministic given ``(X, k, iters, seed)`` — the retrain oracle
    :meth:`KMeansSolver.fit` is compared against."""
    X = np.asarray(X, np.float64)
    m = X.shape[0]
    k = min(k, max(m, 1))
    rng = np.random.default_rng(seed)
    if m == 0:
        return np.zeros((0, X.shape[1]), np.float32), np.zeros(0, np.int32)
    centers = X[rng.choice(m, size=k, replace=False)].copy()
    labels = np.zeros(m, dtype=np.int64)
    for _ in range(max(1, iters)):
        d2 = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        labels = d2.argmin(1)
        for j in range(k):
            mask = labels == j
            if mask.any():
                centers[j] = X[mask].mean(0)
    return centers.astype(np.float32), labels.astype(np.int32)


# ---------------------------------------------------------------------------
# ridge / OLS over the ring
# ---------------------------------------------------------------------------


@dataclass
class SolverStats:
    refreshes: int = 0
    chol_updates: int = 0      # rank-1 update/downdates applied
    refactors: int = 0         # full n³/3 refactors
    downdate_fallbacks: int = 0
    strategy_log: List[str] = field(default_factory=list)


class RidgeSolver:
    """Ridge regression (λ=0 ⇒ OLS) as a consumer of one ring slot.

    ``coefficients()`` reads ``G``/``XY`` from the ring, catches the
    cached Cholesky factor up with the ring's event log (update vs
    refactor priced per refresh), solves, and pushes the result back
    through :meth:`Ring.set_model` — after which ``gradient()`` is a
    maintained-view read.
    """

    def __init__(self, ring: Ring, lam: float = 0.0,
                 slot: Optional[int] = None, *,
                 update_cost_scale: float = 1.0):
        self.ring = ring
        self.lam = float(lam)
        self.slot = ring.claim_slot() if slot is None else slot
        self.update_cost_scale = float(update_cost_scale)
        self.stats = SolverStats()
        self._L: Optional[np.ndarray] = None
        self._cursor = 0           # position in ring.event_log
        self._coef: Optional[np.ndarray] = None
        self._coef_version = -1

    # -- factor maintenance ------------------------------------------------

    def _refactor(self) -> None:
        n = self.ring.spec.features
        A = self.ring.gram().astype(np.float64) + self.lam * np.eye(n)
        self._L = np.linalg.cholesky(A)
        self._cursor = self.ring.log_version
        self.stats.refactors += 1

    def _catch_up(self) -> str:
        """Bring ``L`` up to the ring's log head; returns the strategy
        taken (``"update"`` / ``"refactor"`` / ``"fresh"``)."""
        from repro.plan import solver_resolve_strategy
        n = self.ring.spec.features
        pending = self.ring.log_version - self._cursor
        if self._L is None:
            self._refactor()
            return "fresh"
        if pending == 0:
            return "update"
        strategy = solver_resolve_strategy(
            n, pending, cost_scale=self.update_cost_scale)
        if strategy == "refactor":
            self._refactor()
            return "refactor"
        try:
            for w, x in self.ring.event_log[self._cursor:]:
                chol_rank1_update(self._L, x.astype(np.float64), sign=w)
                self.stats.chol_updates += 1
            self._cursor = self.ring.log_version
        except DowndateError:
            # numerically drained pivot after churn: the maintained gram
            # is still exact — refactor from it
            self.stats.downdate_fallbacks += 1
            self._refactor()
            return "refactor"
        return "update"

    # -- solve -------------------------------------------------------------

    def coefficients(self, *, push: bool = True) -> np.ndarray:
        """The current model ``B = (G + λI)⁻¹·XY`` against everything
        the ring has absorbed.  With ``push`` (default) the result is
        written back to the ring slot so ``grad{slot}`` stays
        maintained."""
        version = self.ring.log_version
        if self._coef is not None and self._coef_version == version:
            return self._coef.copy()
        strategy = self._catch_up()
        self.stats.refreshes += 1
        self.stats.strategy_log.append(strategy)
        rhs = self.ring.xty().astype(np.float64)
        B = solve_cholesky(self._L, rhs).astype(np.float32)
        self._coef, self._coef_version = B, version
        if push:
            self.ring.set_model(self.slot, B)
        return B.copy()

    def gradient(self) -> np.ndarray:
        """``∇ = G·B − XY + λ·B`` via the maintained view (requires a
        prior ``coefficients()`` push for freshness of the B input)."""
        return self.ring.gradient(self.slot, self.lam)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, np.float32) @ self.coefficients(push=False)


class OLSSolver(RidgeSolver):
    """λ=0 ridge, named for the §5.1 workload."""

    def __init__(self, ring: Ring, slot: Optional[int] = None, **kw):
        super().__init__(ring, lam=0.0, slot=slot, **kw)


# ---------------------------------------------------------------------------
# k-means over the ring
# ---------------------------------------------------------------------------


class KMeansSolver:
    """Lloyd's k-means reading the ring's maintained ``X``/``W`` views.

    The assignment/centroid steps consume the *maintained* design
    matrix — exact under inserts and deletes because the row carriers
    are — so ``fit()`` after any churn equals
    :func:`batch_kmeans` on the surviving rows (same seed, same
    deterministic init), which is the property the tests pin.
    """

    def __init__(self, ring: Ring, k: int, *, iters: int = 10,
                 seed: int = 0):
        self.ring = ring
        self.k = int(k)
        self.iters = int(iters)
        self.seed = int(seed)
        self.centers: Optional[np.ndarray] = None
        self.inertia: float = float("nan")
        self.fits = 0

    def fit(self) -> np.ndarray:
        X_live, _ = self.ring.live_data()
        centers, labels = batch_kmeans(X_live, self.k, iters=self.iters,
                                       seed=self.seed)
        self.centers = centers
        if len(labels):
            d2 = ((X_live[:, None, :].astype(np.float64)
                   - centers[None, :, :]) ** 2).sum(-1)
            self.inertia = float(d2[np.arange(len(labels)), labels].sum())
        else:
            self.inertia = 0.0
        self.fits += 1
        return centers

    def assign(self, X: np.ndarray) -> np.ndarray:
        if self.centers is None:
            self.fit()
        d2 = ((np.asarray(X, np.float64)[:, None, :]
               - self.centers[None, :, :]) ** 2).sum(-1)
        return d2.argmin(1).astype(np.int32)
