"""repro.fivm — learning over evolving data: models maintained as
incremental views (LINVIEW §5 + the F-IVM line, arXiv 1703.07484 /
2006.00694).

The subsystem composes substrates that already exist in this repo into
a learning-serving layer:

  * :mod:`repro.fivm.ring` — the maintained covariance/gram "ring"
    ``(c, s, G) = (count, Σxᵢ, XᵀX)`` plus ``XᵀY``, registered as views
    in the LINVIEW compiler and updated under factored insert *and*
    delete (negative-weight downdate) carriers;
  * :mod:`repro.fivm.solvers` — ridge/OLS whose normal-equation solve
    consumes the ring (Cholesky update/downdate or planner-priced
    refactor past the §7 crossover) and k-means reading the same ring
    views, each pushing its coefficients back as a maintained gradient
    view via ``train/grad_compression`` factors;
  * :mod:`repro.fivm.registry` — the pinned-view registry: one ring,
    many models, shared across interactive analyses and fleet tenants.

See docs/fivm.md for the serve contract (decoupled refresh).
"""

from .ring import (Ring, RingSpec, build_ring_program, event_carriers,
                   initial_ring_inputs)
from .solvers import (DowndateError, KMeansSolver, OLSSolver, RidgeSolver,
                      batch_kmeans, batch_ridge, chol_rank1_update,
                      solve_cholesky)
from .registry import RingRegistry

__all__ = [
    "Ring", "RingSpec", "build_ring_program", "event_carriers",
    "initial_ring_inputs",
    "RidgeSolver", "OLSSolver", "KMeansSolver", "batch_ridge",
    "batch_kmeans", "chol_rank1_update", "solve_cholesky",
    "DowndateError", "RingRegistry",
]
