"""Matrix powers A^k (paper §5.2, Fig. 3a–c, Tables 2–3)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import Program
from repro.core.iterative import matrix_powers as build_powers_program
from .common import App


class MatrixPowers(App):
    def __init__(self, n: int, k: int = 16, model: str = "exp", s: int = 4,
                 rank: int = 1, **kw):
        prog = build_powers_program(k=k, n=n, model=model, s=s)
        super().__init__(prog, "A", rank=rank, **kw)
        self.n, self.k, self.model = n, k, model

    @staticmethod
    def synthesize(n: int, seed: int = 0, spectral_scale: float = 0.9):
        """Random A scaled to spectral radius < 1 so powers stay bounded
        ('preconditioned appropriately for numerical stability', §7)."""
        rng = np.random.default_rng(seed)
        A = rng.normal(size=(n, n)).astype(np.float32)
        A *= spectral_scale / max(1e-6, float(np.max(np.abs(
            np.linalg.eigvals(A[:256, :256]))))) if n <= 256 else 1.0
        if n > 256:
            A *= spectral_scale / np.sqrt(n)  # circular law estimate
        return {"A": jnp.asarray(A)}

    def row_update(self, row: int, delta_row: np.ndarray):
        u = np.zeros((self.n, 1), dtype=np.float32)
        u[row, 0] = 1.0
        v = np.asarray(delta_row, dtype=np.float32).reshape(self.n, 1)
        return jnp.asarray(u), jnp.asarray(v)
