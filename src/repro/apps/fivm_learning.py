"""Learning over evolving data (repro.fivm; LINVIEW §5 + F-IVM).

The app bundles one maintained ring, a labeled insert/delete stream,
and the solvers living on it — ridge (λ at read), OLS, k-means — into
the uniform app scaffolding, so benchmarks and the serve driver treat
"models as incremental views" like any other paper workload.

The serve shape (``launch/serve.py --fivm``) runs the ring at
``order=2``: every arriving example banks as a factored delta (O(rank)
bookkeeping — the deferred-input fast path), and the normal-equation
re-solve happens when a *read* folds the window — model-refresh latency
is decoupled from data arrival.  See docs/fivm.md.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core import ReevalEngine
from repro.data import labeled_stream
from repro.fivm import KMeansSolver, RidgeSolver, Ring, RingSpec
from .common import App, register_app


@register_app("fivm_learning")
class FivmLearning(App):
    """One ring, a labeled stream, and its resident models.

    ``order=2`` puts the ring in decoupled (bank-on-ingest,
    fold-on-read) mode; the default first-order ring refreshes views on
    every firing like the other apps.
    """

    def __init__(self, features: int = 16, targets: int = 1,
                 capacity: int = 128, model_slots: int = 2,
                 churn: float = 0.3, lam: float = 0.1, clusters: int = 4,
                 seed: int = 0, order: Optional[int] = None,
                 jit: bool = True, with_reeval: bool = False, **ring_kw):
        self.spec = RingSpec(features=features, targets=targets,
                             capacity=capacity, model_slots=model_slots)
        self.ring = Ring(self.spec, seed=seed, jit=jit, order=order,
                         **ring_kw)
        # App scaffolding fields (uniform benchmark/driver surface)
        self.program = self.ring.program
        self.update_input = "X"
        self.rank = 1
        self.engine = self.ring.engine
        self.reeval = None
        if with_reeval:
            self.reeval = ReevalEngine(self.program, jit=jit)
            self.reeval.initialize(self.ring.initial_inputs())
        self.stream = labeled_stream(features, targets=targets,
                                     capacity=capacity, churn=churn,
                                     seed=seed)
        self.model = RidgeSolver(self.ring, lam=lam)
        self.kmeans = KMeansSolver(self.ring, clusters, seed=seed)

    # -- data path ---------------------------------------------------------

    def ingest(self, count: int) -> int:
        """Pull ``count`` events off the stream into the ring."""
        return self.ring.apply_events(self.stream.events(count))

    def refresh(self) -> np.ndarray:
        """Re-solve the resident ridge model against everything the
        ring absorbed (folds any banked windows first)."""
        return self.model.coefficients()

    # -- serve demo --------------------------------------------------------

    def serve_demo(self, *, bursts: int = 8, burst_size: int = 32,
                   reads: int = 4) -> Dict[str, object]:
        """Decoupled-refresh serving: ``bursts`` ingest bursts with
        interleaved model reads; returns the timing/staleness ledger
        the serve driver prints.  Ingest time is pure banking on an
        ``order>=2`` ring; each read pays its own fold + re-solve."""
        ingest_s, read_s = [], []
        events = 0
        for b in range(bursts):
            t0 = time.perf_counter()
            events += self.ingest(burst_size)
            ingest_s.append(time.perf_counter() - t0)
            if (b + 1) % max(1, bursts // max(1, reads)) == 0:
                t0 = time.perf_counter()
                self.refresh()
                read_s.append(time.perf_counter() - t0)
        stats = self.ring.stats
        return {
            "events": events,
            "live": float(self.ring.count()),
            "ingest_us_per_event": 1e6 * sum(ingest_s) / max(events, 1),
            "read_ms": [1e3 * t for t in read_s],
            "folds": stats.folds,
            "refreshes": self.model.stats.refreshes,
            "strategies": list(self.model.stats.strategy_log),
        }
