"""Sums of matrix powers S_k = I + A + … + A^{k-1} (paper §5.2.3, Fig. 3d)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.iterative import sums_of_powers as build_sums_program
from .common import App


class SumsOfPowers(App):
    def __init__(self, n: int, k: int = 16, model: str = "exp", s: int = 4,
                 rank: int = 1, **kw):
        prog = build_sums_program(k=k, n=n, model=model, s=s)
        super().__init__(prog, "A", rank=rank, **kw)
        self.n, self.k, self.model = n, k, model

    @staticmethod
    def synthesize(n: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        A = rng.normal(size=(n, n)).astype(np.float32)
        A *= 0.9 / np.sqrt(n)
        return {"A": jnp.asarray(A)}
