"""General iterative form T_{i+1} = A·T_i + B (paper §5.3, Fig. 3g–h)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.iterative import general_form as build_general_program
from .common import App


class GeneralIterative(App):
    def __init__(self, n: int, p: int, k: int = 16, model: str = "exp",
                 s: int = 4, with_b: bool = True, rank: int = 1,
                 force_rep=None, **kw):
        prog = build_general_program(k=k, n=n, p_dim=p, model=model, s=s,
                                     with_b=with_b)
        super().__init__(prog, "A", rank=rank, force_rep=force_rep, **kw)
        self.n, self.p, self.k, self.model = n, p, k, model
        self.with_b = with_b

    @staticmethod
    def synthesize(n: int, p: int, with_b: bool = True, seed: int = 0):
        rng = np.random.default_rng(seed)
        A = (rng.normal(size=(n, n)) * 0.9 / np.sqrt(n)).astype(np.float32)
        T0 = rng.normal(size=(n, p)).astype(np.float32)
        out = {"A": jnp.asarray(A), "T0": jnp.asarray(T0)}
        if with_b:
            out["B"] = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
        return out
