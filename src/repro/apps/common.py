"""Shared app scaffolding: every app exposes the same engine triple
(incremental / re-evaluation / hybrid-forced) so benchmarks and tests treat
them uniformly."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import IncrementalEngine, Program, ReevalEngine

Array = jax.Array


@dataclass
class AppEngines:
    program: Program
    incremental: IncrementalEngine
    reeval: ReevalEngine

    def initialize(self, inputs: Dict[str, Array]):
        self.incremental.initialize(inputs)
        self.reeval.initialize(inputs)

    def update_both(self, input_name: str, u: Array, v: Array):
        self.incremental.apply_update(input_name, u, v)
        self.reeval.apply_update(input_name, u, v)

    def divergence(self, name: Optional[str] = None) -> float:
        name = name or self.program.output_names()[0]
        a = self.incremental.views[name]
        b = self.reeval.views[name]
        scale = float(jnp.max(jnp.abs(b))) or 1.0
        return float(jnp.max(jnp.abs(a - b))) / scale


class App:
    """Base: subclasses set ``self.program`` and ``self.update_input``."""

    program: Program
    update_input: str

    def __init__(self, program: Program, update_input: str, rank: int = 1,
                 force_rep: Optional[str] = None, sequential_sm: bool = False,
                 apply_backend: str = "xla", jit: bool = True):
        self.program = program
        self.update_input = update_input
        self.rank = rank
        self.engine = IncrementalEngine(
            program, {update_input: rank}, force_rep=force_rep,
            sequential_sm=sequential_sm, apply_backend=apply_backend, jit=jit)
        self.reeval = ReevalEngine(program, jit=jit)

    def initialize(self, inputs: Dict[str, Array]):
        self.engine.initialize(inputs)
        self.reeval.initialize(inputs)
        return self

    def update(self, u: Array, v: Array) -> Array:
        self.engine.apply_update(self.update_input, u, v)
        return self.engine.output()

    def update_reeval(self, u: Array, v: Array) -> Array:
        self.reeval.apply_update(self.update_input, u, v)
        return self.reeval.output()

    def output(self) -> Array:
        return self.engine.output()

    def speedup_estimate(self) -> float:
        """Analytic FLOP ratio reeval/incremental for one update."""
        return (self.engine.reeval_flops() /
                max(self.engine.trigger_flops(self.update_input), 1.0))


# ---------------------------------------------------------------------------
# app discovery
# ---------------------------------------------------------------------------

_APP_REGISTRY: Dict[str, type] = {}


def register_app(name: str, factory: Optional[type] = None):
    """Register an app factory under ``name`` so drivers enumerate it.

    Usable as a decorator (``@register_app("ols")``) or a direct call
    (``register_app("ols", OLS)``).  ``launch/serve.py`` and the
    benchmarks look apps up here instead of hand-wiring imports —
    adding an app module plus one ``register_app`` line makes it
    discoverable everywhere.
    """
    def _register(f):
        _APP_REGISTRY[name] = f
        return f
    if factory is not None:
        return _register(factory)
    return _register


def get_app(name: str) -> type:
    """The registered factory for ``name`` (KeyError lists what's
    available)."""
    try:
        return _APP_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown app {name!r}; available: "
                       f"{available_apps()}") from None


def available_apps() -> list:
    return sorted(_APP_REGISTRY)
