"""Ordinary Least Squares (paper §5.1, Examples 4.2/4.3, Fig. 3e).

``β* = (XᵀX)⁻¹ Xᵀ Y`` maintained under rank-1 (row) updates to X.
Incremental cost O(n² + mn) vs re-evaluation O(n^γ + mn²).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import Program, dim, inverse, matmul, transpose
from .common import App


def build_ols_program(m: int, n: int, p: int) -> Program:
    prog = Program(name=f"ols_m{m}_n{n}_p{p}")
    M, N, P = dim("m"), dim("n"), dim("p")
    X = prog.input("X", (M, N))
    Y = prog.input("Y", (M, P))
    Z = prog.let("Z", matmul(transpose(X), X))
    W = prog.let("W", inverse(Z))
    prog.let("beta", matmul(W, matmul(transpose(X), Y)))
    prog.outputs = ["beta"]
    prog.bind_dims(m=m, n=n, p=p)
    return prog


class OLS(App):
    def __init__(self, m: int, n: int, p: int = 1, rank: int = 1,
                 sequential_sm: bool = False, **kw):
        super().__init__(build_ols_program(m, n, p), "X", rank=rank,
                         sequential_sm=sequential_sm, **kw)
        self.m, self.n, self.p = m, n, p

    @staticmethod
    def synthesize(m: int, n: int, p: int = 1, seed: int = 0,
                   noise: float = 0.1):
        """Well-conditioned synthetic regression problem."""
        rng = np.random.default_rng(seed)
        X = rng.normal(size=(m, n)).astype(np.float32)
        beta_true = rng.normal(size=(n, p)).astype(np.float32)
        Y = X @ beta_true + noise * rng.normal(size=(m, p)).astype(np.float32)
        return {"X": jnp.asarray(X), "Y": jnp.asarray(Y)}, beta_true

    def row_update(self, row: int, delta_row: np.ndarray):
        """The paper's update pattern: one row of X changes."""
        u = np.zeros((self.m, 1), dtype=np.float32)
        u[row, 0] = 1.0
        v = np.asarray(delta_row, dtype=np.float32).reshape(self.n, 1)
        return jnp.asarray(u), jnp.asarray(v)
