"""Batch gradient descent for linear regression (paper §7 "B≠0", Fig. 3h).

    Θ_{i+1} = Θ_i − η·Xᵀ(X·Θ_i − Y)  ≡  A·Θ_i + B,
    A := I − η·XᵀX   (view),   B := η·XᵀY   (view).

Updates to X hit *both* A and B; the compiler's simultaneous multi-view
delta propagation (Example 4.5) handles this in one trigger.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import Program, dim, identity, matmul, scale, sub, transpose
from repro.core.iterative import append_general_iteration
from .common import App


def build_bgd_program(m: int, n: int, p: int, k: int = 16, eta: float = 1e-3,
                      model: str = "linear", s: int = 4) -> Program:
    prog = Program(name=f"bgd_{model}_k{k}")
    M, N, P_ = dim("m"), dim("n"), dim("p")
    X = prog.input("X", (M, N))
    Y = prog.input("Y", (M, P_))
    Theta0 = prog.input("Theta0", (N, P_))
    G = prog.let("G", matmul(transpose(X), X))           # XᵀX
    A = prog.let("A", sub(identity(N), scale(eta, G)))   # I − η·XᵀX
    B = prog.let("B", scale(eta, matmul(transpose(X), Y)))
    out = append_general_iteration(prog, A, B, Theta0, k, model, s)
    prog.outputs = [out]
    prog.bind_dims(m=m, n=n, p=p)
    return prog


class BatchGradientDescent(App):
    def __init__(self, m: int, n: int, p: int, k: int = 16, eta: float = 1e-3,
                 model: str = "linear", s: int = 4, rank: int = 1, **kw):
        super().__init__(build_bgd_program(m, n, p, k, eta, model, s),
                         "X", rank=rank, **kw)
        self.m, self.n, self.p, self.k, self.eta = m, n, p, k, eta

    @staticmethod
    def synthesize(m: int, n: int, p: int, eta: float = None, seed: int = 0):
        rng = np.random.default_rng(seed)
        X = (rng.normal(size=(m, n)) / np.sqrt(m)).astype(np.float32)
        beta = rng.normal(size=(n, p)).astype(np.float32)
        Y = (X @ beta + 0.01 * rng.normal(size=(m, p))).astype(np.float32)
        Theta0 = np.zeros((n, p), dtype=np.float32)
        return {"X": jnp.asarray(X), "Y": jnp.asarray(Y),
                "Theta0": jnp.asarray(Theta0)}

    def row_update(self, row: int, delta_row: np.ndarray):
        u = np.zeros((self.m, 1), dtype=np.float32)
        u[row, 0] = 1.0
        v = np.asarray(delta_row, dtype=np.float32).reshape(self.n, 1)
        return jnp.asarray(u), jnp.asarray(v)
