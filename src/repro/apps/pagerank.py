"""PageRank via the power method (paper §5.2/§5.3).

    r_{i+1} = α·M·r_i + (1−α)/n · 1

with M the column-stochastic transition matrix.  In LINVIEW form this is
the general iteration with ``A := α·M`` (a *view*, so edge updates to M
propagate through the Scale delta rule) and constant ``B``.

Edge updates: inserting/removing edges incident to one page changes one
column of M — a rank-1 update (paper §4.2's "one complete row or column").
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import Program, dim, scale
from repro.core.iterative import append_general_iteration
from .common import App


def build_pagerank_program(n: int, k: int = 16, alpha: float = 0.85,
                           model: str = "linear", s: int = 4) -> Program:
    prog = Program(name=f"pagerank_{model}_k{k}")
    N, ONE = dim("n"), 1
    M = prog.input("M", (N, N))
    r0 = prog.input("r0", (N, ONE))
    e = prog.input("e", (N, ONE))       # (1−α)/n · 1 — static teleport vector
    A = prog.let("A", scale(alpha, M))
    out = append_general_iteration(prog, A, e, r0, k, model, s)
    prog.outputs = [out]
    prog.bind_dims(n=n, p=1)
    return prog


class PageRank(App):
    def __init__(self, n: int, k: int = 16, alpha: float = 0.85,
                 model: str = "linear", s: int = 4, rank: int = 1, **kw):
        super().__init__(build_pagerank_program(n, k, alpha, model, s),
                         "M", rank=rank, **kw)
        self.n, self.k, self.alpha = n, k, alpha

    @staticmethod
    def synthesize(n: int, alpha: float = 0.85, avg_degree: int = 8,
                   seed: int = 0):
        """Random graph → column-stochastic M, uniform r0, teleport e."""
        rng = np.random.default_rng(seed)
        adj = (rng.random((n, n)) < avg_degree / n).astype(np.float32)
        np.fill_diagonal(adj, 0.0)
        deg = adj.sum(axis=0)
        deg[deg == 0] = 1.0
        M = adj / deg  # column-stochastic
        r0 = np.full((n, 1), 1.0 / n, dtype=np.float32)
        e = np.full((n, 1), (1.0 - alpha) / n, dtype=np.float32)
        return {"M": jnp.asarray(M.astype(np.float32)),
                "r0": jnp.asarray(r0), "e": jnp.asarray(e)}

    def edge_update(self, page: int, new_column: np.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Replace the outlink column of ``page``: M[:,page] = new_column.

        Returns (u, v) with ΔM = u vᵀ, u = new_col − old_col, v = e_page.
        """
        old = np.asarray(self.engine.views["M"][:, page])
        u = (np.asarray(new_column, dtype=np.float32) - old).reshape(-1, 1)
        v = np.zeros((self.n, 1), dtype=np.float32)
        v[page, 0] = 1.0
        return jnp.asarray(u), jnp.asarray(v)
