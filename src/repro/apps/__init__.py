"""Analytics applications from the paper (§5), built on the LINVIEW core."""

from .ols import build_ols_program, OLS
from .matrix_powers import build_powers_program, MatrixPowers
from .sums_powers import build_sums_program, SumsOfPowers
from .general_iterative import build_general_program, GeneralIterative
from .pagerank import build_pagerank_program, PageRank
from .gradient_descent import build_bgd_program, BatchGradientDescent

__all__ = [
    "build_ols_program", "OLS",
    "build_powers_program", "MatrixPowers",
    "build_sums_program", "SumsOfPowers",
    "build_general_program", "GeneralIterative",
    "build_pagerank_program", "PageRank",
    "build_bgd_program", "BatchGradientDescent",
]
