"""Analytics applications from the paper (§5), built on the LINVIEW core.

Every app registers itself in the :mod:`repro.apps.common` registry —
``available_apps()`` / ``get_app(name)`` — so drivers and benchmarks
enumerate them without hand-wired imports.
"""

from .common import App, available_apps, get_app, register_app
from .ols import build_ols_program, OLS
from .matrix_powers import build_powers_program, MatrixPowers
from .sums_powers import build_sums_program, SumsOfPowers
from .general_iterative import build_general_program, GeneralIterative
from .pagerank import build_pagerank_program, PageRank
from .gradient_descent import build_bgd_program, BatchGradientDescent
from .fivm_learning import FivmLearning

# classic apps predate the registry; registering here (rather than per
# module) keeps their modules import-order free
for _name, _cls in (("ols", OLS), ("matrix_powers", MatrixPowers),
                    ("sums_powers", SumsOfPowers),
                    ("general_iterative", GeneralIterative),
                    ("pagerank", PageRank),
                    ("gradient_descent", BatchGradientDescent)):
    register_app(_name, _cls)
del _name, _cls

__all__ = [
    "App", "available_apps", "get_app", "register_app",
    "build_ols_program", "OLS",
    "build_powers_program", "MatrixPowers",
    "build_sums_program", "SumsOfPowers",
    "build_general_program", "GeneralIterative",
    "build_pagerank_program", "PageRank",
    "build_bgd_program", "BatchGradientDescent",
    "FivmLearning",
]
