"""Lease-based work claims — crash-safe coordination with no leader.

A refresh worker that wants to fire a tenant's dirty views **claims**
the tenant under a TTL lease; commit requires the lease to still be
current.  There is no leader election and no failure detector: a
crashed worker simply stops renewing, its lease expires, and any other
worker reclaims the tenant and replays from the tenant's update log.
Safety comes from two mechanisms:

  * **fencing tokens** — every claim gets a per-tenant monotonically
    increasing token; a commit (or renew, or release) presented with a
    superseded token is rejected, so a slow worker that lost its lease
    mid-claim can never clobber the reclaimer's work;
  * **expiry-checked commits** — a lease past its TTL fails
    :meth:`LeaseStore.is_current` even when nobody reclaimed yet, so
    the slow worker rolls back *itself* instead of racing the clock.

The store is process-local (one lock) by design: the fleet runs its
workers as threads over in-memory engines, and the protocol — claim /
fence / expire / reclaim — is exactly what a shared lease table (DB
row, object-store conditional put) would enforce for a multi-process
fleet.  Everything takes an injectable ``clock`` so chaos runs and
tests drive virtual time deterministically.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional


@dataclass
class Lease:
    """One worker's live claim on one tenant."""

    tenant_id: str
    worker_id: str
    token: int            # fencing token: monotone per tenant
    expires_at: float
    released: bool = False

    def __repr__(self) -> str:
        state = "released" if self.released else f"until={self.expires_at:.3f}"
        return (f"Lease({self.tenant_id!r} -> {self.worker_id!r} "
                f"#{self.token} {state})")


class LeaseStore:
    """Per-tenant TTL leases with fencing tokens (thread-safe)."""

    def __init__(self, ttl: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        if ttl <= 0:
            raise ValueError(f"lease ttl must be positive, got {ttl}")
        self.ttl = ttl
        self._clock = clock
        self._lock = threading.Lock()
        self._leases: Dict[str, Lease] = {}
        self._tokens: Dict[str, int] = {}
        self.claims = 0
        self.reclaims = 0       # claims that displaced an expired holder
        self.fence_rejections = 0
        self.broken = 0         # chaos-forced expiries

    # -- claim lifecycle -----------------------------------------------------
    def claim(self, tenant_id: str, worker_id: str) -> Optional[Lease]:
        """Claim ``tenant_id`` for ``worker_id``; None while a live
        (unexpired, unreleased) lease is held by anyone — including this
        worker: claims are not reentrant, one claim = one firing cycle."""
        with self._lock:
            now = self._clock()
            cur = self._leases.get(tenant_id)
            if cur is not None and not cur.released:
                if now < cur.expires_at:
                    return None
                # expired uncommitted claim: the holder crashed or
                # stalled — reclaim (the new token fences the old holder)
                self.reclaims += 1
            token = self._tokens.get(tenant_id, 0) + 1
            self._tokens[tenant_id] = token
            lease = Lease(tenant_id, worker_id, token, now + self.ttl)
            self._leases[tenant_id] = lease
            self.claims += 1
            return lease

    def renew(self, lease: Lease) -> bool:
        """Extend a still-current lease by one TTL; False (no extension)
        once fenced or expired — a worker that failed to renew must
        abandon its claim, not keep working."""
        with self._lock:
            if not self._current(lease):
                self.fence_rejections += 1
                return False
            lease.expires_at = self._clock() + self.ttl
            return True

    def release(self, lease: Lease) -> bool:
        """Give the tenant back (after commit or a clean failure).
        False when the lease was already fenced/expired — the caller's
        work must have been rolled back by then."""
        with self._lock:
            if not self._current(lease):
                self.fence_rejections += 1
                return False
            lease.released = True
            del self._leases[lease.tenant_id]
            return True

    # -- fencing checks ------------------------------------------------------
    def _current(self, lease: Lease) -> bool:
        cur = self._leases.get(lease.tenant_id)
        return (cur is lease and not lease.released
                and self._clock() < lease.expires_at)

    def is_current(self, lease: Lease) -> bool:
        """The commit-time fencing check: this exact token, unreleased,
        unexpired.  A False here means the claim's work MUST be rolled
        back — another worker may already be replaying it."""
        with self._lock:
            return self._current(lease)

    def holder(self, tenant_id: str) -> Optional[Lease]:
        """The live lease on a tenant (None when free or expired)."""
        with self._lock:
            cur = self._leases.get(tenant_id)
            if (cur is None or cur.released
                    or self._clock() >= cur.expires_at):
                return None
            return cur

    def break_lease(self, tenant_id: str) -> bool:
        """Force-expire the current lease (chaos: ``lease_expiry_p``).
        The holder's next fencing check fails exactly as if the TTL had
        run out under it."""
        with self._lock:
            cur = self._leases.get(tenant_id)
            if cur is None or cur.released:
                return False
            cur.expires_at = self._clock()
            self.broken += 1
            return True

    def expired(self) -> List[Lease]:
        """Unreleased leases past their TTL — claims whose holder died
        or stalled, waiting to be reclaimed."""
        with self._lock:
            now = self._clock()
            return [l for l in self._leases.values()
                    if not l.released and now >= l.expires_at]

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"claims": self.claims, "reclaims": self.reclaims,
                    "fence_rejections": self.fence_rejections,
                    "broken": self.broken,
                    "live": sum(1 for l in self._leases.values()
                                if not l.released
                                and self._clock() < l.expires_at)}
