"""The fleet scheduler: lease-claimed, SLO-prioritized, overload-aware.

One :class:`FleetScheduler` coordinates N tenants and M workers with no
leader and no failure detector — coordination is entirely the
:class:`~repro.fleet.lease.LeaseStore` protocol:

    claim → (replay a dead claim's rollback) → snapshot → fire pending
    log entries → fencing check → commit | self-rollback

A worker that crashes mid-claim (chaos ``worker_crash_p``, or a real
exception) simply leaves its lease to expire; the reclaimer finds the
tenant's ``inflight`` record, restores the pre-firing snapshot
(bit-identical — jax arrays are immutable) and replays the same log
entries.  A worker that *loses* its lease mid-claim (TTL ran out,
chaos ``lease_expiry_p`` broke it) fails the commit-time fencing check
and rolls **itself** back.  Either way every log entry is reflected in
the committed store exactly once.

Scheduling order is SLO-aware: tenants are scored by
``priority × staleness-pressure / planner-estimated firing cost``
(:func:`repro.plan.firing_cost_flops`), with SLO-overdue tenants
boosted above everything else — a cheap overdue tenant beats an
expensive fresh one.

Overload is handled in explicit tiers (:class:`OverloadPolicy`): past
``degraded_at`` utilization, cold sheddable tenants degrade to
re-eval-on-read (pending deltas fold straight into their inputs, one
re-evaluation on the next read — no trigger sweeps); past
``shedding_at``, admission refuses sheddable tenants' updates outright.
Reads always serve the last committed snapshot, so overload degrades
freshness, never correctness.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.guard import as_monkey
from repro.guard.txn import restore_snapshot, take_snapshot
from repro.plan import firing_cost_flops

from .admission import ADMITTED, AdmissionController
from .lease import LeaseStore
from .tenant import Inflight, LogEntry, Tenant, TenantRegistry, TenantSpec


class WorkerCrashed(RuntimeError):
    """Chaos ``worker_crash_p`` fired: the worker dies mid-claim,
    leaving its lease and the tenant's inflight record for a reclaimer."""


@dataclass(frozen=True)
class OverloadPolicy:
    """When the fleet stops pretending it can keep everyone fresh.

    ``load`` is total pending log entries over total queue capacity.
    Crossing ``degraded_at`` degrades *cold* sheddable tenants (no read
    for ``cold_after_s``) to re-eval-on-read; crossing ``shedding_at``
    additionally sheds new sheddable traffic at admission.
    """

    degraded_at: float = 0.6
    shedding_at: float = 0.85
    cold_after_s: float = 5.0


@dataclass
class FleetConfig:
    lease_ttl: float = 1.0
    overload: OverloadPolicy = field(default_factory=OverloadPolicy)
    chaos: Optional[object] = None   # ChaosConfig/ChaosMonkey: worker faults
    workers: int = 4                 # threads for start()
    idle_sleep_s: float = 0.002      # thread-worker poll interval


class FleetScheduler:
    """Workers + leases + admission over a :class:`TenantRegistry`.

    Deterministic drive: :meth:`run_claim` / :meth:`run_until_idle`
    with an injectable ``clock``/``sleep`` (tests, chaos acceptance).
    Live drive: :meth:`start` / :meth:`stop` thread pool.
    """

    def __init__(self, config: Optional[FleetConfig] = None,
                 registry: Optional[TenantRegistry] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.config = config or FleetConfig()
        self._clock = clock
        self._sleep = sleep
        self.registry = registry or TenantRegistry(clock=clock)
        self.leases = LeaseStore(self.config.lease_ttl, clock=clock)
        self.admission = AdmissionController(clock=clock)
        self.chaos = as_monkey(self.config.chaos)
        # firing_cost_flops walks the trigger IR; priority calls it for
        # every claimable tenant on every claim, so memoize per
        # (tenant, input, rank, order-signature) — pure in the program
        # structure and the engine's resolved view depths
        self._cost_memo: Dict[Tuple[str, str, int, tuple, float],
                              float] = {}
        self._any_degraded = False  # lets _apply_tier skip the scan
        # aggregate pending/capacity, maintained at append/prune time —
        # load() sits on every submit, so it must not scan the registry
        self._load_lock = threading.Lock()
        self._pending_total = 0
        self._cap_total = 0
        self.worker_crashes = 0
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()

    # -- tenant lifecycle ----------------------------------------------------
    def add_tenant(self, spec: TenantSpec, inputs: Dict[str, object]
                   ) -> Tenant:
        tenant = self.registry.register(spec, inputs)
        self.admission.register(spec)
        with self._load_lock:
            self._cap_total += spec.queue_capacity
        return tenant

    def remove_tenant(self, tenant_id: str) -> None:
        tenant = self.registry.get(tenant_id)
        with self._load_lock:
            self._cap_total -= tenant.spec.queue_capacity
            self._pending_total -= tenant.log.pending_count(
                tenant.applied_lsn)
        self.admission.unregister(tenant_id)
        self.registry.unregister(tenant_id)

    # -- ingress -------------------------------------------------------------
    def submit(self, tenant_id: str, input_name: str, u, v=None) -> str:
        """Admit one update ``input ± u vᵀ`` into a tenant's log.

        ``u`` may be a :class:`~repro.core.factored.DeltaCarrier`
        (``v`` omitted): the log stores the carrier as-is, so a
        row-local update replays through the row-slab trigger a crash
        replay included, and a **no-op carrier is acknowledged without
        ever entering the log** — nothing to fire, prune, or replay,
        and it can never trip the overload tiers.

        Chaos poisoning happens HERE, before the log append, so the log
        stores the poisoned values and a crash-replay re-fires exactly
        what the first attempt saw.  Returns the admission decision
        (``"admitted"``/``"throttled"``/``"queue_full"``/``"shed"``).
        """
        from repro.core.factored import (DeltaCarrier, LowRankCarrier,
                                         RowLocalCarrier, as_carrier)
        carrier = None
        if isinstance(u, DeltaCarrier) or v is None:
            carrier = as_carrier(u, v)
        tenant = self.registry.get(tenant_id)
        if input_name not in tenant.engine.compiled.triggers:
            raise KeyError(
                f"no trigger for input {input_name!r} in tenant "
                f"{tenant_id!r}; have "
                f"{sorted(tenant.engine.compiled.triggers)}")
        tenant.stats.submitted += 1
        if carrier is not None and carrier.kind == "noop":
            # nothing will ever move: ack before admission — a no-op
            # consumes no queue slot, so throttling/shedding it is
            # meaningless (and a storm of them must not degrade anyone)
            tenant.stats.noop_skips += 1
            tenant.stats.count(ADMITTED)
            return ADMITTED
        if self.chaos is not None:
            if carrier is None:
                u, v = self.chaos.poison_update(u, v)
            elif carrier.kind == "row_local":
                Bp, Vp = self.chaos.poison_update(carrier.block, carrier.V)
                carrier = RowLocalCarrier(carrier.rows,
                                          np.asarray(Bp, np.float32),
                                          np.asarray(Vp, np.float32),
                                          carrier.n)
            else:
                Pp, Qp = self.chaos.poison_update(*carrier.factors())
                carrier = LowRankCarrier(np.asarray(Pp, np.float32),
                                         np.asarray(Qp, np.float32))
        tier = self.tier()
        decision = self.admission.admit(tenant, tier)
        tenant.stats.count(decision)
        if decision == ADMITTED:
            tenant.log.append(input_name, u, v, self._clock(),
                              carrier=carrier)
            with self._load_lock:
                self._pending_total += 1
            tier = self.tier()  # the append may have tipped it
        self._apply_tier(tier)
        return decision

    # -- egress --------------------------------------------------------------
    def read(self, tenant_id: str, name: Optional[str] = None):
        """Serve one view from the tenant's committed snapshot.

        Never touches mid-claim engine state (reads are isolated from
        workers); a degraded (re-eval-on-read) tenant gets its pending
        deltas folded in first, under the same lease protocol workers
        use."""
        tenant = self.registry.get(tenant_id)
        tenant.last_read_at = self._clock()
        tenant.stats.reads += 1
        if tenant.mode == "reeval_on_read" and tenant.dirty():
            self._claim_and_fire(tenant, "reader", reeval=True)
        if tenant.dirty():
            tenant.stats.dirty_reads += 1
        name = name or tenant.engine.program.output_names()[0]
        return tenant.committed_views[name]

    def read_views(self, tenant_id: str) -> Dict[str, object]:
        tenant = self.registry.get(tenant_id)
        tenant.last_read_at = self._clock()
        return dict(tenant.committed_views)

    # -- overload tiers ------------------------------------------------------
    def load(self) -> float:
        with self._load_lock:
            return (self._pending_total / self._cap_total
                    if self._cap_total else 0.0)

    def tier(self) -> str:
        load = self.load()
        pol = self.config.overload
        if load >= pol.shedding_at:
            return "shedding"
        if load >= pol.degraded_at:
            return "degraded"
        return "normal"

    def _apply_tier(self, tier: Optional[str] = None) -> None:
        """Move cold sheddable tenants to re-eval-on-read under
        pressure; restore everyone once the fleet cools down."""
        if tier is None:
            tier = self.tier()
        if tier == "normal" and not self._any_degraded:
            return  # hot path: nothing to demote, nothing to restore
        now = self._clock()
        any_degraded = False
        for t in self.registry:
            if tier == "normal":
                t.mode = "incremental"
            elif (t.spec.sheddable
                    and now - t.last_read_at
                    >= self.config.overload.cold_after_s):
                t.mode = "reeval_on_read"
            any_degraded = any_degraded or t.mode != "incremental"
        self._any_degraded = any_degraded

    # -- SLO-aware priority ---------------------------------------------------
    def _pending_ranks(self, tenant: Tenant
                       ) -> Dict[str, Tuple[int, float]]:
        """Per pending input: (stacked rank, affected fraction).  The
        fraction is the summed row containment of the pending carriers
        clamped at 1.0 — a queue of row-local updates prices at the
        row-slab sweep, and one dense entry drops the whole input back
        to full price (dense entries report fraction 1.0)."""
        acc: Dict[str, Tuple[int, float]] = {}
        for e in tenant.log.pending(tenant.applied_lsn):
            k, f = acc.get(e.input_name, (0, 0.0))
            acc[e.input_name] = (k + e.rank,
                                 min(1.0, f + e.affected_fraction()))
        return acc

    def priority(self, tenant: Tenant) -> float:
        """``spec.priority × SLO-pressure / firing cost`` — cheap overdue
        work first.  Overdue tenants (pressure ≥ 1) are boosted above
        every on-time tenant regardless of cost.  Higher-order tenants
        (deferred-cascade views) are priced at their amortized fold
        share, not a full per-firing sweep — otherwise depth-k tenants
        would look exactly ``fold_window**(k-1)``× more expensive than
        they are and starve behind first-order neighbors."""
        pressure = tenant.slo_pressure()
        cost = 1.0
        eng = tenant.engine
        orders = {n: o
                  for n, o in (getattr(eng, "_view_orders", None) or
                               {}).items() if o > 1} or None
        order_sig = (tuple(sorted(orders.items())) if orders else ())
        for input_name, (rank, frac) in self._pending_ranks(tenant).items():
            rank = min(rank, tenant.spec.max_claim_rank)
            # quantize the fraction so the memo stays finite; dense
            # pending work (frac == 1.0) prices with fraction=None —
            # identical to the pre-carrier key, so the memo carries over
            fq = round(min(1.0, max(frac, 1e-4)), 4)
            frac_arg = None if fq >= 1.0 else fq
            key = (tenant.spec.tenant_id, input_name, rank, order_sig,
                   fq)
            c = self._cost_memo.get(key)
            if c is None:
                c = firing_cost_flops(eng.compiled, eng.binding,
                                      input_name, rank,
                                      view_orders=orders,
                                      affected_fraction=frac_arg)
                self._cost_memo[key] = c
            cost += c
        score = tenant.spec.priority * max(pressure, 1e-6) / cost
        if pressure >= 1.0:
            score += tenant.spec.priority * 1e9
        return score

    def _claimable(self) -> List[Tenant]:
        out = [t for t in self.registry
               if t.dirty() and t.mode == "incremental"
               and t.breaker.state != "open"]
        out.sort(key=self.priority, reverse=True)
        return out

    # -- the claim protocol ---------------------------------------------------
    def run_claim(self, worker_id: str) -> str:
        """One worker, one claim cycle.  Returns what happened:
        ``"idle"`` (nothing claimable), ``"committed"``,
        ``"quarantined"`` (all firings guard-aborted; log still
        advanced, breaker fed), or ``"fenced"`` (lost the lease,
        rolled own work back).  Raises :class:`WorkerCrashed` when
        chaos kills the worker mid-claim — the lease and the tenant's
        inflight record are deliberately left behind."""
        for tenant in self._claimable():
            if (tenant.breaker.state == "half_open"
                    and not tenant.breaker.allow()):
                continue  # someone else holds the probe
            lease = self.leases.claim(tenant.spec.tenant_id, worker_id)
            if lease is None:
                continue  # raced another worker; try the next tenant
            return self._fire_claim(tenant, lease)
        return "idle"

    def _claim_and_fire(self, tenant: Tenant, worker_id: str,
                        reeval: bool = False) -> str:
        lease = self.leases.claim(tenant.spec.tenant_id, worker_id)
        if lease is None:
            return "idle"
        return self._fire_claim(tenant, lease, reeval=reeval)

    def _claim_entries(self, tenant: Tenant
                       ) -> Tuple[List[Tuple[str, List[LogEntry]]], int]:
        """Pending entries for one claim, grouped into consecutive
        same-input runs (log order is preserved — firings on different
        inputs do not commute through nonlinear views), capped at
        ``max_claim_rank`` total stacked rank."""
        groups: List[Tuple[str, List[LogEntry]]] = []
        total = 0
        target = tenant.applied_lsn
        for e in tenant.log.pending(tenant.applied_lsn):
            k = e.rank
            if total and total + k > tenant.spec.max_claim_rank:
                break
            if groups and groups[-1][0] == e.input_name:
                groups[-1][1].append(e)
            else:
                groups.append((e.input_name, [e]))
            total += k
            target = e.lsn
        return groups, target

    def _fire_claim(self, tenant: Tenant, lease, reeval: bool = False
                    ) -> str:
        with tenant.mutex:
            if self.chaos is not None:
                delay = self.chaos.slow_worker_delay()
                if delay > 0.0:
                    self._sleep(delay)  # real TTLs expire under this
            engine = tenant.engine
            # a dead worker's uncommitted claim? roll it back first —
            # the restore is bit-identical (same buffers), then we
            # replay the same log entries it saw
            if (tenant.inflight is not None
                    and tenant.inflight.token != lease.token):
                restore_snapshot(engine, tenant.inflight.snapshot)
                tenant.inflight = None
                tenant.stats.replays += 1
            if reeval:
                groups, target = [], tenant.log.last_lsn()
                entries = tenant.log.pending(tenant.applied_lsn)
            else:
                groups, target = self._claim_entries(tenant)
                entries = []
            if target <= tenant.applied_lsn:
                self.leases.release(lease)
                return "idle"
            snap = take_snapshot(engine)
            tenant.inflight = Inflight(lease.token, target, snap)
            guard = engine.guard
            aborted_before = (guard.stats.aborted_firings
                              if guard is not None else 0)
            committed_groups: List[Tuple[str, Tuple[int, ...]]] = []
            if reeval:
                # cold-tier path: fold the raw deltas into the inputs,
                # re-evaluate once — no trigger sweeps
                for e in entries:
                    engine.views[e.input_name] = (
                        engine.views[e.input_name] + e.dense_delta())
                engine.reevaluate()
                tenant.stats.reeval_on_read += 1
                committed_groups.append(
                    ("<reeval>", tuple(e.lsn for e in entries)))
            else:
                for input_name, group in groups:
                    before = dict(engine.views)
                    engine.apply_updates(
                        input_name, [e.payload() for e in group])
                    if self.chaos is not None \
                            and self.chaos.should_crash_worker():
                        self.worker_crashes += 1
                        raise WorkerCrashed(
                            f"chaos killed worker mid-claim on "
                            f"{tenant.spec.tenant_id!r}")
                    if any(before.get(k) is not val
                           for k, val in engine.views.items()):
                        committed_groups.append(
                            (input_name, tuple(e.lsn for e in group)))
            if guard is not None:
                guard.sync()   # settle deferred fast-path accounting
            if self.chaos is not None and self.chaos.should_expire_lease():
                self.leases.break_lease(tenant.spec.tenant_id)
            # -- commit point --------------------------------------------------
            if not self.leases.is_current(lease):
                # fenced: someone may already be replaying — undo our
                # work (bit-identical) and walk away
                restore_snapshot(engine, snap)
                tenant.inflight = None
                tenant.stats.fenced_aborts += 1
                return "fenced"
            n_updates = (len(entries) if reeval
                         else sum(len(g) for _, g in groups))
            tenant.applied_lsn = target
            pruned = tenant.log.prune(target)
            with self._load_lock:
                self._pending_total -= pruned
            tenant.committed_views = dict(engine.views)
            tenant.commit_log.extend(committed_groups)
            tenant.inflight = None
            tenant.stats.commits += 1
            tenant.stats.committed_updates += n_updates
            self.leases.release(lease)
            aborted = ((guard.stats.aborted_firings - aborted_before)
                       if guard is not None else 0)
            if aborted and not committed_groups:
                # every firing in the claim was aborted+quarantined —
                # this tenant is hurting workers for zero progress
                tenant.breaker.record_failure()
                tenant.stats.aborted_claims += 1
                return "quarantined"
            tenant.breaker.record_success()
            return "committed"

    # -- deterministic drive ---------------------------------------------------
    def run_until_idle(self, workers: int = 2, max_passes: int = 10_000,
                       on_stall: Optional[Callable[[], None]] = None
                       ) -> Dict[str, int]:
        """Round-robin ``workers`` virtual workers until no tenant is
        claimably dirty.  Worker crashes are absorbed (the "worker" is
        reincarnated next pass).  ``on_stall`` runs when a full pass
        makes no progress — with a virtual clock, advance it past the
        lease TTL there; with the real clock the default waits it out.
        """
        outcomes: Dict[str, int] = {}
        for _ in range(max_passes):
            self._apply_tier()
            if not self._claimable():
                # clean, degraded-to-read, or breaker-quarantined
                # tenants only — nothing a worker may touch right now
                return outcomes
            progress = False
            for w in range(workers):
                try:
                    res = self.run_claim(f"w{w}")
                except WorkerCrashed:
                    res = "crashed"
                outcomes[res] = outcomes.get(res, 0) + 1
                if res not in ("idle",):
                    progress = True
            if not progress:
                if on_stall is not None:
                    on_stall()
                else:
                    self._sleep(self.config.lease_ttl / 4)
        raise RuntimeError(
            f"run_until_idle made no headway in {max_passes} passes; "
            f"outcomes so far: {outcomes}")

    def drain(self, tenant_ids=None, timeout_s: float = 60.0) -> None:
        """Block until the given tenants (default: all) are clean.

        With live worker threads running, waits on them; otherwise
        drives claims inline.  Degraded (re-eval-on-read) tenants are
        folded directly.  Raises ``TimeoutError`` if live workers make
        no headway in ``timeout_s``."""
        ids = (list(tenant_ids) if tenant_ids is not None
               else self.registry.ids())
        tenants = [self.registry.get(t) for t in ids]
        for t in tenants:
            if t.mode == "reeval_on_read" and t.dirty():
                self._claim_and_fire(t, "drain", reeval=True)
        if not self._threads:
            self.run_until_idle()
            return
        t0 = self._clock()
        while any(t.dirty() and t.mode == "incremental" for t in tenants):
            if self._clock() - t0 > timeout_s:
                raise TimeoutError(
                    f"fleet drain of {ids} stalled after {timeout_s}s; "
                    f"health: {[t.health() for t in tenants]}")
            self._sleep(self.config.idle_sleep_s)

    # -- live drive ------------------------------------------------------------
    def start(self, workers: Optional[int] = None) -> None:
        """Spawn the worker threads (idempotent while running)."""
        if self._threads:
            return
        self._stop.clear()
        for i in range(workers or self.config.workers):
            t = threading.Thread(target=self._worker_loop,
                                 args=(f"worker-{i}",),
                                 name=f"fleet-worker-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=30.0)
        self._threads = []

    def _worker_loop(self, worker_id: str) -> None:
        incarnation = 0
        while not self._stop.is_set():
            try:
                res = self.run_claim(f"{worker_id}.{incarnation}")
            except WorkerCrashed:
                incarnation += 1   # the old worker is gone; a new one
                continue           # (fresh id) picks up the pieces
            except Exception:
                incarnation += 1   # never let one tenant kill the pool
                continue
            if res == "idle":
                self._sleep(self.config.idle_sleep_s)
            self._apply_tier()

    # -- introspection ---------------------------------------------------------
    def tenant_health(self) -> List[Dict[str, object]]:
        return [t.health() for t in self.registry]

    def fleet_stats(self) -> Dict[str, object]:
        tenants = list(self.registry)
        stats: Dict[str, object] = {
            "tenants": len(tenants),
            "tier": self.tier(),
            "load": self.load(),
            "leases": self.leases.stats(),
            "trigger_cache": self.registry.trigger_cache.stats(),
            "worker_crashes": self.worker_crashes,
            "commits": sum(t.stats.commits for t in tenants),
            "committed_updates": sum(t.stats.committed_updates
                                     for t in tenants),
            "replays": sum(t.stats.replays for t in tenants),
            "fenced_aborts": sum(t.stats.fenced_aborts for t in tenants),
            "decisions": {},
        }
        decisions: Dict[str, int] = stats["decisions"]
        for t in tenants:
            for k, n in t.stats.decisions.items():
                decisions[k] = decisions.get(k, 0) + n
        if self.chaos is not None:
            stats["chaos"] = {
                "poisoned": self.chaos.poisoned,
                "lease_expiries": self.chaos.lease_expiries,
                "slowdowns": self.chaos.slowdowns,
            }
        return stats
