"""repro.fleet — fault-tolerant multi-tenant view service.

N tenants (each a program + :class:`~repro.core.runtime.IncrementalEngine`
+ staleness SLO) share a pool of refresh workers coordinated purely by
TTL **leases with fencing tokens** — no leader, no failure detector.
A worker claims a tenant's dirty log prefix, fires it through the
guard/transaction path, and commits only if its lease is still current;
crashed or fenced claims are rolled back (bit-identically) and replayed
from the tenant's update log, so every admitted update is reflected in
the committed store **exactly once**.

Around that core: token-bucket admission with bounded per-tenant logs,
noisy-neighbor quarantine (per-tenant circuit breakers over the guard's
abort accounting), SLO-×-cost scheduling priority, explicit overload
tiers (degrade cold tenants to re-eval-on-read, shed under saturation),
and a shared cross-tenant compiled-trigger cache.  See docs/fleet.md.

    from repro.fleet import FleetScheduler, FleetConfig, TenantSpec

    fleet = FleetScheduler(FleetConfig(lease_ttl=0.5))
    fleet.add_tenant(TenantSpec("acme", program, {"u": 1}, slo_s=0.2),
                     inputs)
    fleet.submit("acme", "u", du, dv)
    fleet.run_until_idle()          # or fleet.start() for live threads
    fresh = fleet.read("acme")
"""

from .admission import (ADMITTED, DECISIONS, QUEUE_FULL, SHED, THROTTLED,
                        AdmissionController, TokenBucket)
from .lease import Lease, LeaseStore
from .scheduler import (FleetConfig, FleetScheduler, OverloadPolicy,
                        WorkerCrashed)
from .tenant import (Inflight, LogEntry, Tenant, TenantRegistry, TenantSpec,
                     TenantStats, UpdateLog)

__all__ = [
    "FleetScheduler", "FleetConfig", "OverloadPolicy", "WorkerCrashed",
    "TenantSpec", "Tenant", "TenantRegistry", "TenantStats",
    "UpdateLog", "LogEntry", "Inflight",
    "LeaseStore", "Lease",
    "AdmissionController", "TokenBucket",
    "ADMITTED", "THROTTLED", "QUEUE_FULL", "SHED", "DECISIONS",
]
