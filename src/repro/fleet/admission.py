"""Admission control: per-tenant quotas + bounded queues + load shedding.

The fleet's front door applies three gates, in order, before an update
may enter a tenant's log:

  1. **load shedding** — under the ``"shedding"`` overload tier,
     sheddable tenants' updates are refused outright (:data:`SHED`);
     reserved-capacity tenants (``sheddable=False``) pass;
  2. **token-bucket quota** — each tenant refills at ``quota_rate``
     updates/s up to ``quota_burst``; a noisy producer is throttled
     (:data:`THROTTLED`) before it can monopolize worker time;
  3. **bounded log** — a tenant whose pending (unapplied) log is full
     gets :data:`QUEUE_FULL` back-pressure instead of unbounded memory
     growth.  Rejection is the contract: the producer retries, the
     fleet never OOMs on behalf of its slowest tenant.

All decisions are returned as strings so callers (and tests) can
histogram them; nothing here raises on a refused update.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict

ADMITTED = "admitted"
THROTTLED = "throttled"      # token bucket empty — retry later
QUEUE_FULL = "queue_full"    # pending log at capacity — back-pressure
SHED = "shed"                # overload tier sheds this tenant's traffic

DECISIONS = (ADMITTED, THROTTLED, QUEUE_FULL, SHED)


class TokenBucket:
    """Classic token bucket with an injectable clock (thread-safe).

    ``rate`` is tokens/second (``float("inf")`` = unmetered), ``burst``
    the bucket depth.  The bucket starts full so a fresh tenant can
    burst immediately.
    """

    def __init__(self, rate: float, burst: int,
                 clock: Callable[[], float] = time.monotonic):
        if burst < 1:
            raise ValueError(f"burst must be ≥ 1, got {burst}")
        self.rate = float(rate)
        self.burst = int(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock()
        self._lock = threading.Lock()

    def allow(self, n: int = 1) -> bool:
        """Consume ``n`` tokens if available."""
        if self.rate == float("inf"):
            return True
        with self._lock:
            now = self._clock()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False

    def available(self) -> float:
        with self._lock:
            now = self._clock()
            return min(self.burst,
                       self._tokens + (now - self._last) * self.rate)


class AdmissionController:
    """Per-tenant buckets + the tier-aware admission decision."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}

    def register(self, spec) -> None:
        self._buckets[spec.tenant_id] = TokenBucket(
            spec.quota_rate, spec.quota_burst, self._clock)

    def unregister(self, tenant_id: str) -> None:
        self._buckets.pop(tenant_id, None)

    def admit(self, tenant, tier: str, n: int = 1) -> str:
        """Decide one submission of ``n`` logical updates for ``tenant``
        (a :class:`repro.fleet.tenant.Tenant`) under overload ``tier``.
        Order matters: shedding is checked first (no quota tokens are
        burned on traffic the tier refuses anyway), then quota, then
        queue capacity."""
        spec = tenant.spec
        if tier == "shedding" and spec.sheddable:
            return SHED
        bucket = self._buckets.get(spec.tenant_id)
        if bucket is not None and not bucket.allow(n):
            return THROTTLED
        if tenant.log.pending_count(tenant.applied_lsn) + n \
                > spec.queue_capacity:
            return QUEUE_FULL
        return ADMITTED

    def available(self, tenant_id: str) -> float:
        bucket = self._buckets.get(tenant_id)
        return float("inf") if bucket is None else bucket.available()
