"""Tenants: one program + engine + SLO per customer, plus the update
log that makes worker crashes survivable.

A tenant owns everything the fleet must never mix across customers: an
:class:`~repro.core.runtime.IncrementalEngine` (guarded, wired to the
fleet's shared :class:`~repro.plan.TriggerCache`), a durable-ordered
:class:`UpdateLog` of admitted updates, the **committed view store**
reads are served from, and a per-tenant
:class:`~repro.guard.CircuitBreaker` for noisy-neighbor quarantine.

The split between ``engine.views`` (working state, mutated mid-claim)
and ``committed_views`` (a pointer snapshot advanced only at commit) is
what gives readers isolation for free: jax arrays are immutable, so a
reader holding the committed dict sees a consistent pre-claim store no
matter what a worker is doing to the engine concurrently.

Exactly-once accounting lives in three fields: ``applied_lsn`` (the
log prefix reflected in ``committed_views``), ``inflight`` (the claim
currently trying to advance it, with its pre-firing snapshot), and
``commit_log`` (the sequence of committed firing groups — the replay
script the bit-identical property test checks against).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.runtime import IncrementalEngine
from repro.guard import CircuitBreaker, GuardConfig
from repro.guard.txn import FiringSnapshot
from repro.plan import TriggerCache


@dataclass
class TenantSpec:
    """Static per-tenant contract: program, SLO, quotas, containment."""

    tenant_id: str
    program: object                 # repro.core.ir.Program
    update_ranks: Optional[Dict[str, int]] = None
    slo_s: float = 1.0              # staleness SLO (dirty → refreshed)
    priority: float = 1.0           # scheduler weight (higher = sooner)
    sheddable: bool = True          # may the shedding tier drop it?
    quota_rate: float = float("inf")  # admitted updates/second
    quota_burst: int = 64
    queue_capacity: int = 256       # max pending (unapplied) log entries
    max_claim_rank: int = 64        # stacked rank one claim fires at most
    guarded: bool = True            # wrap the engine in repro.guard
    chaos: Optional[object] = None  # ChaosConfig/ChaosMonkey for the engine
    breaker_threshold: int = 3      # aborted claims → quarantined
    breaker_reset_s: float = 5.0
    engine_opts: Dict[str, object] = field(default_factory=dict)


@dataclass
class LogEntry:
    """One admitted update, totally ordered by per-tenant LSN.

    Either a raw ``(u, v)`` factor pair, or a
    :class:`~repro.core.factored.DeltaCarrier` (``carrier`` set, ``u`` /
    ``v`` ``None``) — the log stores whichever form was submitted, so a
    crash replay re-fires the *same representation* the first attempt
    saw (a row-local carrier replays through the row-slab trigger, not
    a widened dense sweep — bit-identity demands the same code path)."""

    lsn: int
    input_name: str
    u: Optional[np.ndarray]
    v: Optional[np.ndarray]
    submitted_at: float
    carrier: Optional[object] = None

    @property
    def rank(self) -> int:
        """Stacked-rank contribution of this entry (claim capping)."""
        if self.carrier is not None:
            return max(1, int(self.carrier.rank))
        return self.u.shape[1] if self.u.ndim == 2 else 1

    def affected_fraction(self) -> float:
        return (self.carrier.affected_fraction()
                if self.carrier is not None else 1.0)

    def payload(self):
        """What the engine applies: the carrier, or the raw pair."""
        return self.carrier if self.carrier is not None else (self.u, self.v)

    def dense_delta(self) -> np.ndarray:
        """``ΔA`` as a dense array (cold-tier reeval-on-read fold)."""
        if self.carrier is not None:
            P, Q = self.carrier.factors()
            return P @ Q.T
        return (self.u @ self.v.T if self.u.ndim == 2
                else np.outer(self.u, self.v))


class UpdateLog:
    """Append-only per-tenant update log (thread-safe).

    The log *is* the recovery story: a worker's uncommitted firing dies
    with its lease, and the reclaimer replays the same entries —
    ``pending(applied_lsn)`` — against the rolled-back store.  Entries
    are pruned only once a commit advances ``applied_lsn`` past them.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: List[LogEntry] = []
        self._next_lsn = 1
        self.appended = 0
        self.pruned = 0

    def append(self, input_name: str, u, v, now: float,
               carrier=None) -> LogEntry:
        with self._lock:
            if carrier is not None:
                entry = LogEntry(self._next_lsn, input_name, None, None,
                                 now, carrier=carrier)
            else:
                entry = LogEntry(self._next_lsn, input_name,
                                 np.asarray(u, dtype=np.float32),
                                 np.asarray(v, dtype=np.float32), now)
            self._next_lsn += 1
            self._entries.append(entry)
            self.appended += 1
            return entry

    def _first_pending(self, applied_lsn: int) -> int:
        """Index of the first entry with ``lsn > applied_lsn`` (lock
        held).  LSNs are consecutive and prune only drops a prefix, so
        this is index arithmetic, not a scan — ``pending_count`` sits on
        every admission decision and fleet load() probe."""
        if not self._entries:
            return 0
        return min(len(self._entries),
                   max(0, applied_lsn - self._entries[0].lsn + 1))

    def pending(self, applied_lsn: int) -> List[LogEntry]:
        """Entries not yet reflected in the committed store, in LSN
        order."""
        with self._lock:
            return self._entries[self._first_pending(applied_lsn):]

    def pending_count(self, applied_lsn: int) -> int:
        with self._lock:
            return len(self._entries) - self._first_pending(applied_lsn)

    def last_lsn(self) -> int:
        with self._lock:
            return self._next_lsn - 1

    def oldest_pending_at(self, applied_lsn: int) -> Optional[float]:
        with self._lock:
            i = self._first_pending(applied_lsn)
            return self._entries[i].submitted_at \
                if i < len(self._entries) else None

    def prune(self, upto_lsn: int) -> int:
        """Drop entries with ``lsn <= upto_lsn`` (they are committed)."""
        with self._lock:
            keep = [e for e in self._entries if e.lsn > upto_lsn]
            n = len(self._entries) - len(keep)
            self._entries = keep
            self.pruned += n
            return n

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


@dataclass
class Inflight:
    """The claim currently mutating a tenant's engine: its fencing
    token, the log prefix it is trying to commit, and the pre-firing
    snapshot a reclaimer restores if the holder dies."""

    token: int
    target_lsn: int
    snapshot: FiringSnapshot


@dataclass
class TenantStats:
    submitted: int = 0
    decisions: Dict[str, int] = field(default_factory=dict)
    commits: int = 0
    committed_updates: int = 0
    replays: int = 0            # claims that rolled back a dead worker
    fenced_aborts: int = 0      # own commit rejected by fencing check
    aborted_claims: int = 0     # guard aborted every firing in a claim
    reads: int = 0
    dirty_reads: int = 0        # reads served while pending work existed
    reeval_on_read: int = 0     # cold-tier degraded refreshes
    noop_skips: int = 0         # no-op carriers acked without logging

    def count(self, decision: str) -> None:
        self.decisions[decision] = self.decisions.get(decision, 0) + 1


class Tenant:
    """Runtime state for one tenant (see module docstring)."""

    def __init__(self, spec: TenantSpec, trigger_cache: TriggerCache,
                 clock=time.monotonic):
        self.spec = spec
        self._clock = clock
        opts = dict(spec.engine_opts)
        opts.setdefault("guard", GuardConfig() if spec.guarded else None)
        opts.setdefault("chaos", spec.chaos)
        self.engine = IncrementalEngine(
            spec.program, spec.update_ranks,
            trigger_cache=trigger_cache, **opts)
        self.log = UpdateLog()
        self.applied_lsn = 0
        self.committed_views: Dict[str, object] = {}
        self.inflight: Optional[Inflight] = None
        self.breaker = CircuitBreaker(spec.breaker_threshold,
                                      spec.breaker_reset_s, clock=clock)
        self.mutex = threading.RLock()   # serializes engine access
        self.stats = TenantStats()
        self.mode = "incremental"        # or "reeval_on_read" (cold tier)
        self.last_read_at = clock()      # cold-tenant detection (overload)
        #: committed firing groups, in commit order:
        #: (input_name, (lsn, …)) per group — the replay script for the
        #: bit-identical N-isolated-engines property test
        self.commit_log: List[Tuple[str, Tuple[int, ...]]] = []

    def initialize(self, inputs: Dict[str, object]) -> None:
        with self.mutex:
            self.engine.initialize(inputs)
            self.committed_views = dict(self.engine.views)

    # -- dirtiness / staleness ----------------------------------------------
    def dirty(self) -> bool:
        return self.log.last_lsn() > self.applied_lsn

    def staleness(self) -> float:
        """Seconds the oldest unapplied update has been waiting (0.0
        when clean) — the quantity the SLO bounds."""
        oldest = self.log.oldest_pending_at(self.applied_lsn)
        return 0.0 if oldest is None else max(0.0, self._clock() - oldest)

    def slo_pressure(self) -> float:
        """staleness / SLO — ≥ 1.0 means the SLO is already violated."""
        return self.staleness() / max(self.spec.slo_s, 1e-9)

    # -- health --------------------------------------------------------------
    def health(self) -> Dict[str, object]:
        guard = self.engine.guard
        return {
            "tenant": self.spec.tenant_id,
            "mode": self.mode,
            "breaker": self.breaker.state,
            "dirty": self.dirty(),
            "pending": self.log.pending_count(self.applied_lsn),
            "applied_lsn": self.applied_lsn,
            "staleness_s": self.staleness(),
            "slo_s": self.spec.slo_s,
            "commits": self.stats.commits,
            "replays": self.stats.replays,
            "quarantined": (len(guard.quarantine) if guard is not None
                            else 0),
        }


class TenantRegistry:
    """All tenants of one fleet + the shared compiled-trigger cache.

    The cache is THE cross-tenant fast path: same-program tenants key
    to identical (fingerprint, backend, tail) entries, so the second
    tenant's triggers come back pre-jitted (benchmarks/bench_fleet.py
    measures the aggregate win).
    """

    def __init__(self, trigger_cache: Optional[TriggerCache] = None,
                 clock=time.monotonic):
        self.trigger_cache = (trigger_cache if trigger_cache is not None
                              else TriggerCache())
        self._clock = clock
        self._tenants: Dict[str, Tenant] = {}
        self._lock = threading.Lock()

    def register(self, spec: TenantSpec,
                 inputs: Dict[str, object]) -> Tenant:
        with self._lock:
            if spec.tenant_id in self._tenants:
                raise ValueError(f"tenant {spec.tenant_id!r} already "
                                 f"registered")
        tenant = Tenant(spec, self.trigger_cache, clock=self._clock)
        tenant.initialize(inputs)
        with self._lock:
            self._tenants[spec.tenant_id] = tenant
        return tenant

    def unregister(self, tenant_id: str) -> Optional[Tenant]:
        with self._lock:
            return self._tenants.pop(tenant_id, None)

    def get(self, tenant_id: str) -> Tenant:
        with self._lock:
            try:
                return self._tenants[tenant_id]
            except KeyError:
                raise KeyError(f"unknown tenant {tenant_id!r}; have "
                               f"{sorted(self._tenants)}") from None

    def __iter__(self):
        with self._lock:
            return iter(list(self._tenants.values()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._tenants)

    def ids(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)
