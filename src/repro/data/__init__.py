"""Data substrate: deterministic shard-aware synthetic pipelines and the
update-stream generators used by the IVM benchmarks."""

from .pipeline import TokenPipeline, make_batch_specs, synth_batch
from .updates import UpdateStream, zipf_row_stream

__all__ = ["TokenPipeline", "make_batch_specs", "synth_batch",
           "UpdateStream", "zipf_row_stream"]
