"""Data substrate: deterministic shard-aware synthetic pipelines and the
update-stream generators used by the IVM benchmarks."""

from .pipeline import TokenPipeline, make_batch_specs, synth_batch
from .updates import (LabeledStream, LabeledUpdate, RowLocalStream,
                      UpdateStream, labeled_stream, row_local_stream,
                      zipf_row_stream)

__all__ = ["TokenPipeline", "make_batch_specs", "synth_batch",
           "UpdateStream", "RowLocalStream", "row_local_stream",
           "zipf_row_stream", "LabeledStream", "LabeledUpdate",
           "labeled_stream"]
