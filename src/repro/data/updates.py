"""Update-stream generators for the IVM workloads (paper §7).

The paper's experiments drive a continuous stream of rank-1 row updates;
Table 4 additionally skews *which* rows change using a Zipf distribution.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class UpdateStream:
    """Stream of (u, v) factored updates to an (n × m) input matrix."""

    n: int
    m: int
    rank: int = 1
    scale: float = 0.1
    seed: int = 0
    zipf: Optional[float] = None     # row-selection skew (None = uniform)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        while True:
            yield self.next_update(rng)

    def next_update(self, rng) -> Tuple[np.ndarray, np.ndarray]:
        u = np.zeros((self.n, self.rank), dtype=np.float32)
        rows = self._rows(rng, self.rank)
        u[rows, np.arange(self.rank)] = 1.0
        v = (self.scale * rng.normal(size=(self.m, self.rank))
             ).astype(np.float32)
        return u, v

    def _rows(self, rng, k: int) -> np.ndarray:
        if self.zipf is None or self.zipf <= 0:
            return rng.integers(0, self.n, size=k)
        # Zipf over row indices, clipped into range (Table 4 workload)
        r = rng.zipf(max(self.zipf, 1.01), size=k)
        return np.minimum(r - 1, self.n - 1)

    def batch(self, count: int) -> Tuple[np.ndarray, np.ndarray]:
        """A batch of ``count`` rank-1 updates merged into rank-`count`
        factors (the paper's batch-update experiment)."""
        rng = np.random.default_rng(self.seed)
        us, vs = [], []
        for _ in range(count):
            u, v = self.next_update(rng)
            us.append(u)
            vs.append(v)
        return np.concatenate(us, axis=1), np.concatenate(vs, axis=1)


def zipf_row_stream(n: int, m: int, zipf_factor: float, seed: int = 0
                    ) -> UpdateStream:
    return UpdateStream(n=n, m=m, zipf=zipf_factor, seed=seed)
