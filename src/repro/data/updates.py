"""Update-stream generators for the IVM workloads (paper §7).

The paper's experiments drive a continuous stream of rank-1 row updates;
Table 4 additionally skews *which* rows change using a Zipf distribution.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class UpdateStream:
    """Stream of (u, v) factored updates to an (n × m) input matrix.

    One stream owns ONE generator state, lazily seeded from ``seed``:
    every draw — iteration or :meth:`batch` — advances it, so
    consecutive ``batch()`` calls produce *different* updates (the old
    behavior re-seeded per call, silently replaying the same batch
    forever).  For a bit-identical replay (e.g. timing incremental vs
    re-evaluation on the same stream) either call :meth:`reset` or
    construct a second stream with the same seed.
    """

    n: int
    m: int
    rank: int = 1
    scale: float = 0.1
    seed: int = 0
    zipf: Optional[float] = None     # row-selection skew (None = uniform)
    _rng: Optional[np.random.Generator] = field(
        default=None, init=False, repr=False, compare=False)

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return self._rng

    def reset(self) -> None:
        """Rewind to ``seed``; the next draw replays from the start."""
        self._rng = None

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_update(self.rng)

    def next_update(self, rng=None) -> Tuple[np.ndarray, np.ndarray]:
        rng = self.rng if rng is None else rng
        u = np.zeros((self.n, self.rank), dtype=np.float32)
        rows = self._rows(rng, self.rank)
        u[rows, np.arange(self.rank)] = 1.0
        v = (self.scale * rng.normal(size=(self.m, self.rank))
             ).astype(np.float32)
        return u, v

    def _rows(self, rng, k: int) -> np.ndarray:
        if self.zipf is None or self.zipf <= 0:
            return rng.integers(0, self.n, size=k)
        # Zipf over row indices, clipped into range (Table 4 workload)
        r = rng.zipf(max(self.zipf, 1.01), size=k)
        return np.minimum(r - 1, self.n - 1)

    def batch(self, count: int) -> Tuple[np.ndarray, np.ndarray]:
        """A batch of ``count`` rank-1 updates merged into rank-`count`
        factors (the paper's batch-update experiment).  Draws from the
        stream's shared generator, advancing it past the batch."""
        us, vs = [], []
        for _ in range(count):
            u, v = self.next_update()
            us.append(u)
            vs.append(v)
        return np.concatenate(us, axis=1), np.concatenate(vs, axis=1)


@dataclass
class RowLocalStream:
    """Stream of :class:`~repro.core.factored.RowLocalCarrier` updates:
    each draw touches ``rows_touched`` distinct rows of an (n × m)
    input with a rank-``rank`` delta, carried in compact ``(rows,
    block, V)`` form — the sparsity is *declared*, not rediscovered by
    scanning a padded dense factor.

    Same generator discipline as :class:`UpdateStream`: one lazily
    seeded state, every draw advances it, :meth:`reset` rewinds, and
    two streams with the same parameters are draw-for-draw identical
    (the seeded-determinism regression in tests/test_sparse_delta.py
    pins this — replay harnesses depend on it).

    ``zipf`` skews which rows are touched (Table 4); skewed draws are
    deduplicated, so a hot-spotted draw may carry *fewer* than
    ``rows_touched`` rows — the carrier reports whatever support the
    draw actually has.
    """

    n: int
    m: int
    rows_touched: int = 1
    rank: int = 1
    scale: float = 0.1
    seed: int = 0
    zipf: Optional[float] = None
    _rng: Optional[np.random.Generator] = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        if not (1 <= self.rows_touched <= self.n):
            raise ValueError(f"rows_touched must be in [1, {self.n}], "
                             f"got {self.rows_touched}")

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return self._rng

    def reset(self) -> None:
        self._rng = None

    def __iter__(self):
        while True:
            yield self.next_carrier()

    def _draw_rows(self, rng) -> np.ndarray:
        if self.zipf is None or self.zipf <= 0:
            rows = rng.choice(self.n, size=self.rows_touched,
                              replace=False)
        else:
            r = rng.zipf(max(self.zipf, 1.01), size=self.rows_touched)
            rows = np.minimum(r - 1, self.n - 1)
        return np.unique(rows).astype(np.int32)  # sorted + deduped

    def next_carrier(self, rng=None):
        from repro.core.factored import RowLocalCarrier
        rng = self.rng if rng is None else rng
        rows = self._draw_rows(rng)
        block = (self.scale * rng.normal(size=(len(rows), self.rank))
                 ).astype(np.float32)
        v = (self.scale * rng.normal(size=(self.m, self.rank))
             ).astype(np.float32)
        return RowLocalCarrier(rows, block, v, self.n)

    def batch(self, count: int):
        """``count`` carriers stacked into one (union-support) carrier
        — dense-equivalent to applying them in sequence."""
        from repro.core.factored import stack_carriers
        return stack_carriers([self.next_carrier() for _ in range(count)])


def row_local_stream(n: int, rows_touched: int, *, m: Optional[int] = None,
                     rank: int = 1, scale: float = 0.1, seed: int = 0,
                     zipf: Optional[float] = None) -> RowLocalStream:
    """A carrier-native row-local update stream (``m`` defaults to
    ``n``, the square-input case the benchmarks drive)."""
    return RowLocalStream(n=n, m=n if m is None else m,
                          rows_touched=rows_touched, rank=rank,
                          scale=scale, seed=seed, zipf=zipf)


def zipf_row_stream(n: int, m: int, zipf_factor: float, seed: int = 0,
                    rows_touched: Optional[int] = None):
    """Table 4's skewed-row workload.  With ``rows_touched`` set the
    stream emits :class:`RowLocalCarrier` updates natively (the hot
    rows arrive *declared*); without it, the legacy padded ``(u, v)``
    pairs."""
    if rows_touched is not None:
        return row_local_stream(n, rows_touched, m=m, seed=seed,
                                zipf=zipf_factor)
    return UpdateStream(n=n, m=m, zipf=zipf_factor, seed=seed)
