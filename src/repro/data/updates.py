"""Update-stream generators for the IVM workloads (paper §7).

The paper's experiments drive a continuous stream of rank-1 row updates;
Table 4 additionally skews *which* rows change using a Zipf distribution.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class UpdateStream:
    """Stream of (u, v) factored updates to an (n × m) input matrix.

    One stream owns ONE generator state, lazily seeded from ``seed``:
    every draw — iteration or :meth:`batch` — advances it, so
    consecutive ``batch()`` calls produce *different* updates (the old
    behavior re-seeded per call, silently replaying the same batch
    forever).  For a bit-identical replay (e.g. timing incremental vs
    re-evaluation on the same stream) either call :meth:`reset` or
    construct a second stream with the same seed.
    """

    n: int
    m: int
    rank: int = 1
    scale: float = 0.1
    seed: int = 0
    zipf: Optional[float] = None     # row-selection skew (None = uniform)
    _rng: Optional[np.random.Generator] = field(
        default=None, init=False, repr=False, compare=False)

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return self._rng

    def reset(self) -> None:
        """Rewind to ``seed``; the next draw replays from the start."""
        self._rng = None

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_update(self.rng)

    def next_update(self, rng=None) -> Tuple[np.ndarray, np.ndarray]:
        rng = self.rng if rng is None else rng
        u = np.zeros((self.n, self.rank), dtype=np.float32)
        rows = self._rows(rng, self.rank)
        u[rows, np.arange(self.rank)] = 1.0
        v = (self.scale * rng.normal(size=(self.m, self.rank))
             ).astype(np.float32)
        return u, v

    def _rows(self, rng, k: int) -> np.ndarray:
        if self.zipf is None or self.zipf <= 0:
            return rng.integers(0, self.n, size=k)
        # Zipf over row indices, clipped into range (Table 4 workload)
        r = rng.zipf(max(self.zipf, 1.01), size=k)
        return np.minimum(r - 1, self.n - 1)

    def batch(self, count: int) -> Tuple[np.ndarray, np.ndarray]:
        """A batch of ``count`` rank-1 updates merged into rank-`count`
        factors (the paper's batch-update experiment).  Draws from the
        stream's shared generator, advancing it past the batch."""
        us, vs = [], []
        for _ in range(count):
            u, v = self.next_update()
            us.append(u)
            vs.append(v)
        return np.concatenate(us, axis=1), np.concatenate(vs, axis=1)


@dataclass
class RowLocalStream:
    """Stream of :class:`~repro.core.factored.RowLocalCarrier` updates:
    each draw touches ``rows_touched`` distinct rows of an (n × m)
    input with a rank-``rank`` delta, carried in compact ``(rows,
    block, V)`` form — the sparsity is *declared*, not rediscovered by
    scanning a padded dense factor.

    Same generator discipline as :class:`UpdateStream`: one lazily
    seeded state, every draw advances it, :meth:`reset` rewinds, and
    two streams with the same parameters are draw-for-draw identical
    (the seeded-determinism regression in tests/test_sparse_delta.py
    pins this — replay harnesses depend on it).

    ``zipf`` skews which rows are touched (Table 4); skewed draws are
    deduplicated, so a hot-spotted draw may carry *fewer* than
    ``rows_touched`` rows — the carrier reports whatever support the
    draw actually has.
    """

    n: int
    m: int
    rows_touched: int = 1
    rank: int = 1
    scale: float = 0.1
    seed: int = 0
    zipf: Optional[float] = None
    _rng: Optional[np.random.Generator] = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        if not (1 <= self.rows_touched <= self.n):
            raise ValueError(f"rows_touched must be in [1, {self.n}], "
                             f"got {self.rows_touched}")

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return self._rng

    def reset(self) -> None:
        self._rng = None

    def __iter__(self):
        while True:
            yield self.next_carrier()

    def _draw_rows(self, rng) -> np.ndarray:
        if self.zipf is None or self.zipf <= 0:
            rows = rng.choice(self.n, size=self.rows_touched,
                              replace=False)
        else:
            r = rng.zipf(max(self.zipf, 1.01), size=self.rows_touched)
            rows = np.minimum(r - 1, self.n - 1)
        return np.unique(rows).astype(np.int32)  # sorted + deduped

    def next_carrier(self, rng=None):
        from repro.core.factored import RowLocalCarrier
        rng = self.rng if rng is None else rng
        rows = self._draw_rows(rng)
        block = (self.scale * rng.normal(size=(len(rows), self.rank))
                 ).astype(np.float32)
        v = (self.scale * rng.normal(size=(self.m, self.rank))
             ).astype(np.float32)
        return RowLocalCarrier(rows, block, v, self.n)

    def batch(self, count: int):
        """``count`` carriers stacked into one (union-support) carrier
        — dense-equivalent to applying them in sequence."""
        from repro.core.factored import stack_carriers
        return stack_carriers([self.next_carrier() for _ in range(count)])


@dataclass(frozen=True)
class LabeledUpdate:
    """One labeled tuple event against the F-IVM ring: an *insert* adds
    example ``(x, y)`` at row ``slot`` of the (capacity × features)
    design matrix; a *delete* is the matching negative-weight downdate
    of the **exact payload inserted earlier** (arXiv 1703.07484's
    "deletion = insertion with weight −1").  Replaying the stored
    payload, not a re-draw, is what makes insert-then-delete restore
    the ring bit-near-identically."""

    kind: str                 # "insert" | "delete"
    slot: int                 # row slot in X / Y / W
    x: np.ndarray             # (features,) float32
    y: np.ndarray             # (targets,)  float32

    @property
    def weight(self) -> float:
        return 1.0 if self.kind == "insert" else -1.0


@dataclass
class LabeledStream:
    """Mixed insert/delete stream of labeled examples for the learning
    views (repro.fivm).

    The stream owns the slot ledger: inserts claim free row slots of a
    ``capacity``-row design matrix, deletes re-emit the *stored* payload
    of a live slot with weight −1 and free it.  ``churn`` is the mix
    knob — the probability (once warm) that the next event is a delete;
    ``churn=0`` is append-only, ``churn≈0.9`` is delete-heavy.  Labels
    carry signal: ``y = xᵀ·w_true + noise`` with ``w_true`` drawn once
    from the seed, so regressions fit on the live set are non-trivial.

    Same generator discipline as :class:`UpdateStream` — one lazily
    seeded state, every draw advances it, :meth:`reset` rewinds ledger
    *and* generator, and two streams with identical parameters are
    event-for-event identical (deterministic replay)."""

    features: int
    targets: int = 1
    capacity: int = 256
    churn: float = 0.3
    scale: float = 1.0
    noise: float = 0.01
    seed: int = 0
    _rng: Optional[np.random.Generator] = field(
        default=None, init=False, repr=False, compare=False)
    _live: dict = field(default_factory=dict, init=False, repr=False,
                        compare=False)
    _free: list = field(default_factory=list, init=False, repr=False,
                        compare=False)
    _w_true: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        if not (0.0 <= self.churn < 1.0):
            raise ValueError(f"churn must be in [0, 1), got {self.churn}")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._free = list(range(self.capacity))

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return self._rng

    @property
    def w_true(self) -> np.ndarray:
        """The (features × targets) ground-truth weights behind the
        labels; drawn from ``seed + 1`` so it is stable across resets
        and independent of how many events were consumed."""
        if self._w_true is None:
            rng = np.random.default_rng(self.seed + 1)
            self._w_true = rng.normal(
                size=(self.features, self.targets)).astype(np.float32)
        return self._w_true

    @property
    def live_slots(self) -> Tuple[int, ...]:
        return tuple(sorted(self._live))

    @property
    def live_count(self) -> int:
        return len(self._live)

    def reset(self) -> None:
        """Rewind generator AND slot ledger; the next draw replays the
        stream from its first event."""
        self._rng = None
        self._live = {}
        self._free = list(range(self.capacity))

    def __iter__(self) -> Iterator[LabeledUpdate]:
        while True:
            yield self.next_event()

    def _draw_example(self, rng) -> Tuple[np.ndarray, np.ndarray]:
        x = (self.scale * rng.normal(size=self.features)).astype(np.float32)
        eps = (self.noise * rng.normal(size=self.targets)).astype(np.float32)
        y = (x @ self.w_true + eps).astype(np.float32)
        return x, y

    def next_event(self) -> LabeledUpdate:
        rng = self.rng
        want_delete = bool(self._live) and (
            not self._free or rng.random() < self.churn)
        if want_delete:
            slots = sorted(self._live)
            slot = slots[int(rng.integers(0, len(slots)))]
            x, y = self._live.pop(slot)
            self._free.append(slot)
            return LabeledUpdate("delete", slot, x, y)
        slot = self._free.pop()
        x, y = self._draw_example(rng)
        self._live[slot] = (x, y)
        return LabeledUpdate("insert", slot, x, y)

    def events(self, count: int) -> list:
        """The next ``count`` events as a list (advances the stream)."""
        return [self.next_event() for _ in range(count)]


def labeled_stream(features: int, *, targets: int = 1, capacity: int = 256,
                   churn: float = 0.3, scale: float = 1.0,
                   noise: float = 0.01, seed: int = 0) -> LabeledStream:
    """A labeled insert/delete event stream for the fivm learning views
    (churn is the delete-mix knob; deletes are stored-payload
    negative-weight downdates)."""
    return LabeledStream(features=features, targets=targets,
                         capacity=capacity, churn=churn, scale=scale,
                         noise=noise, seed=seed)


def row_local_stream(n: int, rows_touched: int, *, m: Optional[int] = None,
                     rank: int = 1, scale: float = 0.1, seed: int = 0,
                     zipf: Optional[float] = None) -> RowLocalStream:
    """A carrier-native row-local update stream (``m`` defaults to
    ``n``, the square-input case the benchmarks drive)."""
    return RowLocalStream(n=n, m=n if m is None else m,
                          rows_touched=rows_touched, rank=rank,
                          scale=scale, seed=seed, zipf=zipf)


def zipf_row_stream(n: int, m: int, zipf_factor: float, seed: int = 0,
                    rows_touched: Optional[int] = None):
    """Table 4's skewed-row workload.  With ``rows_touched`` set the
    stream emits :class:`RowLocalCarrier` updates natively (the hot
    rows arrive *declared*); without it, the legacy padded ``(u, v)``
    pairs."""
    if rows_touched is not None:
        return row_local_stream(n, rows_touched, m=m, seed=seed,
                                zipf=zipf_factor)
    return UpdateStream(n=n, m=m, zipf=zipf_factor, seed=seed)
