"""Deterministic, shard-aware synthetic data pipeline.

Design (what a real pod-scale loader must provide, minus the storage
backend, which is out of scope offline):

  * **Determinism / restart**: batch t is a pure function of (seed, step),
    so a job restarted from a step-k checkpoint regenerates exactly the
    batches k, k+1, … — no loader state to checkpoint.
  * **Shard-awareness**: each data-parallel host materializes only its
    slice (host_id, num_hosts); the global batch is the concatenation.
  * **Prefetch**: a background double-buffer thread hides generation
    latency behind the step (`TokenPipeline.__iter__`).

The token distribution is a mixture of Zipf-distributed unigrams and
short repeated motifs, which gives a non-trivial, learnable signal for
the convergence example (examples/train_lm.py).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _rng_for_step(seed: int, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step, host)))


def synth_tokens(rng: np.random.Generator, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    """Zipf unigrams + copied motifs (so loss can actually go down)."""
    zipf = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = (zipf % (vocab - 2)) + 1
    # motif copying: repeat a short window later in the sequence
    if seq >= 64:
        start = rng.integers(0, seq // 4, size=batch)
        for b in range(batch):
            w = toks[b, start[b]:start[b] + 16]
            dst = seq // 2 + start[b]
            toks[b, dst:dst + 16] = w[:max(0, min(16, seq - dst))]
    return toks.astype(np.int32)


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                step: int = 0, host: int = 0, num_hosts: int = 1
                ) -> Dict[str, np.ndarray]:
    """The host-local slice of global batch ``step``."""
    assert shape.global_batch % num_hosts == 0
    b = shape.global_batch // num_hosts
    s = shape.seq_len
    rng = _rng_for_step(seed, step, host)
    if cfg.family == "vlm":
        text_len = max(16, s - cfg.n_patches)
        return {
            "patches": rng.normal(size=(b, cfg.n_patches, cfg.frontend_dim)
                                  ).astype(np.float32),
            "tokens": synth_tokens(rng, b, text_len, cfg.vocab),
        }
    if cfg.family == "audio":
        mask = rng.random((b, s)) < 0.08
        return {
            "frames": rng.normal(size=(b, s, cfg.frontend_dim)
                                 ).astype(np.float32),
            "targets": rng.integers(0, cfg.vocab, size=(b, s)
                                    ).astype(np.int32),
            "mask": mask,
        }
    return {"tokens": synth_tokens(rng, b, s, cfg.vocab)}


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        text_len = max(16, s - cfg.n_patches)
        return {
            "patches": jax.ShapeDtypeStruct((b, cfg.n_patches,
                                             cfg.frontend_dim), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, text_len), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                           jnp.float32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


class TokenPipeline:
    """Double-buffered iterator over deterministic synthetic batches."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, start_step: int = 0, host: int = 0,
                 num_hosts: int = 1, prefetch: int = 2):
        self.cfg, self.shape = cfg, shape
        self.seed, self.host, self.num_hosts = seed, host, num_hosts
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.shape, seed=self.seed,
                                step=step, host=self.host,
                                num_hosts=self.num_hosts)
            try:
                self._q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        out = self._q.get()
        self.step += 1
        return out

    def close(self):
        self._stop.set()
