"""Incremental logit views (beyond-paper integration #3).

Serving systems cache *views over model outputs*: classifier scores for a
corpus, prompt-prefix logits, retrieval embeddings.  When the weights get
a low-rank update ΔW = U Vᵀ (adapter hot-swap, online fine-tune step),
re-running the model over the corpus costs O(m·n·p); LINVIEW's delta rule
for the final linear view

    Y = H W     ⇒     ΔY = H (ΔW) = (H U) Vᵀ

costs O(m·k·(n+p)) — §5.1's OLS maintenance transplanted to serving.
This module maintains such views through the LINVIEW engine, so the same
compiler/trigger machinery drives both the analytics and serving paths.

Scope note (DESIGN.md §5): this is exact only for views that are linear
in the updated weight (lm-head/classifier/embedding-projection layers —
the common hot-swap case).  Updates to weights *behind* a nonlinearity
invalidate the cache; `covers()` reports which updates are maintainable
and the engine falls back to re-encoding otherwise.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (IncrementalEngine, Program, dim, matmul, transpose,
                        var)


def build_logit_view_program(m: int, d: int, p: int) -> Program:
    """The logit-view program Y = H · Wᵀ as a standalone IR builder.

    H: (m, d) cached corpus hidden states, W: (p, d) output head.
    Used by :class:`IncrementalLogitView` for a single in-process view
    and by ``repro.fleet`` tenants — a multi-tenant serving fleet
    registers one tenant per (corpus, head) pair over this exact
    program, so same-shape tenants share compiled triggers through the
    fleet's :class:`~repro.plan.TriggerCache`.
    """
    prog = Program(name="logit_view")
    M, D, P_ = dim("m"), dim("d"), dim("p")
    H = prog.input("H", (M, D))
    W = prog.input("W", (P_, D))
    prog.let("Y", matmul(H, transpose(W)))
    prog.outputs = ["Y"]
    prog.bind_dims(m=m, d=d, p=p)
    return prog


class IncrementalLogitView:
    """Maintains Y = H · Wᵀ under rank-k updates to W.

    H: (m, d) cached hidden states for a corpus of m items (computed once
    with the frozen backbone); W: (p, d) output head (vocab or classes).
    """

    def __init__(self, hidden: jax.Array, head: jax.Array, rank: int = 1,
                 flush_size: int = 16, flush_age: float = 0.05,
                 max_batch_rank: Optional[int] = None,
                 plan=None):
        m, d = hidden.shape
        p, d2 = head.shape
        assert d == d2
        prog = build_logit_view_program(m, d, p)
        self.engine = IncrementalEngine(
            prog, {"W": rank, "H": rank},
            max_batch_rank=max_batch_rank,
            flush_size=flush_size, flush_age=flush_age,
            plan=plan)
        self.engine.initialize({"H": jnp.asarray(hidden, jnp.float32),
                                "W": jnp.asarray(head, jnp.float32)})

    def replan(self, workload) -> "object":
        """Hot-swap a cost-based maintenance re-plan for this view.

        ``workload`` is a :class:`repro.plan.WorkloadDescriptor` (or a
        ready :class:`~repro.plan.MaintenancePlan`).  The staleness
        contract survives the swap: pending queued hot-swap deltas are
        kept (they flush under the *new* plan on the same
        ``flush_size``/``flush_age`` thresholds), and reads through
        :attr:`logits` still see at most ``flush_age`` of staleness.
        Returns the installed plan.
        """
        from repro.plan import MaintenancePlan, plan_for_engine
        plan = (workload if isinstance(workload, MaintenancePlan)
                else plan_for_engine(self.engine, workload))
        self.engine.set_plan(plan)
        return plan

    @property
    def logits(self) -> jax.Array:
        # read-path staleness bound: flush pending deltas that tripped the
        # size/age thresholds (enqueue-only checking would let a lone
        # queued delta go stale forever if no further updates arrive)
        self.engine.maybe_flush("W")
        return self.engine.views["Y"]

    def update_head(self, u: jax.Array, v: jax.Array) -> jax.Array:
        """W += u vᵀ (u: (p, k) class/vocab side, v: (d, k))."""
        self.engine.apply_update("W", u, v)
        return self.logits

    def update_head_batch(self, updates) -> jax.Array:
        """Apply a stream of head updates ``[(u_t, v_t)]`` as ONE batched
        trigger firing — the corpus logits Y are swept once per batch
        instead of once per adapter delta."""
        self.engine.apply_updates("W", updates)
        return self.logits

    def submit_head_update(self, u: jax.Array, v: jax.Array) -> bool:
        """Serving-path contract: queue a head update for coalescing.

        Updates accumulate in the engine queue and flush as one batched
        trigger when the stacked rank hits ``flush_size`` or the oldest
        pending delta exceeds ``flush_age`` seconds.  Returns True if this
        submission triggered a flush (logits are fresh), False if the
        update is still pending (call :meth:`flush` before reading logits
        with exactness requirements).
        """
        return self.engine.enqueue_update("W", u, v) is not None

    def flush(self) -> jax.Array:
        """Force all pending updates into the maintained logits."""
        self.engine.flush()
        return self.logits

    @property
    def pending_updates(self) -> int:
        return self.engine.pending_rank("W")

    def add_items(self, u: jax.Array, v: jax.Array) -> jax.Array:
        """Corpus-side update H += u vᵀ (e.g. refreshed item embeddings
        for rows picked out by u)."""
        self.engine.apply_update("H", u, v)
        return self.logits

    @staticmethod
    def covers(update_path: str) -> bool:
        """Is a weight at ``update_path`` maintainable exactly?"""
        linear_views = ("lm_head", "embed", "frontend", "router")
        return any(t in update_path for t in linear_views)

    def speedup_estimate(self) -> float:
        return (self.engine.reeval_flops() /
                max(self.engine.trigger_flops("W"), 1.0))
