"""Batched serving engine: prefill + decode with KV/SSM caches.

A deliberately small but real engine: fixed-batch slots, greedy/temperature
sampling, per-slot stop handling, and a jitted decode step shared across
slots.  ``launch/serve.py`` drives it; the dry-run lowers its
``serve_step`` for the decode shapes.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import LM


@dataclass
class ServeEngine:
    model: LM
    params: Any
    batch_size: int = 8
    max_seq: int = 2048
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.encoder_only:
            raise ValueError("encoder-only model has no decode step")
        self.cache = self.model.init_cache(self.batch_size, self.max_seq)
        self._decode = jax.jit(self.model.decode_step,
                               donate_argnums=(1,))
        self._rng = jax.random.PRNGKey(self.seed)

    def prefill(self, prompts: np.ndarray) -> jax.Array:
        """Populate the cache from the prompts.

        Transformer families use the batched single-pass prefill (also
        correct for bidirectional VLM prefixes); recurrent families
        (ssm/hybrid) step their state token-by-token.

        prompts: (B, S) int32 → last-token logits (B, V).
        """
        b, s = prompts.shape
        assert b == self.batch_size
        if self.model.cfg.family in ("dense", "moe", "vlm"):
            logits, self.cache = jax.jit(
                self.model.prefill, static_argnames=("max_seq",))(
                self.params, {"tokens": jnp.asarray(prompts)},
                max_seq=self.max_seq)
            self._pos = s
            return logits[:, -1, :]
        logits = None
        for t in range(s):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(prompts[:, t:t + 1]),
                jnp.asarray(t, jnp.int32))
        self._pos = s
        return logits[:, 0, :]

    def sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(sub, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 stop_token: Optional[int] = None) -> np.ndarray:
        last = self.prefill(prompts)
        out: List[np.ndarray] = []
        tok = self.sample(last)
        done = np.zeros(self.batch_size, bool)
        for i in range(max_new):
            out.append(np.asarray(tok))
            if stop_token is not None:
                done |= np.asarray(tok) == stop_token
                if done.all():
                    break
            logits, self.cache = self._decode(
                self.params, self.cache, tok[:, None],
                jnp.asarray(self._pos, jnp.int32))
            self._pos += 1
            tok = self.sample(logits[:, 0, :])
        return np.stack(out, axis=1)


def make_serve_step(model: LM):
    """The dry-run's decode entrypoint: one token for the whole batch."""

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step


def make_prefill_step(model: LM):
    """The dry-run's prefill entrypoint: full forward, returns logits."""

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step
