"""Batched serving engine: prefill + decode with KV/SSM caches.

A deliberately small but real engine: fixed-batch slots, greedy/temperature
sampling, per-slot stop handling, and a jitted decode step shared across
slots.  ``launch/serve.py`` drives it; the dry-run lowers its
``serve_step`` for the decode shapes.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import LM


@dataclass
class ServeEngine:
    model: LM
    params: Any
    batch_size: int = 8
    max_seq: int = 2048
    temperature: float = 0.0
    seed: int = 0
    #: optional :class:`repro.guard.DegradePolicy` — wraps every attached
    #: logit view in retry + circuit-breaker + last-good-snapshot serving
    degrade: Optional[Any] = None
    _logit_views: Dict[str, Any] = field(default_factory=dict, init=False)
    _view_guards: Dict[str, Any] = field(default_factory=dict, init=False)
    _fleet: Optional[Any] = field(default=None, init=False)
    _fleet_tenants: Dict[str, str] = field(default_factory=dict, init=False)

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.encoder_only:
            raise ValueError("encoder-only model has no decode step")
        self.cache = self.model.init_cache(self.batch_size, self.max_seq)
        self._decode = jax.jit(self.model.decode_step,
                               donate_argnums=(1,))
        self._rng = jax.random.PRNGKey(self.seed)

    def prefill(self, prompts: np.ndarray) -> jax.Array:
        """Populate the cache from the prompts.

        Transformer families use the batched single-pass prefill (also
        correct for bidirectional VLM prefixes); recurrent families
        (ssm/hybrid) step their state token-by-token.

        prompts: (B, S) int32 → last-token logits (B, V).
        """
        b, s = prompts.shape
        assert b == self.batch_size
        if self.model.cfg.family in ("dense", "moe", "vlm"):
            logits, self.cache = jax.jit(
                self.model.prefill, static_argnames=("max_seq",))(
                self.params, {"tokens": jnp.asarray(prompts)},
                max_seq=self.max_seq)
            self._pos = s
            return logits[:, -1, :]
        logits = None
        for t in range(s):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(prompts[:, t:t + 1]),
                jnp.asarray(t, jnp.int32))
        self._pos = s
        return logits[:, 0, :]

    def sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(sub, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)

    # -- incremental logit views (LINVIEW serving integration) ---------------
    #
    # Corpus-level views over model outputs (classifier scores, retrieval
    # logits) are maintained incrementally under low-rank weight updates
    # instead of re-encoding the corpus.  Hot-swap deltas are *queued* and
    # coalesced: a burst of T adapter updates costs one batched trigger
    # firing per view (one sweep over each logit matrix), not T.

    def attach_logit_view(self, weight_path: str, view) -> None:
        """Register an :class:`IncrementalLogitView` maintained for the
        weight at ``weight_path`` (e.g. ``"lm_head"``)."""
        from .incremental_views import IncrementalLogitView
        if not IncrementalLogitView.covers(weight_path):
            raise ValueError(
                f"{weight_path!r} is behind a nonlinearity; its cached "
                f"views cannot be maintained exactly — re-encode instead")
        self._logit_views[weight_path] = view
        if self.degrade is not None:
            from repro.guard import GuardedView
            self._view_guards[weight_path] = GuardedView(view, self.degrade)

    def attach_fleet(self, fleet, tenant_of: Dict[str, str]) -> None:
        """Back logit views by a shared multi-tenant fleet service.

        ``fleet`` is a :class:`repro.fleet.FleetScheduler`;
        ``tenant_of`` maps weight paths to tenant ids already registered
        in it (over :func:`~repro.serve.incremental_views.
        build_logit_view_program` programs).  Hot-swap deltas for these
        paths go through the fleet's admission control into the tenant's
        update log (so they survive worker crashes), reads come from the
        tenant's committed snapshot, and :meth:`view_health` reports the
        tenant's lease/breaker/staleness state.  Paths may be fleet- or
        locally-backed side by side; fleet routing wins where both
        exist.
        """
        from .incremental_views import IncrementalLogitView
        for path, tenant_id in tenant_of.items():
            if not IncrementalLogitView.covers(path):
                raise ValueError(
                    f"{path!r} is behind a nonlinearity; its cached "
                    f"views cannot be maintained exactly")
            fleet.registry.get(tenant_id)   # raises on unknown tenant
        self._fleet = fleet
        self._fleet_tenants.update(tenant_of)

    def hot_swap(self, weight_path: str, u: jax.Array, v: jax.Array) -> bool:
        """Route a low-rank weight delta ``W += u vᵀ`` to the *cached corpus
        views* maintained for ``weight_path``.

        This keeps the incremental logit views consistent with the new
        weights; swapping the delta into the live decode params
        (``self.params``) is the caller's job — param-tree layout is
        model-family specific, and applying only one side would silently
        diverge.  The delta is enqueued on the view attached at
        ``weight_path``; the queue flushes when the size threshold trips
        on enqueue, and the staleness threshold is enforced on the next
        ``logits`` read (or an explicit :meth:`flush_views`).  Returns
        True if this enqueue flushed the view (its logits are fresh now).
        """
        if weight_path in self._fleet_tenants:
            # fleet-backed: the delta enters the tenant's durable update
            # log through admission control; workers fire it under a
            # lease.  True = admitted (refresh is asynchronous, bounded
            # by the tenant's SLO), False = throttled/shed back-pressure.
            decision = self._fleet.submit(
                self._fleet_tenants[weight_path], "W",
                np.asarray(u, np.float32), np.asarray(v, np.float32))
            return decision == "admitted"
        if weight_path not in self._logit_views:
            raise KeyError(f"no logit view attached for {weight_path!r}; "
                           f"have {sorted(self._logit_views)} and fleet "
                           f"tenants {sorted(self._fleet_tenants)}")
        guard = self._view_guards.get(weight_path)
        if guard is not None:
            # retried + breaker-gated: a repeatedly failing refresh trips
            # the breaker and the view degrades to its last-good snapshot
            return guard.submit(u, v)
        return self._logit_views[weight_path].submit_head_update(u, v)

    def flush_views(self) -> None:
        """Force all pending hot-swap deltas into the maintained views —
        call before serving reads that need exact logits.  Guarded views
        retry with backoff; a view whose breaker is open stays on its
        snapshot (see :meth:`view_health`) instead of raising."""
        for path, view in self._logit_views.items():
            guard = self._view_guards.get(path)
            if guard is not None:
                guard.flush()
            else:
                view.flush()
        if self._fleet is not None and self._fleet_tenants:
            self._fleet.drain(self._fleet_tenants.values())

    def view_logits(self, weight_path: str):
        """Read one view's logits at bounded staleness: fresh when
        healthy, the last-good snapshot when degraded (unguarded views
        read straight through)."""
        if weight_path in self._fleet_tenants:
            return self._fleet.read(self._fleet_tenants[weight_path], "Y")
        guard = self._view_guards.get(weight_path)
        if guard is not None:
            return guard.read()
        return self._logit_views[weight_path].logits

    def view_health(self) -> Dict[str, Dict[str, Any]]:
        """Per-view serving health: breaker state, staleness bound,
        retry/degradation counters (``{"serving": "fresh"}`` for
        unguarded views)."""
        out: Dict[str, Dict[str, Any]] = {}
        for path in self._logit_views:
            guard = self._view_guards.get(path)
            out[path] = (guard.health() if guard is not None
                         else {"breaker": None, "serving": "fresh",
                               "staleness_s": 0.0})
        for path, tenant_id in self._fleet_tenants.items():
            out[path] = self._fleet.registry.get(tenant_id).health()
        return out

    def replan_views(self, workload) -> Dict[str, Any]:
        """Hot-swap a cost-based maintenance re-plan into every attached
        logit view (e.g. when the adapter-delta traffic profile shifts).

        ``workload`` is a :class:`repro.plan.WorkloadDescriptor`; each
        view prices its own plan against it.  The swap never drops the
        staleness contract: pending queued deltas survive (and flush on
        the unchanged ``flush_size``/``flush_age`` thresholds under the
        new plan), and in-flight reads still see logits at most
        ``flush_age`` stale.  Returns {weight_path: installed plan}.
        """
        return {path: view.replan(workload)
                for path, view in self._logit_views.items()}

    # -- checkpoint hooks ----------------------------------------------------
    def save_checkpoint(self, manager, step: int,
                        blocking: bool = False) -> str:
        """Snapshot the serving weights through a
        :class:`repro.dist.checkpoint.CheckpointManager`.

        Only ``params`` are persisted: decode caches are per-request
        transients, and incremental logit views rebuild from the weights
        they were attached with.  A stream of low-rank hot-swap deltas
        between saves is exactly the workload the manager's factored
        incremental checkpoints compress well.
        """
        return manager.save(step, self.params, blocking=blocking)

    def restore_checkpoint(self, manager, step: Optional[int] = None
                           ) -> "ServeEngine":
        """Load weights from checkpoint ``step`` (default latest) and
        reset all weight-derived serving state: the decode cache (KV
        computed under the old weights must not leak into post-restore
        requests) and any attached logit views (they may have absorbed
        hot-swap deltas newer than the checkpoint and cannot be rolled
        back — re-attach them against the restored weights; a stale
        ``hot_swap`` call now raises instead of silently diverging)."""
        self.params = manager.restore(self.params, step=step)
        self.cache = self.model.init_cache(self.batch_size, self.max_seq)
        self._pos = 0
        self._logit_views.clear()
        self._view_guards.clear()
        return self

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 stop_token: Optional[int] = None) -> np.ndarray:
        last = self.prefill(prompts)
        out: List[np.ndarray] = []
        tok = self.sample(last)
        done = np.zeros(self.batch_size, bool)
        for i in range(max_new):
            out.append(np.asarray(tok))
            if stop_token is not None:
                done |= np.asarray(tok) == stop_token
                if done.all():
                    break
            logits, self.cache = self._decode(
                self.params, self.cache, tok[:, None],
                jnp.asarray(self._pos, jnp.int32))
            self._pos += 1
            tok = self.sample(logits[:, 0, :])
        return np.stack(out, axis=1)


def make_serve_step(model: LM):
    """The dry-run's decode entrypoint: one token for the whole batch."""

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step


def make_prefill_step(model: LM):
    """The dry-run's prefill entrypoint: full forward, returns logits."""

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step
