"""Serving substrate: batched decode engine + incremental logit views.

``IncrementalLogitView`` (pure LINVIEW-core) is always importable;
``ServeEngine`` needs the model stack (``repro.models`` → ``repro.dist``)
and degrades to a stub that raises on construction where that is not
built yet (see ROADMAP).
"""

import importlib.util

from .incremental_views import IncrementalLogitView

if importlib.util.find_spec("repro.dist") is not None:
    from .engine import ServeEngine
else:  # repro.dist not built yet; any other ImportError propagates

    class ServeEngine:  # type: ignore[no-redef]
        """Unavailable: the model stack requires ``repro.dist``."""

        def __init__(self, *args, **kwargs):
            raise ImportError(
                "ServeEngine requires repro.dist, which is not built yet "
                "(see ROADMAP open items); IncrementalLogitView works "
                "without it")

__all__ = ["ServeEngine", "IncrementalLogitView"]
