"""Serving substrate: batched decode engine + incremental logit views."""

from .engine import ServeEngine
from .incremental_views import IncrementalLogitView

__all__ = ["ServeEngine", "IncrementalLogitView"]
