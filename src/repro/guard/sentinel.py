"""Drift sentinel: stochastic residual probes + exactness recovery
(guard layer 3).

Incremental maintenance is algebraically exact but floating-point
drifts: a million rank-k sweeps accumulate rounding that a single
re-evaluation would not.  Re-evaluating everything to *check* for drift
would forfeit the paper's entire §7 win, so the sentinel sketches
instead: every ``probe_every`` firings it draws a few random probe
vectors ``x`` and measures, per materialized view ``A`` with defining
statement ``A := f(parents)``,

    drift(A) = ‖f(parents)·x − A·x‖_F / ‖f(parents)·x‖_F

where ``f(parents)·x`` is computed *matrix-free* (matvec chains through
the expression tree, O(n²) per probe instead of the O(n³) of
materializing ``f``).  Per-statement residuals cover the whole DAG by
induction: inputs are maintained exactly (the trigger's ``+=`` is the
update itself), so any divergence from full re-evaluation must show up
as some statement disagreeing with its own parents.

When a view's drift exceeds ``tol`` the sentinel runs **exactness
recovery**: targeted re-evaluation of only the drifted views, in
program order (so a recovered ancestor feeds its recovered descendant)
— the §7 cost model's escape hatch, paid only when the probes prove it
is needed.  Recoveries are also reported to the engine's
:class:`~repro.plan.AdaptivePlanner` (when one is attached) as a
re-planning signal: a view that keeps drifting is a view whose
incremental strategy is numerically too aggressive.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import expr as ex
from repro.core.codegen import evaluate
from repro.core.cost import shape_of


@dataclass(frozen=True)
class SentinelConfig:
    """Probe cadence and tolerance.

    ``probe_every`` amortizes the probe against the firings it covers
    (a probe costs O(Σ n·m · n_probes) — a few matvecs — vs the
    2·k·n·m of every firing's sweep, so the clean-path overhead is
    ~``n_probes / (k · probe_every)``).  ``tol`` is the relative
    residual above which a view is declared drifted; ``recover=False``
    reports drift without re-evaluating (monitoring-only mode).
    """

    probe_every: int = 64
    n_probes: int = 2
    tol: float = 5e-3
    seed: int = 0
    recover: bool = True


class DriftSentinel:
    """Tracks per-view drift for one engine's program."""

    def __init__(self, config: SentinelConfig, program, binding):
        self.config = config
        self.program = program
        self.binding = dict(binding)
        self._rng = np.random.default_rng(config.seed)
        self._since_probe = 0
        self.probes = 0
        self.recoveries = 0
        self.last_drift: Dict[str, float] = {}
        self.max_drift = 0.0

    # -- cadence -------------------------------------------------------------
    def after_firing(self, engine) -> Optional[Dict[str, float]]:
        """Count one committed firing; probe when the cadence is due.
        Returns the per-view drift map on probe firings, else None."""
        self._since_probe += 1
        if self._since_probe < self.config.probe_every:
            return None
        self._since_probe = 0
        drifts = self.probe(engine)
        drifted = [n for n, d in drifts.items() if d > self.config.tol]
        if drifted and self.config.recover:
            self.recover(engine, drifted)
        return drifts

    # -- probing -------------------------------------------------------------
    def probe(self, engine) -> Dict[str, float]:
        """Residual-sketch every materialized view against its defining
        statement (lazy views left stale by planned firings are skipped
        — they are *known* stale and recomputed on read)."""
        drifts: Dict[str, float] = {}
        views = engine.views
        for st in self.program.statements:
            name = st.target.name
            if name in engine._stale or name not in views:
                continue
            _, m = shape_of(st.target, self.binding)
            x = jnp.asarray(self._rng.standard_normal(
                (m, self.config.n_probes)).astype(np.float32))
            ref = expr_matvec(st.expr, views, self.binding, x)
            cur = views[name] @ x
            denom = float(jnp.linalg.norm(ref))
            num = float(jnp.linalg.norm(ref - cur))
            drift = num / max(denom, 1e-30)
            if not np.isfinite(drift):
                drift = float("inf")
            drifts[name] = drift
        self.probes += 1
        self.last_drift = drifts
        finite = [d for d in drifts.values() if np.isfinite(d)]
        if finite:
            self.max_drift = max(self.max_drift, max(finite))
        return drifts

    def drifted_views(self) -> List[str]:
        return [n for n, d in self.last_drift.items() if d > self.config.tol]

    # -- recovery ------------------------------------------------------------
    def recover(self, engine, names) -> List[str]:
        """Targeted exactness recovery: re-evaluate only the drifted
        views, in program order, against the engine's current store —
        ancestors first, so a drifted chain heals in one pass."""
        todo = set(names)
        recovered = []
        for st in self.program.statements:
            name = st.target.name
            if name not in todo:
                continue
            engine.views[name] = evaluate(st.expr, engine.views,
                                          self.binding)
            engine._accum_rank[name] = 0
            recovered.append(name)
        if recovered:
            self.recoveries += 1
            if engine.planner is not None:
                engine.planner.note_drift(recovered)
        return recovered


# ---------------------------------------------------------------------------
# matrix-free expression application: expr @ x without materializing expr
# ---------------------------------------------------------------------------


def expr_matvec(e, env, binding, x):
    """Evaluate ``e @ x`` for a skinny probe block ``x`` — matvec chains
    instead of matmuls, O(n²·probes) where materializing ``e`` costs
    O(n³).  ``Inverse`` nodes become triangular solves against the
    (materialized) operand; node types with no cheap matvec form fall
    back to full evaluation (they are small in every paper program)."""
    if isinstance(e, ex.Var):
        return env[e.name] @ x
    if isinstance(e, ex.Identity):
        return x
    if isinstance(e, ex.Zero):
        n = _dim(e.shape[0], binding)
        return jnp.zeros((n, x.shape[1]), dtype=x.dtype)
    if isinstance(e, ex.MatMul):
        return expr_matvec(e.lhs, env, binding,
                           expr_matvec(e.rhs, env, binding, x))
    if isinstance(e, ex.Add):
        out = expr_matvec(e.terms[0], env, binding, x)
        for t in e.terms[1:]:
            out = out + expr_matvec(t, env, binding, x)
        return out
    if isinstance(e, ex.Scale):
        f = evaluate(e.factor, env, binding)
        if getattr(f, "ndim", 0) == 2:
            f = f[0, 0]
        return f * expr_matvec(e.operand, env, binding, x)
    if isinstance(e, ex.Transpose):
        return expr_rmatvec(e.operand, env, binding, x)
    if isinstance(e, ex.Inverse):
        a = evaluate(e.operand, env, binding)
        if a.shape == (1, 1):
            return x / a
        return jnp.linalg.solve(a, x)
    # HStack / ColSlice / Const: rare and small — materialize
    return evaluate(e, env, binding) @ x


def expr_rmatvec(e, env, binding, x):
    """``eᵀ @ x`` by the dual recursion (so Transpose nodes never
    materialize their operand)."""
    if isinstance(e, ex.Var):
        return env[e.name].T @ x
    if isinstance(e, ex.Identity):
        return x
    if isinstance(e, ex.Zero):
        m = _dim(e.shape[1], binding)
        return jnp.zeros((m, x.shape[1]), dtype=x.dtype)
    if isinstance(e, ex.MatMul):
        return expr_rmatvec(e.rhs, env, binding,
                            expr_rmatvec(e.lhs, env, binding, x))
    if isinstance(e, ex.Add):
        out = expr_rmatvec(e.terms[0], env, binding, x)
        for t in e.terms[1:]:
            out = out + expr_rmatvec(t, env, binding, x)
        return out
    if isinstance(e, ex.Scale):
        f = evaluate(e.factor, env, binding)
        if getattr(f, "ndim", 0) == 2:
            f = f[0, 0]
        return f * expr_rmatvec(e.operand, env, binding, x)
    if isinstance(e, ex.Transpose):
        return expr_matvec(e.operand, env, binding, x)
    if isinstance(e, ex.Inverse):
        a = evaluate(e.operand, env, binding)
        if a.shape == (1, 1):
            return x / a
        return jnp.linalg.solve(a.T, x)
    return evaluate(e, env, binding).T @ x


def _dim(d, binding):
    return binding[d.name] if isinstance(d, ex.Dim) else int(d)
