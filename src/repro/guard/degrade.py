"""Graceful degradation for the serving path (guard layer 5).

A view refresh that fails once is retried with exponential backoff +
jitter; a view that fails *repeatedly* trips a per-view circuit breaker
and degrades to serving its **last-good snapshot** with an explicit
staleness bound, instead of blocking the request path behind a broken
refresh.  After ``breaker_reset`` seconds the breaker goes half-open
and lets one refresh probe through; success closes it and fresh serving
resumes.

Everything here is clock/sleep-injectable so the breaker state machine
unit-tests with a fake clock, and :class:`GuardedView` is duck-typed
over anything exposing ``submit_head_update`` / ``flush`` / ``logits``
(in practice :class:`repro.serve.incremental_views.IncrementalLogitView`).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np


@dataclass(frozen=True)
class DegradePolicy:
    """Retry/backoff/breaker knobs for one serving view."""

    max_retries: int = 3          # attempts per refresh (1 + retries)
    backoff_base: float = 0.01    # first retry delay, seconds
    backoff_max: float = 1.0      # delay cap
    jitter: float = 0.5           # ± fraction of the delay randomized
    #: AWS-style "full jitter": each sleep is uniform(0, delay) instead
    #: of delay·(1 ± jitter).  A fleet of workers retrying the same
    #: failure decorrelates completely — reclaim storms cannot
    #: synchronize into periodic thundering herds (the ±-fraction mode
    #: keeps them within ``jitter`` of lock-step).
    full_jitter: bool = False
    #: total wall-clock budget for one retried call, seconds (None =
    #: attempts-bounded only).  Enforced against the injected ``clock``,
    #: so a lease-holding fleet worker can bound its retry loop well
    #: under the lease TTL instead of retrying into a fencing conflict.
    retry_deadline: Optional[float] = None
    breaker_threshold: int = 3    # consecutive exhausted refreshes → open
    breaker_reset: float = 30.0   # seconds open → half-open probe
    seed: int = 0


class CircuitBreaker:
    """closed → (threshold consecutive failures) → open → (reset
    timeout) → half_open → one probe → closed | open."""

    def __init__(self, threshold: int = 3, reset_timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.reset_timeout = reset_timeout
        self._clock = clock
        self._failures = 0
        self._opened_at: Optional[float] = None
        self._probe_started: Optional[float] = None

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return "closed"
        if self._clock() - self._opened_at >= self.reset_timeout:
            return "half_open"
        return "open"

    @property
    def consecutive_failures(self) -> int:
        return self._failures

    def allow(self) -> bool:
        """May a refresh be attempted now?  half_open admits exactly ONE
        in-flight probe — concurrent callers (a fleet of workers all
        watching the same broken tenant) see the window as still open
        instead of stampeding the backend together.  A probe whose
        caller vanished (crashed worker) is abandoned after another
        ``reset_timeout``, re-arming the window."""
        if self.state != "half_open":
            return self.state == "closed"
        now = self._clock()
        if (self._probe_started is not None
                and now - self._probe_started < self.reset_timeout):
            return False  # someone else's probe is in flight
        self._probe_started = now
        return True

    def record_success(self) -> None:
        self._failures = 0
        self._opened_at = None
        self._probe_started = None

    def record_failure(self) -> None:
        self._failures += 1
        self._probe_started = None
        if self._failures >= self.threshold or self._opened_at is not None:
            self._opened_at = self._clock()


def retry_with_backoff(fn: Callable[[], object], policy: DegradePolicy,
                       rng: np.random.Generator,
                       sleep: Callable[[float], None] = time.sleep,
                       clock: Callable[[], float] = time.monotonic):
    """Call ``fn`` up to ``1 + max_retries`` times with exponential
    backoff + jitter between attempts.  Returns ``(value, attempts)``;
    re-raises the last exception when every attempt failed.

    The injected ``clock``/``sleep`` pair makes the loop fully
    deterministic under a fake clock (fleet tests, chaos runs).  With
    ``policy.retry_deadline`` set, the loop also gives up once the next
    sleep would land past the deadline — a lease-holding worker must
    fail fast and let the claim be reclaimed, not retry through its own
    TTL.  ``policy.full_jitter`` draws each sleep uniform(0, delay)
    (decorrelated) instead of delay·(1 ± jitter).
    """
    t0 = clock()
    delay = policy.backoff_base
    last: Optional[BaseException] = None
    for attempt in range(1 + policy.max_retries):
        try:
            return fn(), attempt + 1
        except Exception as e:  # noqa: BLE001 — the whole point is containment
            last = e
            if attempt == policy.max_retries:
                break
            if policy.full_jitter:
                pause = min(delay, policy.backoff_max) * rng.random()
            else:
                jit = 1.0 + policy.jitter * (2.0 * rng.random() - 1.0)
                pause = min(delay * jit, policy.backoff_max)
            if (policy.retry_deadline is not None
                    and clock() - t0 + pause > policy.retry_deadline):
                break
            sleep(pause)
            delay = min(delay * 2.0, policy.backoff_max)
    raise last  # type: ignore[misc]


class GuardedView:
    """Wraps one incremental logit view with retries, a circuit breaker,
    and a last-good snapshot fallback.

    The snapshot is refreshed after every successful flush (a reference
    to the immutable logits array — free).  While the breaker is open,
    :meth:`read` serves the snapshot and reports its staleness; deltas
    submitted meanwhile still enqueue (they are host-side and cheap), so
    a recovered view flushes the full backlog and is exact again.
    """

    def __init__(self, view, policy: Optional[DegradePolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.view = view
        self.policy = policy or DegradePolicy()
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(self.policy.seed)
        self.breaker = CircuitBreaker(self.policy.breaker_threshold,
                                      self.policy.breaker_reset, clock)
        self._snapshot = None
        self._snapshot_time: Optional[float] = None
        self.last_error: Optional[str] = None
        self.retries_used = 0
        self.refresh_failures = 0
        self.degraded_reads = 0
        self._snapshot_now()

    # -- internals -----------------------------------------------------------
    def _snapshot_now(self) -> None:
        self._snapshot = self.view.logits
        self._snapshot_time = self._clock()

    def _guarded(self, fn: Callable[[], object]) -> bool:
        """Run one refresh through retry + breaker; True on success."""
        if not self.breaker.allow():
            return False
        try:
            _, attempts = retry_with_backoff(fn, self.policy, self._rng,
                                             sleep=self._sleep,
                                             clock=self._clock)
        except Exception as e:  # noqa: BLE001
            self.refresh_failures += 1
            self.last_error = repr(e)
            self.breaker.record_failure()
            return False
        self.retries_used += attempts - 1
        self.breaker.record_success()
        self.last_error = None
        self._snapshot_now()
        return True

    # -- the serving contract ------------------------------------------------
    def submit(self, u, v) -> bool:
        """Queue one hot-swap delta.  Enqueueing is host-side and always
        succeeds; the *flush* it may trip is the guarded part.  Returns
        True when the view's logits are fresh after this call."""
        if not self.breaker.allow():
            # refreshes are suspended: enqueue without flushing so the
            # open breaker is not hammered by every delta
            self.view.engine.enqueue_update("W", u, v) \
                if hasattr(self.view, "engine") else None
            return False
        return self._guarded(lambda: self.view.submit_head_update(u, v))

    def flush(self) -> bool:
        """Force pending deltas into the view (retried, breaker-gated).
        Returns True when the view is fresh, False when degraded."""
        return self._guarded(self.view.flush)

    def read(self):
        """Logits at bounded staleness: fresh when the view is healthy,
        the last-good snapshot when the breaker is open (counted in
        ``degraded_reads``; staleness surfaced via :meth:`health`)."""
        if self.flush():
            return self.view.logits
        self.degraded_reads += 1
        return self._snapshot

    def staleness(self) -> float:
        """Seconds since the served snapshot was known good (0 when
        serving fresh)."""
        if self.breaker.state == "closed":
            return 0.0
        if self._snapshot_time is None:
            return float("inf")
        return self._clock() - self._snapshot_time

    def health(self) -> Dict[str, object]:
        return {
            "breaker": self.breaker.state,
            "serving": ("snapshot" if self.breaker.state == "open"
                        else "fresh"),
            "staleness_s": self.staleness(),
            "consecutive_failures": self.breaker.consecutive_failures,
            "refresh_failures": self.refresh_failures,
            "retries_used": self.retries_used,
            "degraded_reads": self.degraded_reads,
            "pending_updates": getattr(self.view, "pending_updates", 0),
            "last_error": self.last_error,
        }
