"""Update validation & quarantine (guard layer 1).

Every ``(u, v)`` factored update is admitted through
:func:`validate_update` before it can touch an engine queue or trigger:
shape/dtype conformance against the target input, NaN/Inf screening,
and a rank/norm budget (a single adversarial update with a huge
Frobenius norm can push an f32 view to Inf even though every entry is
finite).  Rejected updates are not dropped — they land in a per-input
:class:`QuarantineQueue` where an operator (or a test) can inspect
them, repair them, and :meth:`~QuarantineQueue.replay` them through the
engine's normal guarded path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class ValidationPolicy:
    """What :func:`validate_update` enforces on incoming factors.

    ``max_norm`` bounds ``‖u‖_F · ‖v‖_F`` — an upper bound on the
    Frobenius norm of the applied delta ``u vᵀ`` — so one oversized
    update cannot blow a float32 view past overflow even though every
    entry is individually finite.  ``check_outputs`` belongs to the
    transactional layer (:mod:`repro.guard.txn`): post-firing NaN/Inf
    validation of every written view before the firing commits.

    ``noop_tol`` enables the no-op gate: an update whose delta norm
    bound ``‖u‖_F·‖v‖_F`` is at most ``noop_tol`` is *skipped* — no
    firing, no quarantine (it is a legal no-op, not a fault; counted in
    ``GuardStats.noop_skips``).  The bound dominates the true delta
    norm, so the gate can never skip an update that would move any view
    by more than ``noop_tol`` (a NaN norm fails the comparison and
    falls through to the finite screen).
    """

    check_finite: bool = True
    check_outputs: bool = True
    max_update_rank: Optional[int] = None
    max_norm: Optional[float] = None
    noop_tol: float = 0.0


def validate_update(input_name: str, u: np.ndarray, v: np.ndarray,
                    input_shape: Tuple[int, int],
                    policy: ValidationPolicy) -> Optional[str]:
    """Admission check for ``input_name += u @ v.T``.

    Returns ``None`` when the update is admissible, else a short
    human-readable rejection reason (which becomes the quarantine
    record's ``reason``).  Pure host-side: factors are converted with
    ``np.asarray`` (a device sync for jax arrays — the guard needs the
    values to validate them).
    """
    n, m = input_shape
    u = np.asarray(u)
    v = np.asarray(v)
    if u.ndim != 2 or v.ndim != 2:
        return (f"{input_name}: factors must be 2-D, got "
                f"u.ndim={u.ndim} v.ndim={v.ndim}")
    if u.shape[0] != n or v.shape[0] != m:
        return (f"{input_name}: factor rows ({u.shape[0]}, {v.shape[0]}) "
                f"do not match input shape ({n}, {m})")
    if u.shape[1] != v.shape[1]:
        return (f"{input_name}: factor ranks disagree "
                f"({u.shape[1]} != {v.shape[1]})")
    if u.dtype.kind != "f" or v.dtype.kind != "f":
        return (f"{input_name}: factors must be floating point, got "
                f"{u.dtype}/{v.dtype}")
    if policy.max_update_rank is not None and u.shape[1] > policy.max_update_rank:
        return (f"{input_name}: rank {u.shape[1]} exceeds budget "
                f"{policy.max_update_rank}")
    if policy.check_finite and not (np.isfinite(u).all()
                                    and np.isfinite(v).all()):
        return f"{input_name}: non-finite entries in update factors"
    if policy.max_norm is not None:
        norm = float(np.linalg.norm(u)) * float(np.linalg.norm(v))
        if not norm <= policy.max_norm:  # catches NaN too
            return (f"{input_name}: delta norm bound {norm:.3e} exceeds "
                    f"budget {policy.max_norm:.3e}")
    return None


def validate_carrier(input_name: str, rows: np.ndarray, block: np.ndarray,
                     v: np.ndarray, input_shape: Tuple[int, int],
                     policy: ValidationPolicy) -> Optional[str]:
    """Admission check for a row-local carrier in *compact* form.

    The same budgets as :func:`validate_update`, restated on the
    ``(rows, block, V)`` triple so admission never materializes the
    dense-shaped left factor: structure (row indices sorted, unique,
    in-range; block rows match), dtype, NaN/Inf, and the rank/norm
    budgets (``‖block‖_F·‖V‖_F`` equals the widened bound exactly —
    the scattered zeros contribute nothing).
    """
    n, m = input_shape
    rows = np.asarray(rows)
    block = np.asarray(block)
    v = np.asarray(v)
    if rows.ndim != 1 or block.ndim != 2 or v.ndim != 2:
        return (f"{input_name}: carrier dims — rows.ndim={rows.ndim} "
                f"block.ndim={block.ndim} v.ndim={v.ndim}")
    if rows.dtype.kind not in "iu":
        return f"{input_name}: carrier rows must be integral, got {rows.dtype}"
    if rows.size == 0:
        return f"{input_name}: row-local carrier with empty row set"
    if rows.min() < 0 or rows.max() >= n:
        return (f"{input_name}: carrier rows out of range [0, {n}) "
                f"(min {rows.min()}, max {rows.max()})")
    if np.any(np.diff(rows) <= 0):
        return f"{input_name}: carrier rows must be sorted and unique"
    if block.shape[0] != rows.size:
        return (f"{input_name}: block rows {block.shape[0]} != affected "
                f"rows {rows.size}")
    if v.shape[0] != m:
        return (f"{input_name}: right factor rows {v.shape[0]} do not "
                f"match input columns {m}")
    if block.shape[1] != v.shape[1]:
        return (f"{input_name}: factor ranks disagree "
                f"({block.shape[1]} != {v.shape[1]})")
    if block.dtype.kind != "f" or v.dtype.kind != "f":
        return (f"{input_name}: factors must be floating point, got "
                f"{block.dtype}/{v.dtype}")
    if (policy.max_update_rank is not None
            and block.shape[1] > policy.max_update_rank):
        return (f"{input_name}: rank {block.shape[1]} exceeds budget "
                f"{policy.max_update_rank}")
    if policy.check_finite and not (np.isfinite(block).all()
                                    and np.isfinite(v).all()):
        return f"{input_name}: non-finite entries in update factors"
    if policy.max_norm is not None:
        norm = float(np.linalg.norm(block)) * float(np.linalg.norm(v))
        if not norm <= policy.max_norm:
            return (f"{input_name}: delta norm bound {norm:.3e} exceeds "
                    f"budget {policy.max_norm:.3e}")
    return None


@dataclass
class QuarantinedUpdate:
    """One rejected update, held with enough context to replay it."""

    input_name: str
    u: np.ndarray
    v: np.ndarray
    reason: str
    seq: int
    wall_time: float = field(default_factory=time.time)


class QuarantineQueue:
    """Bounded FIFO of rejected updates, inspectable and replayable.

    ``capacity`` bounds memory under a sustained poison storm: the
    oldest records are evicted first (and counted in ``evicted``), so a
    misbehaving producer can never OOM the view service through its own
    rejects.
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._items: List[QuarantinedUpdate] = []
        self._seq = 0
        self.evicted = 0

    def put(self, input_name: str, u, v, reason: str) -> QuarantinedUpdate:
        rec = QuarantinedUpdate(input_name=input_name,
                                u=np.asarray(u), v=np.asarray(v),
                                reason=reason, seq=self._seq)
        self._seq += 1
        self._items.append(rec)
        if len(self._items) > self.capacity:
            drop = len(self._items) - self.capacity
            self._items = self._items[drop:]
            self.evicted += drop
        return rec

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(list(self._items))

    def by_input(self, input_name: str) -> List[QuarantinedUpdate]:
        return [q for q in self._items if q.input_name == input_name]

    def reasons(self) -> Dict[str, int]:
        """Histogram of rejection reasons (first line only)."""
        out: Dict[str, int] = {}
        for q in self._items:
            key = q.reason.split(":", 1)[-1].strip()
            out[key] = out.get(key, 0) + 1
        return out

    def clear(self) -> None:
        self._items.clear()

    def replay(self, engine, repair: Optional[Callable[[QuarantinedUpdate],
               Optional[Tuple[np.ndarray, np.ndarray]]]] = None,
               input_name: Optional[str] = None) -> Tuple[int, int]:
        """Re-submit quarantined updates through the engine's guarded path.

        ``repair`` maps a record to fixed ``(u, v)`` factors (or ``None``
        to drop it); without one, records are replayed verbatim — useful
        after a policy change (e.g. a raised norm budget).  Replayed
        updates go through :meth:`IncrementalEngine.apply_update`, so
        they are re-validated: a still-bad update lands back in
        quarantine rather than looping.  Returns ``(applied,
        requarantined)``.
        """
        guard = getattr(engine, "guard", None)
        if guard is not None:
            guard.sync()  # deferred rejects belong to this replay pass
        picked = [q for q in self._items
                  if input_name is None or q.input_name == input_name]
        kept_out = {id(q) for q in picked}  # identity, not ==: the
        # records hold ndarrays, whose == is elementwise
        self._items = [q for q in self._items if id(q) not in kept_out]
        applied = requarantined = 0
        for q in picked:
            fixed = (q.u, q.v) if repair is None else repair(q)
            if fixed is None:
                continue
            before = len(self)
            engine.apply_update(q.input_name, fixed[0], fixed[1])
            if guard is not None:
                guard.sync()  # resolve any deferred reject NOW, so the
                # still-bad update counts as requarantined, not applied
            if len(self) > before:
                requarantined += 1
            else:
                applied += 1
        return applied, requarantined
