"""repro.guard — failure containment around every trigger firing.

Five cooperating layers (see docs/robustness.md for the failure matrix):

  1. :mod:`repro.guard.validate` — admission checks + quarantine for
     incoming ``(u, v)`` update factors;
  2. :mod:`repro.guard.txn`      — transactional firings: snapshot,
     post-firing NaN/Inf validation, atomic rollback;
  3. :mod:`repro.guard.sentinel` — stochastic drift probes + targeted
     exactness recovery, feeding the adaptive planner;
  4. :mod:`repro.guard.chaos`    — deterministic seeded fault injection
     threaded through the engine / checkpoints / fault tolerance;
  5. :mod:`repro.guard.degrade`  — serve-path retries, circuit breaker,
     last-good-snapshot fallback with explicit staleness.

Attach to an engine with ``IncrementalEngine(prog, guard=GuardConfig())``
(:class:`EngineGuard` is the per-engine runtime the engine drives);
inject faults with ``IncrementalEngine(prog, chaos=ChaosConfig(...))``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from .chaos import ChaosConfig, ChaosError, ChaosMonkey, as_monkey
from .degrade import (CircuitBreaker, DegradePolicy, GuardedView,
                      retry_with_backoff)
from .sentinel import DriftSentinel, SentinelConfig
from .txn import (FiringAborted, FiringSnapshot, changed_views,
                  check_finite, restore_snapshot, take_snapshot)
from .validate import (QuarantinedUpdate, QuarantineQueue, ValidationPolicy,
                       validate_carrier, validate_update)

__all__ = [
    "GuardConfig", "GuardStats", "EngineGuard",
    "ValidationPolicy", "QuarantineQueue", "QuarantinedUpdate",
    "validate_update", "validate_carrier",
    "FiringAborted", "FiringSnapshot", "take_snapshot", "restore_snapshot",
    "changed_views", "check_finite",
    "SentinelConfig", "DriftSentinel",
    "ChaosConfig", "ChaosError", "ChaosMonkey", "as_monkey",
    "DegradePolicy", "CircuitBreaker", "GuardedView", "retry_with_backoff",
]


@dataclass(frozen=True)
class GuardConfig:
    """Everything one guarded engine enforces.

    ``transactional=False`` keeps validation/quarantine but lets a
    failed firing propagate (debugging); ``sentinel=None`` disables
    drift probing.  The default — validation + transactional firings,
    no sentinel — is the cheapest configuration that still guarantees
    the store never goes non-finite.
    """

    validation: ValidationPolicy = field(default_factory=ValidationPolicy)
    sentinel: Optional[SentinelConfig] = None
    transactional: bool = True
    quarantine_capacity: int = 1024


@dataclass
class GuardStats:
    """Failure-log counters — deliberately NOT part of
    :class:`~repro.core.runtime.EngineStats`, so a rollback can restore
    the engine's stats bit-identically while the guard still remembers
    what went wrong.

    On the fused fast path the counters are *eventually consistent*:
    a firing's outcome lives on device until the next sync window
    (every 32 firings) or an explicit :meth:`EngineGuard.sync`.  The
    store itself is always protected immediately — only the accounting
    is deferred."""

    admitted: int = 0
    quarantined: int = 0
    noop_skips: int = 0          # updates dropped by the no-op gate (legal
                                 # skips, NOT faults — never quarantined)
    aborted_firings: int = 0
    rollbacks: int = 0
    probes: int = 0
    drift_recoveries: int = 0
    max_drift: float = 0.0


class EngineGuard:
    """Per-engine guard runtime; driven by
    :class:`~repro.core.runtime.IncrementalEngine` at its admission,
    firing, and post-commit hooks."""

    def __init__(self, config: GuardConfig, engine):
        import dataclasses
        from repro.core.cost import shape_of
        self.config = config
        self.quarantine = QuarantineQueue(config.quarantine_capacity)
        self.stats = GuardStats()
        self.sentinel = (DriftSentinel(config.sentinel, engine.program,
                                       engine.binding)
                         if config.sentinel is not None else None)
        self._input_shapes = {
            name: shape_of(var, engine.binding)
            for name, var in engine.program.inputs.items()}
        # this config can run firings through the fused in-program path
        # (trigger + finite-check + select-commit in one dispatch)
        self.fused_path_ok = (config.transactional
                              and config.validation.check_outputs)
        # admission policy minus the finite screen — what the host still
        # checks when the finite screen is deferred into the fused
        # firing program
        self._structural_policy = dataclasses.replace(
            config.validation, check_finite=False)
        # fused trigger+finite-check programs, keyed by (input, bucket)
        self._fused: dict = {}
        # fused firings whose outcome has not been fetched yet: the
        # select-commit already kept the store safe on device, so only
        # the *accounting* (reject/rollback counters + quarantine) is
        # deferred
        self._pending: list = []
        # device-resident cumulative [input-rejects, output-aborts]
        # counts, threaded through every fused firing; sync() learns
        # "all clean" from ONE fetch regardless of how many firings are
        # pending, and only walks per-firing records when a count moved
        self._nbad = None
        self._nbad_seen = (0, 0)

    # -- admission (layer 1) -------------------------------------------------
    def admit(self, input_name: str, u, v, defer_finite: bool = False
              ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Validate one update; quarantine and return None on reject.

        With ``defer_finite=True`` (the engine's fused fast path) the
        host checks only structure — shape/dtype/rank conformance — and
        the NaN/Inf screen runs inside the firing program itself, where
        a poisoned update rolls back via the select-commit and is
        reclassified as an admission reject at the next :meth:`sync`.
        A norm budget keeps the full host-side check (the budget needs
        the values anyway)."""
        u = np.asarray(u)
        v = np.asarray(v)
        policy = self.config.validation
        if policy.noop_tol > 0.0 and self._noop_gate(u, v):
            return None
        if defer_finite and policy.max_norm is None:
            policy = self._structural_policy
        reason = validate_update(input_name, u, v,
                                 self._input_shapes[input_name], policy)
        if reason is not None:
            self.quarantine.put(input_name, u, v, reason)
            self.stats.quarantined += 1
            return None
        self.stats.admitted += 1
        return u, v

    def admit_batch_stacked(self, input_name: str, updates
                            ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Fast-path batch admission that also *stacks*: returns the
        concatenated ``(P, Q)`` factors ready for one rank-ΣkT firing,
        or ``None`` to send the batch down the careful per-update walk
        (:meth:`admit_batch`).  The concat IS the validation vehicle —
        numpy refuses ragged rows, the stacked dtype exposes any
        non-float32 factor, and one vectorized NaN/Inf reduction over
        ``(P, Q)`` replaces T per-update screens — so the guarded clean
        path stacks once where the unguarded engine would stack anyway,
        instead of concatenating for admission and again for the
        trigger."""
        policy = self.config.validation
        if (policy.max_norm is not None
                or policy.max_update_rank is not None
                or policy.noop_tol > 0.0 or not updates):
            # budgets and the no-op gate need per-update values — the
            # careful walk applies them one update at a time
            return None
        n, m = self._input_shapes[input_name]
        try:
            P = np.concatenate([u for u, _ in updates], axis=1)
            Q = np.concatenate([v for _, v in updates], axis=1)
            # equal stacked ranks can still hide misaligned pairs
            # (u_i, v_i); a mispairing silently changes the delta
            if [u.shape[1] for u, _ in updates] != \
                    [v.shape[1] for _, v in updates]:
                return None
        except Exception:  # noqa: BLE001 — ragged, 1-D, or odd factors
            return None
        if (P.shape[0] != n or Q.shape[0] != m
                or P.shape[1] != Q.shape[1]
                or P.dtype != np.float32 or Q.dtype != np.float32):
            return None
        if policy.check_finite and not (np.isfinite(P).all()
                                        and np.isfinite(Q).all()):
            return None
        self.stats.admitted += len(updates)
        return P, Q

    def _noop_gate(self, u: np.ndarray, v: np.ndarray) -> bool:
        """The no-op gate (runs BEFORE quarantine screening): an update
        whose delta norm bound sits under ``policy.noop_tol`` is a legal
        skip, not a fault — it must never land in quarantine, where an
        operator would read it as an anomaly.  Sound by construction:
        ``‖u‖_F·‖v‖_F ≥ ‖u vᵀ‖_F`` bounds how far ANY maintained view
        can move, and a NaN/Inf norm fails the ``<=`` so poisoned
        updates fall through to the finite screen instead of being
        silently dropped."""
        norm = float(np.linalg.norm(u)) * float(np.linalg.norm(v))
        if norm <= self.config.validation.noop_tol:
            self.stats.noop_skips += 1
            return True
        return False

    def admit_carrier(self, input_name: str, rows, block, v,
                      count: int = 1) -> Optional[Tuple[np.ndarray,
                                                        np.ndarray]]:
        """Admission for a row-local carrier in compact form: the no-op
        gate, then :func:`validate_carrier` — structure, NaN/Inf, and
        the rank/norm budgets, all computed on the ``(r, k)`` block so
        admission cost scales with the rows *touched*.  On reject the
        factors are quarantined widened (dense-shaped ``(P, Q)``) when
        the row structure permits, so :meth:`QuarantineQueue.replay`
        rides the ordinary update path; ``count`` is the logical update
        count a stacked carrier batch represents."""
        rows = np.asarray(rows)
        block = np.asarray(block)
        v = np.asarray(v)
        policy = self.config.validation
        if policy.noop_tol > 0.0 and self._noop_gate(block, v):
            return None
        reason = validate_carrier(input_name, rows, block, v,
                                  self._input_shapes[input_name], policy)
        if reason is not None:
            try:  # widen for replay; malformed rows keep the compact form
                n = self._input_shapes[input_name][0]
                P = np.zeros((n, block.shape[1]), np.float32)
                P[rows.astype(np.int64)] = block
                qu = P
            except Exception:  # noqa: BLE001
                qu = block
            self.quarantine.put(input_name, qu, v, reason)
            self.stats.quarantined += 1
            return None
        self.stats.admitted += count
        return block, v

    def admit_batch(self, input_name: str, updates) -> list:
        """Careful per-update batch admission: full
        :func:`validate_update` on each update, so one poisoned or
        malformed update quarantines alone and the healthy remainder
        still batches.  The engine lands here only when
        :meth:`admit_batch_stacked` refused the fast path — policy
        budgets set, or something in the batch is structurally off or
        non-finite."""
        admitted = [self.admit(input_name, u, v) for u, v in updates]
        return [a for a in admitted if a is not None]

    # -- transactional firing (layer 2) --------------------------------------
    def _fused_trigger(self, engine, input_name: str, bucket: int,
                       screened: bool = False):
        """The clean-path firing program: trigger sweep, NaN/Inf
        validation of every written view, AND the commit/rollback select
        fused into ONE jitted dispatch.  When any written view comes out
        non-finite the program returns the *pre-firing* arrays instead
        (``where(ok, new, old)``), so the store can never go non-finite
        — without any host-side sync on the clean path.  The ``ok``
        scalar stays on device; only the abort *accounting* reads it,
        lazily (:meth:`sync`)."""
        key = (input_name, bucket, screened)
        hit = self._fused.get(key)
        if hit is None:
            # host-screened factors (batch admission) skip the
            # in-program screen: one fewer full pass over (u, v)
            screen_inputs = (self.config.validation.check_finite
                             and not screened)
            # the fused program is pure w.r.t. the views passed in —
            # engine-local state never enters the closure — so it is
            # shared through the engine's trigger cache: same-program
            # tenants in a fleet pay its trace/compile once
            hit = engine._cached_build(
                ("fused", input_name, bucket, screened, screen_inputs),
                lambda: self._build_fused(engine, input_name, bucket,
                                          screen_inputs))
            self._fused[key] = hit
        return hit

    def _build_fused(self, engine, input_name: str, bucket: int,
                     screen_inputs: bool):
        import jax
        import jax.numpy as jnp
        from repro.core.codegen import trigger_touched_views
        inner = engine._batched_trigger_fn(input_name, bucket)
        written, read_only = trigger_touched_views(
            engine._bucket_trigger(input_name, bucket))

        # flat tuples across the jit boundary (the dict-pytree
        # round-trip costs tens of µs per dispatch — same reason
        # build_trigger_fn stages its core this way).  No per-firing
        # flag output either: the threaded [input-rejects,
        # output-aborts] counter both reports aggregate health
        # (sync's single fetch) and, via its per-firing snapshots,
        # identifies WHICH firing failed in the rare abort walk.
        def core(wvals, rvals, u, v, nbad):
            views = dict(zip(written, wvals))
            views.update(zip(read_only, rvals))
            out = inner(views, u, v)
            ok_out = jnp.stack([jnp.isfinite(out[n]).all()
                                for n in written]).all()
            if screen_inputs:  # the admission screen, deferred here
                ok_in = jnp.isfinite(u).all() & jnp.isfinite(v).all()
            else:
                ok_in = jnp.bool_(True)
            ok = ok_in & ok_out
            # select-commit: elementwise where fuses into the
            # trigger's own update loops (lax.cond was measured
            # far slower here — its branch outputs are copied)
            new = tuple(jnp.where(ok, out[n], w)
                        for n, w in zip(written, wvals))
            bad = jnp.stack([~ok_in, ok_in & ~ok_out])
            return new, nbad + bad.astype(jnp.int32)

        core = jax.jit(core)

        def fused(views, u, v, nbad):
            new, nbad = core(tuple(views[n] for n in written),
                             tuple(views[n] for n in read_only),
                             u, v, nbad)
            views.update(zip(written, new))
            return views, nbad

        return (fused, written)

    def fire(self, engine, input_name: str, bucket: int, P, Q,
             screened: bool = False) -> None:
        """Run one trigger firing transactionally: fire → validate
        outputs → commit, or roll back atomically and raise
        :class:`FiringAborted`.  Rollback restores the pre-firing
        arrays, so the store and
        :class:`~repro.core.runtime.EngineStats` come back
        bit-identically.

        Unplanned firings take the fused fast path
        (``engine._guard_fast_path``): the NaN/Inf screens (both the
        deferred admission screen on the factors and the output check)
        and the commit/rollback select all run inside the firing's own
        XLA program, so a bad firing never reaches the store at all and
        the clean path pays no device sync.  The accounting — reject
        and rollback counters, quarantined factors — resolves within a
        sync window (every 32 firings) or on an explicit
        :meth:`sync`."""
        if engine._guard_fast_path:
            if len(self._pending) >= 32:
                self.sync()
            return self._fire_fused(engine, input_name, bucket, P, Q,
                                    screened)
        if not self.config.transactional:
            if engine.chaos is not None:
                engine.chaos.maybe_raise_in_trigger()
            return engine._fire_inner(input_name, bucket, P, Q)
        snap = take_snapshot(engine)
        try:
            if engine.chaos is not None:
                engine.chaos.maybe_raise_in_trigger()
            engine._fire_inner(input_name, bucket, P, Q)
            reason = self.validate_outputs(snap, engine.views)
            if reason is not None:
                raise FiringAborted(reason, input_name, "validate")
        except FiringAborted:
            restore_snapshot(engine, snap)
            self.stats.rollbacks += 1
            raise
        except Exception as e:  # noqa: BLE001 — any kernel error rolls back
            restore_snapshot(engine, snap)
            self.stats.rollbacks += 1
            raise FiringAborted(repr(e), input_name, "execute") from e

    def fire_rowlocal(self, engine, input_name: str, fn, rows, block,
                      v) -> None:
        """Transactional row-slab firing.  Always the snapshot path —
        the fused select-commit program is keyed to dense ``(P, Q)``
        triggers and a row-local firing is already cheap enough that a
        snapshot's O(changed bytes) cost doesn't dominate it."""
        if not self.config.transactional:
            if engine.chaos is not None:
                engine.chaos.maybe_raise_in_trigger()
            engine.views = fn(engine.views, rows, block, v)
            return
        snap = take_snapshot(engine)
        try:
            if engine.chaos is not None:
                engine.chaos.maybe_raise_in_trigger()
            engine.views = fn(engine.views, rows, block, v)
            reason = self.validate_outputs(snap, engine.views)
            if reason is not None:
                raise FiringAborted(reason, input_name, "validate")
        except FiringAborted:
            restore_snapshot(engine, snap)
            self.stats.rollbacks += 1
            raise
        except Exception as e:  # noqa: BLE001 — any kernel error rolls back
            restore_snapshot(engine, snap)
            self.stats.rollbacks += 1
            raise FiringAborted(repr(e), input_name, "execute") from e

    def _fire_fused(self, engine, input_name: str, bucket: int,
                    P, Q, screened: bool = False) -> None:
        fn, written = self._fused_trigger(engine, input_name, bucket,
                                          screened)
        if self._nbad is None:
            import jax.numpy as jnp
            self._nbad = jnp.zeros((2,), jnp.int32)
        try:
            if engine.chaos is not None:
                engine.chaos.maybe_raise_in_trigger()
            out, self._nbad = fn(engine.views, P, Q, self._nbad)
        except FiringAborted:
            self.stats.rollbacks += 1
            raise
        except Exception as e:  # noqa: BLE001
            self.stats.rollbacks += 1
            raise FiringAborted(repr(e), input_name, "execute") from e
        engine.views = out  # safe either way: bad firings self-selected out
        self._pending.append((self._nbad, input_name, P, Q))

    def sync(self) -> None:
        """Resolve deferred fused-firing outcomes.  The fused program
        threads a cumulative ``[input-rejects, output-aborts]`` count
        through every firing, so the clean case costs ONE fetch per
        sync window regardless of how many firings are pending; only
        when a count moved does the (rare) per-firing walk run — a
        poisoned update is reclassified as an admission reject (exactly
        as the host screen would have recorded it), a firing whose
        *outputs* went non-finite is counted as a rollback, and both
        quarantine the factors the in-program select rolled back."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        tail = tuple(int(x) for x in np.asarray(pending[-1][0]))
        if tail == self._nbad_seen:  # every pending firing was clean
            return
        prev_in, prev_out = self._nbad_seen
        self._nbad_seen = tail
        for nbad_after, input_name, P, Q in pending:
            cur_in, cur_out = (int(x) for x in np.asarray(nbad_after))
            if cur_in > prev_in:
                # deferred admission screen fired: the factors were
                # non-finite, the select kept the store untouched
                self.stats.admitted -= 1
                self.stats.quarantined += 1
                self.quarantine.put(
                    input_name, P, Q,
                    f"{input_name}: non-finite entries in update factors")
            elif cur_out > prev_out:
                self.stats.rollbacks += 1
                self.stats.aborted_firings += 1
                self.quarantine.put(
                    input_name, P, Q,
                    f"{input_name}: firing aborted — non-finite output, "
                    f"rolled back in-program")
            prev_in, prev_out = cur_in, cur_out

    # -- post-firing validation (layer 2) ------------------------------------
    def validate_outputs(self, snap: FiringSnapshot, views) -> Optional[str]:
        if not self.config.validation.check_outputs:
            return None
        return check_finite(views, changed_views(snap, views))

    def on_abort(self, input_name: str, P, Q, reason: str) -> None:
        """A firing rolled back: keep its factors for inspection/replay.

        If the factors themselves turn out non-finite (possible only on
        the fused path, where the admission screen is deferred into the
        firing program and an unrelated fault — e.g. an injected trigger
        raise — can abort the firing first), the record is reclassified
        as the admission reject the host screen would have produced."""
        self.stats.aborted_firings += 1
        P = np.asarray(P)
        Q = np.asarray(Q)
        if (self.config.validation.check_finite
                and not (np.isfinite(P).all() and np.isfinite(Q).all())):
            self.stats.admitted -= 1
            self.stats.quarantined += 1
            self.quarantine.put(
                input_name, P, Q,
                f"{input_name}: non-finite entries in update factors")
            return
        self.quarantine.put(input_name, P, Q,
                            f"{input_name}: firing aborted — {reason}")

    # -- post-commit (layer 3) -----------------------------------------------
    def after_firing(self, engine) -> None:
        if self.sentinel is None:
            return
        drifts = self.sentinel.after_firing(engine)
        if drifts is not None:
            self.stats.probes = self.sentinel.probes
            self.stats.drift_recoveries = self.sentinel.recoveries
            self.stats.max_drift = self.sentinel.max_drift
