"""Transactional trigger firings (guard layer 2).

A firing either commits completely or leaves the engine untouched.
Because jax arrays are immutable, the pre-firing snapshot is *free*: a
shallow copy of the view dict keeps the old device buffers alive while
the firing builds new ones; rollback is a pointer swap, so a rolled-back
store is bit-identical to the pre-firing store (the literal same
buffers).  The snapshot also captures the engine's host-side firing
bookkeeping (hybrid staleness counters, lazy-stale set, and a copy of
``EngineStats``) so an aborted firing is invisible there too.

The price of the guarantee is that guarded engines cannot donate view
buffers into the firing (`donate=True` would let XLA overwrite the very
arrays the snapshot holds); :class:`repro.core.runtime.IncrementalEngine`
refuses that combination at construction.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Set


class FiringAborted(RuntimeError):
    """A guarded firing failed and was rolled back.

    ``reason`` says why ("chaos: injected trigger fault", "non-finite
    output in view Z", a kernel error repr); ``stage`` is where it was
    caught (``"execute"`` — the trigger raised — or ``"validate"`` — it
    produced non-finite outputs).
    """

    def __init__(self, reason: str, input_name: str, stage: str):
        super().__init__(f"firing on {input_name!r} aborted [{stage}]: "
                         f"{reason}")
        self.reason = reason
        self.input_name = input_name
        self.stage = stage


@dataclass
class FiringSnapshot:
    """Everything a rollback must restore, captured by reference."""

    views: Dict[str, object]
    accum_rank: Dict[str, int]
    stale: Set[str]
    stats: object  # copied EngineStats dataclass
    # deferred-cascade window state (higher-order engines): pending
    # window factors, window-start base snapshots, firing counters
    cascade: Optional[tuple] = None


def take_snapshot(engine) -> FiringSnapshot:
    """Pre-firing snapshot: O(#views) pointer copies, no device work."""
    cascade_fn = getattr(engine, "_cascade_snapshot", None)
    return FiringSnapshot(views=dict(engine.views),
                          accum_rank=dict(engine._accum_rank),
                          stale=set(engine._stale),
                          stats=dataclasses.replace(engine.stats),
                          cascade=cascade_fn() if cascade_fn else None)


def restore_snapshot(engine, snap: FiringSnapshot) -> None:
    """Roll the engine back to ``snap`` — bit-identical: the restored
    views are the very arrays the snapshot kept alive."""
    engine.views = snap.views
    engine._accum_rank = snap.accum_rank
    engine._stale = snap.stale
    for f in dataclasses.fields(type(engine.stats)):
        setattr(engine.stats, f.name, getattr(snap.stats, f.name))
    if snap.cascade is not None:
        engine._cascade_restore(snap.cascade)


def changed_views(snap: FiringSnapshot,
                  views: Dict[str, object]) -> List[str]:
    """Names whose array identity changed since the snapshot — exactly
    the views this firing wrote (jax arrays are immutable, so a write
    always produces a new buffer)."""
    return [name for name, val in views.items()
            if snap.views.get(name) is not val]


def check_finite(views: Dict[str, object], names) -> Optional[str]:
    """Post-firing output validation: one fused device reduction over
    every written view, a single scalar sync.  Returns a reason naming
    the first offending view, or ``None`` when all outputs are finite.

    The probe itself is a cached jitted program
    (:func:`repro.core.codegen.build_finite_check`) keyed on the sorted
    name tuple, so the clean path never retraces."""
    names = sorted(names)
    if not names:
        return None
    from repro.core.codegen import build_finite_check
    flags = build_finite_check(names)({n: views[n] for n in names})
    if bool(flags.all()):
        return None
    bad = [n for n, ok in zip(names, list(flags)) if not bool(ok)]
    return f"non-finite output in view(s) {', '.join(bad)}"
