"""Deterministic fault injection (guard layer 4).

The recovery paths in this repo — quarantine, transactional rollback,
checkpoint-chain fallback, host eviction — are only trustworthy if they
are *exercised*, not merely written.  :class:`ChaosConfig` declares a
seeded fault mix and :class:`ChaosMonkey` threads it through the real
code paths:

  * ``poison_p``       — corrupt an incoming ``(u, v)`` update with
    NaN/Inf/huge entries before validation sees it
    (:class:`~repro.core.runtime.IncrementalEngine`);
  * ``trigger_raise_p`` — raise :class:`ChaosError` inside a trigger
    firing, standing in for a kernel/device fault (the transactional
    layer must roll back);
  * ``corrupt_checkpoint_p`` — flip bytes in a just-written checkpoint
    payload (:class:`~repro.dist.checkpoint.CheckpointManager`'s
    checksum verification and chain fallback must catch it);
  * ``kill_host_p``    — permanently swallow a host's heartbeats
    (:class:`~repro.dist.fault_tolerance.FaultTolerantController`'s
    timeout eviction and the supervisor restart loop must recover);
  * ``worker_crash_p`` — kill a fleet refresh worker *between* firing
    and commit (:mod:`repro.fleet`'s lease reclaim must roll back the
    uncommitted work and replay it from the tenant's update log);
  * ``lease_expiry_p`` — force-expire a worker's lease mid-claim (its
    commit must be fenced off and its work rolled back — the
    slow-worker-loses-the-race case, compressed);
  * ``slow_worker_p``  — stall a worker for ``slow_worker_s`` seconds
    inside its claim, so its lease expires *naturally* and reclaim +
    fencing race a still-running worker.

Every decision comes from one ``np.random.default_rng(seed)`` drawn in
call order, so a failing chaos run replays exactly under the same seed.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Set, Tuple

import numpy as np


class ChaosError(RuntimeError):
    """An injected fault (never raised by real failures)."""


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault-injection mix; all probabilities default to off."""

    seed: int = 0
    poison_p: float = 0.0
    poison_kind: str = "nan"          # "nan" | "inf" | "huge"
    trigger_raise_p: float = 0.0
    corrupt_checkpoint_p: float = 0.0
    kill_host_p: float = 0.0
    worker_crash_p: float = 0.0       # fleet: die after firing, pre-commit
    lease_expiry_p: float = 0.0       # fleet: lease yanked mid-claim
    slow_worker_p: float = 0.0        # fleet: stall inside a claim …
    slow_worker_s: float = 0.0        # … for this many (injected) seconds

    def monkey(self) -> "ChaosMonkey":
        return ChaosMonkey(self)


class ChaosMonkey:
    """Stateful injector for one :class:`ChaosConfig` (owns the rng and
    the fault counters; construct one per run)."""

    def __init__(self, config: ChaosConfig):
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        self._killed: Set[int] = set()
        self.poisoned = 0
        self.raises = 0
        self.corruptions = 0
        self.kills = 0
        self.worker_crashes = 0
        self.lease_expiries = 0
        self.slowdowns = 0

    # -- update poisoning ----------------------------------------------------
    def poison_update(self, u, v) -> Tuple[np.ndarray, np.ndarray]:
        """With probability ``poison_p``, corrupt one factor entry.

        ``"nan"``/``"inf"`` plant a non-finite entry (caught by the
        finite check); ``"huge"`` plants a finite ~1e38 entry whose
        outer product overflows f32 (caught by the norm budget or the
        post-firing output validation).  Always returns host copies so
        the caller's arrays are never mutated.
        """
        cfg = self.config
        if cfg.poison_p <= 0 or self._rng.random() >= cfg.poison_p:
            return u, v
        u = np.array(u, dtype=np.float32, copy=True)
        v = np.array(v, dtype=np.float32, copy=True)
        side = u if self._rng.random() < 0.5 else v
        idx = (int(self._rng.integers(side.shape[0])),
               int(self._rng.integers(side.shape[1])))
        side[idx] = {"nan": np.nan, "inf": np.inf,
                     "huge": np.float32(1e38)}[cfg.poison_kind]
        self.poisoned += 1
        return u, v

    # -- trigger faults ------------------------------------------------------
    def maybe_raise_in_trigger(self) -> None:
        cfg = self.config
        if cfg.trigger_raise_p > 0 and self._rng.random() < cfg.trigger_raise_p:
            self.raises += 1
            raise ChaosError("injected trigger fault")

    # -- checkpoint corruption -----------------------------------------------
    def maybe_corrupt_checkpoint(self, payload_path: str) -> bool:
        """With probability ``corrupt_checkpoint_p``, XOR-flip a short
        byte run inside the payload file (past the zip header, so the
        archive still opens and only the array bytes are wrong — the
        realistic bit-rot case checksums exist for)."""
        cfg = self.config
        if (cfg.corrupt_checkpoint_p <= 0
                or self._rng.random() >= cfg.corrupt_checkpoint_p):
            return False
        size = os.path.getsize(payload_path)
        if size < 256:
            return False
        off = int(self._rng.integers(size // 2, size - 16))
        with open(payload_path, "r+b") as f:
            f.seek(off)
            chunk = bytearray(f.read(8))
            f.seek(off)
            f.write(bytes(b ^ 0xFF for b in chunk))
        self.corruptions += 1
        return True

    # -- host kills ----------------------------------------------------------
    def should_kill_host(self, host: int) -> bool:
        """Once killed, a host stays silent (its heartbeats are swallowed
        until :meth:`revive`), so the controller's timeout eviction sees a
        realistic permanent failure, not a flicker."""
        if host in self._killed:
            return True
        cfg = self.config
        if cfg.kill_host_p > 0 and self._rng.random() < cfg.kill_host_p:
            self._killed.add(host)
            self.kills += 1
            return True
        return False

    def revive(self, host: int) -> None:
        self._killed.discard(host)

    def killed_hosts(self) -> Set[int]:
        return set(self._killed)

    # -- fleet worker faults (repro.fleet) -----------------------------------
    def should_crash_worker(self) -> bool:
        """Crash this worker NOW — after it fired but before it commits.

        The scheduler abandons the claim without releasing the lease
        (exactly what a dead process looks like to the lease store); the
        TTL expires, another worker reclaims, rolls the uncommitted
        firing back, and replays from the tenant's update log."""
        cfg = self.config
        if cfg.worker_crash_p > 0 and self._rng.random() < cfg.worker_crash_p:
            self.worker_crashes += 1
            return True
        return False

    def should_expire_lease(self) -> bool:
        """Yank the current claim's lease before its commit, so the
        commit hits the fencing check and the work is rolled back — the
        deterministic compression of a worker losing a TTL race."""
        cfg = self.config
        if cfg.lease_expiry_p > 0 and self._rng.random() < cfg.lease_expiry_p:
            self.lease_expiries += 1
            return True
        return False

    def slow_worker_delay(self) -> float:
        """Seconds to stall inside the claim (0.0 = healthy).  Injected
        through the scheduler's clock/sleep, so with a fake clock the
        stall is virtual but still long enough to expire the lease."""
        cfg = self.config
        if cfg.slow_worker_p > 0 and self._rng.random() < cfg.slow_worker_p:
            self.slowdowns += 1
            return float(cfg.slow_worker_s)
        return 0.0


def as_monkey(chaos: Optional[object]) -> Optional[ChaosMonkey]:
    """Accept a :class:`ChaosConfig`, a :class:`ChaosMonkey`, or None.

    Passing one *monkey* to several components (engine + checkpoint
    manager + controller) makes them share a draw sequence; passing the
    *config* gives each component its own independent seeded stream.
    """
    if chaos is None or isinstance(chaos, ChaosMonkey):
        return chaos
    if isinstance(chaos, ChaosConfig):
        return chaos.monkey()
    raise TypeError(f"chaos must be ChaosConfig | ChaosMonkey | None, "
                    f"got {type(chaos).__name__}")
