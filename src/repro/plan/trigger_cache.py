"""Persistent compiled-trigger cache (ROADMAP: stop re-jitting per
(bucket, mesh) on every new engine instance).

A compiled trigger is a pure function of (program fingerprint, trigger
kind, input, bucket rank, plan partition, mesh, backend options) — none
of it engine-local — so the jitted callable can outlive the
``IncrementalEngine`` that first built it.  The cache stores callables
under exactly that key: a second engine constructed over a structurally
identical program at the same sizes, executing the same plan on the
same mesh, gets the *same* function object back, and jax's jit cache
(keyed on function identity) serves the compiled executable with no
re-trace and no re-compile.

Process-level by design: XLA executables are not picklable, so true
on-disk persistence is delegated to jax's own compilation cache
(``jax.config.update("jax_compilation_cache_dir", …)``), which composes
with this cache — the key here removes the *re-trace*, the jax cache
removes the *re-compile* across processes.

Engines use the process-global instance whenever they execute a plan;
pass ``trigger_cache=TriggerCache()`` for an isolated one (tests).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Tuple


class TriggerCache:
    """Thread-safe (key → compiled trigger callable) map with hit/miss
    counters.  Keys must be hashable tuples; values are the callables
    produced by the codegen builders."""

    def __init__(self):
        self._fns: Dict[Tuple, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: Tuple, builder: Callable[[], Callable]
                     ) -> Callable:
        """Return the cached callable for ``key``, building (and
        retaining) it on first use."""
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                return fn
        fn = builder()  # build outside the lock: jit tracing can be slow
        with self._lock:
            won = self._fns.setdefault(key, fn)
            if won is fn:
                self.misses += 1
            else:
                self.hits += 1
        return won

    def __len__(self) -> int:
        return len(self._fns)

    def __contains__(self, key: Tuple) -> bool:
        return key in self._fns

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._fns), "hits": self.hits,
                "misses": self.misses}


_GLOBAL = TriggerCache()


def global_trigger_cache() -> TriggerCache:
    """The process-wide cache engines share by default."""
    return _GLOBAL


def mesh_cache_key(mesh, axis: Optional[str] = None) -> Optional[Tuple]:
    """Hashable identity of a mesh for trigger-cache keying.

    Includes the concrete device ids in order: the distributed trigger
    builders close over ``NamedSharding(mesh, …)``, so the compiled
    callable is pinned to that exact device placement — two meshes with
    the same shape over different devices (or a permutation, e.g. after
    an elastic reshape) must NOT share cache entries.  Two meshes over
    the identical device sequence compile identical triggers and do
    share."""
    if mesh is None:
        return None
    devs = mesh.devices.ravel()
    return (tuple(mesh.shape.items()),
            axis or mesh.axis_names[0],
            devs[0].platform if len(devs) else "cpu",
            tuple(int(d.id) for d in devs))
