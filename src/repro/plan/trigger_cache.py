"""Persistent compiled-trigger cache (ROADMAP: stop re-jitting per
(bucket, mesh) on every new engine instance).

A compiled trigger is a pure function of (program fingerprint, trigger
kind, input, bucket rank, plan partition, mesh, backend options) — none
of it engine-local — so the jitted callable can outlive the
``IncrementalEngine`` that first built it.  The cache stores callables
under exactly that key: a second engine constructed over a structurally
identical program at the same sizes, executing the same plan on the
same mesh, gets the *same* function object back, and jax's jit cache
(keyed on function identity) serves the compiled executable with no
re-trace and no re-compile.

Process-level by design: XLA executables are not picklable, so true
on-disk persistence is delegated to jax's own compilation cache
(``jax.config.update("jax_compilation_cache_dir", …)``), which composes
with this cache — the key here removes the *re-trace*, the jax cache
removes the *re-compile* across processes.

Engines use the process-global instance whenever they execute a plan;
pass ``trigger_cache=TriggerCache()`` for an isolated one (tests).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple


class TriggerCache:
    """Thread-safe (key → compiled trigger callable) map with hit/miss
    counters.  Keys must be hashable tuples; values are the callables
    produced by the codegen builders.

    Fleet workers read AND populate this concurrently (N tenants share
    one cache), so every access — including ``len``/``in``/``stats`` —
    holds the lock; ``get_or_build`` builds outside it (jit tracing is
    slow) and lets the first writer win.  ``capacity`` bounds the entry
    count with LRU eviction (``None`` = unbounded, the default): a
    multi-tenant service over many distinct programs must not grow
    compiled-trigger state without bound.
    """

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be ≥ 1, got {capacity}")
        self.capacity = capacity
        self._fns: "OrderedDict[Tuple, Callable]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key: Tuple, builder: Callable[[], Callable]
                     ) -> Callable:
        """Return the cached callable for ``key``, building (and
        retaining) it on first use."""
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                self._fns.move_to_end(key)
                return fn
        fn = builder()  # build outside the lock: jit tracing can be slow
        with self._lock:
            won = self._fns.setdefault(key, fn)
            self._fns.move_to_end(key)
            if won is fn:
                self.misses += 1
                self._evict_over_capacity()
            else:
                self.hits += 1
        return won

    def _evict_over_capacity(self) -> None:
        # caller holds the lock
        while self.capacity is not None and len(self._fns) > self.capacity:
            self._fns.popitem(last=False)
            self.evictions += 1

    def evict(self, key: Tuple) -> bool:
        """Drop one entry (e.g. a retired tenant's program); True if it
        was present.  The callable itself stays valid for holders — only
        future lookups rebuild."""
        with self._lock:
            return self._fns.pop(key, None) is not None

    def __len__(self) -> int:
        with self._lock:
            return len(self._fns)

    def __contains__(self, key: Tuple) -> bool:
        with self._lock:
            return key in self._fns

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._fns), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}


_GLOBAL = TriggerCache()


def global_trigger_cache() -> TriggerCache:
    """The process-wide cache engines share by default."""
    return _GLOBAL


def mesh_cache_key(mesh, axis: Optional[str] = None) -> Optional[Tuple]:
    """Hashable identity of a mesh for trigger-cache keying.

    Includes the concrete device ids in order: the distributed trigger
    builders close over ``NamedSharding(mesh, …)``, so the compiled
    callable is pinned to that exact device placement — two meshes with
    the same shape over different devices (or a permutation, e.g. after
    an elastic reshape) must NOT share cache entries.  Two meshes over
    the identical device sequence compile identical triggers and do
    share."""
    if mesh is None:
        return None
    devs = mesh.devices.ravel()
    return (tuple(mesh.shape.items()),
            axis or mesh.axis_names[0],
            devs[0].platform if len(devs) else "cpu",
            tuple(int(d.id) for d in devs))
