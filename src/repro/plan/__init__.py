"""repro.plan — cost-based adaptive execution planning for IVM programs.

Public API:

    from repro.plan import (
        WorkloadDescriptor, ViewPlan, MaintenancePlan,
        plan_program, plan_for_engine, program_fingerprint,
        AdaptivePlanner, TriggerCache, global_trigger_cache,
    )

A :class:`MaintenancePlan` tells the engine, per maintained view,
whether to propagate factored deltas, re-evaluate, or switch between
the two at a rank threshold — plus which intermediates to keep eagerly
materialized.  :class:`AdaptivePlanner` refits the plan online from
observed firings; :class:`TriggerCache` makes compiled triggers survive
across engine instances.  See docs/planner.md.
"""

from .planner import (MaintenancePlan, ViewPlan, WorkloadDescriptor,
                      firing_cost_flops, plan_for_engine, plan_program,
                      program_fingerprint, solver_resolve_strategy,
                      static_plan, trigger_chain_costs)
from .trigger_cache import TriggerCache, global_trigger_cache, mesh_cache_key
from .adaptive import AdaptivePlanner
from .calibrate import calibrate_cost_scale, calibrate_op_cost_scales

__all__ = [
    "MaintenancePlan", "ViewPlan", "WorkloadDescriptor",
    "plan_for_engine", "plan_program", "program_fingerprint",
    "static_plan", "firing_cost_flops", "trigger_chain_costs",
    "solver_resolve_strategy",
    "calibrate_cost_scale", "calibrate_op_cost_scales",
    "TriggerCache", "global_trigger_cache", "mesh_cache_key",
    "AdaptivePlanner",
]
