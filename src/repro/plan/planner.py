"""Cost-based maintenance planning for compiled IVM programs (§5–§7).

LINVIEW's central economic claim is that incremental maintenance only
wins when you *choose* per view: factored delta propagation while the
update rank stays small, re-evaluation once the avalanche makes the
delta as expensive as recomputing (§7 crossover), and a hybrid of the
two when the workload straddles the boundary.  The engine has always
had the cost model (:mod:`repro.core.cost`) and the compiled triggers
(:mod:`repro.core.compiler`); this module connects them into an
executable **maintenance plan**:

  * a per-view **strategy** — ``"incremental"`` | ``"reeval"`` |
    ``"hybrid"`` (incremental until a rank/staleness threshold, then
    re-evaluate);
  * a DAG-level **materialization choice** — an intermediate view is
    kept eagerly maintained iff its amortized per-firing delta cost
    beats recomputing it (and its consumers) on demand, à la §5's
    intermediate-view discussion;
  * the **workload descriptor** the choices were priced under, so an
    adaptive planner can detect drift and re-plan online.

Plans are pure data (JSON-serializable) — execution lives in
:class:`repro.core.runtime.IncrementalEngine`, compiled-trigger reuse in
:mod:`repro.plan.trigger_cache`.  See docs/planner.md.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, replace
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.codegen import trigger_touched_views
from repro.core.compiler import CompiledProgram, compile_program
from repro.core.cost import (batch_crossover_rank, batched_strategy,
                             cholesky_factor_cost, cholesky_update_cost,
                             expr_cost, expr_cost_kinds,
                             rowlocal_crossover_fraction, shape_of,
                             triangular_solve_cost)
from repro.core.program import Program

STRATEGIES = ("incremental", "reeval", "hybrid")


# ---------------------------------------------------------------------------
# workload descriptor
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadDescriptor:
    """What the planner prices against: the update stream shape.

    ``update_rank`` × ``batch_size`` is the typical stacked rank of one
    trigger firing; ``rank_lo`` / ``rank_hi`` bound the distribution
    (default: the expectation itself — a point mass).  A view whose §7
    crossover lies above ``rank_hi`` is always incremental, below
    ``rank_lo`` always re-evaluated, and in between goes hybrid.
    ``reads_per_firing`` is how often the store is *read* relative to
    firings — the materialization lever: intermediates nobody reads can
    be maintained lazily.

    ``cost_scale`` corrects the FLOP model for the backend: the
    wall-clock cost of one incremental-sweep FLOP relative to one
    re-evaluation FLOP (``1.0`` = trust FLOPs).  Skinny rank-K updates
    run at a far worse rate than the dense matmuls re-evaluation is
    made of — >10x on CPU BLAS — so the *effective* §7 crossover sits
    at ``K*/cost_scale``.  Measure it with
    :func:`repro.plan.calibrate_cost_scale`.

    ``chain_aware`` additionally prices the trigger's shared delta
    chain into each view's sweep cost.  The assigns of one trigger
    (``ΔZ``-style intermediate factors) are computed once per firing and
    amortize across every view maintained incrementally — but when
    siblings cross to re-evaluation, a *lone* incremental view keeps
    the whole chain it reads alive and bears its full cost.  The naive
    per-view ``2·K·n·m`` sweep price ignores that, overestimating how
    long incremental maintenance keeps winning (and underestimating the
    firing costs a fleet scheduler prioritizes by).  Off by default so
    declared-workload plans stay stable; the fleet turns it on.

    ``op_cost_scales`` refines the *re-evaluation* side per op kind
    (keys ``"matmul"`` / ``"inverse"`` / ``"other"``, values =
    wall-clock per FLOP relative to a dense matmul FLOP; missing kinds
    default to 1.0).  An OLS view whose re-evaluation is mostly an n×n
    ``Inverse`` runs those FLOPs several× slower than the matmul rate
    the plain count assumes, so its true crossover sits above the
    unscaled ``K*`` — exactly the cells straddling the §7 boundary that
    a single global scale misplans.  Measure with
    :func:`repro.plan.calibrate_op_cost_scales`.
    """

    update_rank: int = 1          # per-update factored rank k
    batch_size: int = 1           # T updates coalesced per firing
    rank_lo: Optional[int] = None
    rank_hi: Optional[int] = None
    reads_per_firing: float = 1.0
    # expected fraction of input rows one update touches (None = dense /
    # unknown).  With a fraction set, views the compiler proved row-local
    # (Trigger.carriers) are priced at the row-slab sweep cost — their
    # effective §7 crossover scales by 1/fraction, so containment keeps
    # incremental maintenance winning at stacked ranks where a dense
    # sweep would already have crossed to re-evaluation.
    affected_fraction: Optional[float] = None
    cost_scale: float = 1.0       # wall-clock per-FLOP cost of the sweep
    #                               relative to re-evaluation (calibrated)
    chain_aware: bool = False     # price the shared delta chain into sweeps
    op_cost_scales: Optional[Dict[str, float]] = None
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    # higher-order (deferred-cascade) capability: max depth plan_program
    # may assign per view (1 = classic first order, no depth pricing),
    # the engine's fold window base, and the stacked-window rank cap —
    # the extra-state/QR-recompression side of the depth trade-off
    max_order: int = 1
    fold_window: int = 8
    max_fold_rank: int = 64

    def effective_reeval_flops(self, kinds: Dict[str, float]) -> float:
        """Σ kind_flops × kind_scale — FLOPs in matmul-equivalents."""
        if not self.op_cost_scales:
            return sum(kinds.values())
        return sum(f * self.op_cost_scales.get(k, 1.0)
                   for k, f in kinds.items())

    def expected_rank(self) -> int:
        return max(1, int(self.update_rank) * int(self.batch_size))

    def rank_bounds(self) -> Tuple[int, int]:
        k = self.expected_rank()
        lo = k if self.rank_lo is None else max(1, int(self.rank_lo))
        # hi floors at lo so a descriptor with only rank_lo set can
        # never produce inverted bounds (hi < lo would misclassify
        # always-past-crossover workloads as incremental)
        hi = max(lo, k) if self.rank_hi is None else max(lo, int(self.rank_hi))
        return lo, hi


# ---------------------------------------------------------------------------
# plan format
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ViewPlan:
    """One maintained view's refresh policy.

    ``materialize=False`` is only sound for views that no trigger's
    surviving factor blocks read — :func:`plan_program` guarantees this
    (``_trigger_read_views`` ∪ outputs ∪ inputs are never lazy); a
    hand-crafted plan that unmaterializes a factor-block-read view
    feeds stale values to incremental consumers.  Views read only by
    *re-evaluated* consumers are safe: the engine pulls stale lazy
    views into the recompute closure."""

    view: str
    strategy: str                       # "incremental" | "reeval" | "hybrid"
    threshold_rank: Optional[int] = None  # hybrid: switch to reeval here
    materialize: bool = True            # False → lazy (recompute on read)
    crossover_rank: int = 0             # §7 crossover (diagnostic)
    reeval_flops: float = 0.0           # view re-evaluation cost (diagnostic)
    # delta depth: 1 = per-firing maintenance (strategy above applies);
    # o >= 2 = deferred cascade — the engine folds this view's update
    # window every fold_window**(o-1) firings (or at the next read)
    # instead of sweeping per firing
    order: int = 1
    # row-local containment: True when the compiler proved this view's
    # delta row-support-preserving under every trigger that maintains it
    # AND the workload's affected fraction sits under the traffic
    # crossover — its strategy above was priced at the row-slab sweep
    # cost, and fleet firing pricing scales its sweep by the fraction
    row_local: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.order < 1:
            raise ValueError(f"order must be >= 1, got {self.order}")


@dataclass(frozen=True)
class MaintenancePlan:
    """Executable maintenance plan for one compiled program.

    ``fingerprint`` ties the plan to the (program, dims) it was priced
    for — the engine refuses to execute a plan for a different program,
    and the compiled-trigger cache keys on it so identical plans share
    jitted triggers across engine instances.
    """

    fingerprint: str
    workload: WorkloadDescriptor
    views: Dict[str, ViewPlan]
    mesh_key: Optional[Tuple] = None

    # -- per-firing decision -------------------------------------------------
    def decide(self, stacked_rank: int, accum_rank: Dict[str, int]
               ) -> Tuple[FrozenSet[str], FrozenSet[str]]:
        """Partition views for a firing at ``stacked_rank``.

        Returns ``(reeval_due, lazy_skip)``: views to re-evaluate inside
        the firing, and unmaterialized views to skip (marked stale,
        recomputed on read).  ``accum_rank`` is the engine's per-view
        applied rank since the view's last re-evaluation — the hybrid
        staleness counter: a hybrid view re-evaluates when either this
        firing's rank or the accumulated rank crosses its threshold.
        """
        reeval, lazy = set(), set()
        for name, vp in self.views.items():
            if vp.order >= 2:
                # deferred views are the engine's business: neither swept,
                # re-evaluated, nor lazy-skipped per firing — their window
                # folds on the engine's cascade schedule
                continue
            if not vp.materialize:
                lazy.add(name)
                continue
            if vp.strategy == "reeval":
                reeval.add(name)
            elif vp.strategy == "hybrid":
                thr = max(1, int(vp.threshold_rank or 1))
                # accumulated rank is reset to 0 whenever the view is
                # re-evaluated, so this single check covers both "this
                # firing is too big" and "staleness built up"
                if accum_rank.get(name, 0) + stacked_rank >= thr:
                    reeval.add(name)
        return frozenset(reeval), frozenset(lazy)

    def strategy(self, view: str) -> str:
        return self.views[view].strategy

    def lazy_views(self) -> FrozenSet[str]:
        return frozenset(n for n, vp in self.views.items()
                         if not vp.materialize)

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "fingerprint": self.fingerprint,
            "workload": asdict(self.workload),
            "views": {n: asdict(vp) for n, vp in sorted(self.views.items())},
            "mesh_key": list(self.mesh_key) if self.mesh_key else None,
        }, indent=1, default=list)

    @staticmethod
    def from_json(s: str) -> "MaintenancePlan":
        d = json.loads(s)
        wl = d["workload"]
        for k in ("mesh_shape", "mesh_axes"):
            if wl.get(k) is not None:
                wl[k] = tuple(wl[k])

        def untuple(x):  # JSON lists back to the nested-tuple mesh key
            return tuple(untuple(i) for i in x) if isinstance(x, list) else x

        return MaintenancePlan(
            fingerprint=d["fingerprint"],
            workload=WorkloadDescriptor(**wl),
            views={n: ViewPlan(**vp) for n, vp in d["views"].items()},
            mesh_key=untuple(d["mesh_key"]) if d.get("mesh_key") else None)


# ---------------------------------------------------------------------------
# fingerprinting
# ---------------------------------------------------------------------------


def program_fingerprint(program: Program,
                        binding: Optional[Dict[str, int]] = None) -> str:
    """Stable identity of (program structure, concrete dims).

    Two engines compiled from structurally identical programs at the
    same sizes produce the same fingerprint — that is what lets a plan
    (and its cached compiled triggers) survive across
    ``IncrementalEngine`` instances.
    """
    binding = dict(program.dims if binding is None else binding)
    payload = repr(program) + "|" + repr(sorted(binding.items()))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def _trigger_read_views(compiled: CompiledProgram) -> FrozenSet[str]:
    """Views some trigger's factor blocks *read* (old values).

    The delta chain assumes every referenced view is current at firing
    time, so these can never be maintained lazily."""
    read: set = set()
    for trig in compiled.triggers.values():
        _, ro = trigger_touched_views(trig)
        read |= set(ro)
        for a in trig.assigns:
            read |= set(a.expr.free_vars())
    return frozenset(read)


def _rowlocal_closed_views(compiled: CompiledProgram) -> FrozenSet[str]:
    """Views whose delta is row-support-preserving under EVERY trigger
    that maintains them in factored form (``Trigger.carriers`` —
    compile-time §4 closure).  A view that is row-local under updates
    to one input but widens under another cannot be priced at the
    row-slab cost: the plan is per-view, not per-(view, input)."""
    status: Dict[str, bool] = {}
    for trig in compiled.triggers.values():
        for up in trig.updates:
            if up.kind != "lowrank":
                continue
            ok = trig.carriers.get(up.view) == "row_local"
            status[up.view] = status.get(up.view, True) and ok
    return frozenset(n for n, ok in status.items() if ok)


def plan_program(compiled, workload: WorkloadDescriptor, *,
                 binding: Optional[Dict[str, int]] = None,
                 mesh=None, mesh_axis: Optional[str] = None
                 ) -> MaintenancePlan:
    """Price every maintained view under ``workload`` and emit a plan.

    Strategy per view (the §7 crossover ``K* = reeval/(2·n·m)``,
    divided by the workload's calibrated ``cost_scale`` to get the
    effective wall-clock crossover ``K*_eff``):

      * ``rank_hi < K*_eff``  → ``incremental`` — the factored sweep
        always wins at the ranks this workload produces;
      * ``rank_lo ≥ K*_eff``  → ``reeval`` — the avalanche always loses;
      * otherwise             → ``hybrid``, ``threshold_rank = K*_eff``.

    Materialization (intermediates only): a view that no trigger reads
    and no output needs is kept eagerly maintained iff its per-firing
    apply cost beats ``reads_per_firing ×`` its recompute cost —
    otherwise it goes lazy (skipped during firings, recomputed on
    read).

    Depth (``workload.max_order >= 2`` only): each view is additionally
    priced at depths 2..max_order.  At depth ``o`` the engine folds a
    window of ``w = fold_window**(o-1)`` firings into one stacked sweep
    (capped at ``max_fold_rank`` by re-compression) — but a read forces
    the fold early, so the *effective* window is
    ``min(w, 1/reads_per_firing)``.  The smallest depth whose amortized
    per-firing fold cost beats the best depth-1 cost by >= 2x is
    assigned (inputs and trigger-read views stay first-order, and
    producer depths are clamped to their consumers' so no trigger ever
    reads a stale deferred view).  Any plan with a depth >= 2 view
    materializes every view — fold bases and lazy recomputation do not
    mix.
    """
    if isinstance(compiled, Program):
        compiled = compile_program(compiled)
    program = compiled.program
    binding = dict(program.dims if binding is None else binding)
    lo, hi = workload.rank_bounds()
    outputs = set(program.output_names())
    never_lazy = _trigger_read_views(compiled) | outputs | set(program.inputs)
    rl_closed = _rowlocal_closed_views(compiled)
    frac = workload.affected_fraction

    views: Dict[str, ViewPlan] = {}
    shapes: Dict[str, Tuple[int, int]] = {}
    reeval_effs: Dict[str, float] = {}
    for st in program.statements:
        name = st.target.name
        shape = shape_of(st.target, binding)
        reeval = expr_cost(st.expr, binding).flops
        # per-op-kind scaling: crossover priced in matmul-equivalent
        # FLOPs, so inverse-heavy views (OLS) land on the right side
        reeval_eff = workload.effective_reeval_flops(
            expr_cost_kinds(st.expr, binding))
        kstar = batch_crossover_rank(shape, reeval_eff)
        # cardinality-based selection: a row-local-closed view under a
        # contained workload sweeps only frac·n rows, so its effective
        # crossover (both against cost_scale AND the hybrid threshold)
        # scales by 1/frac — incremental keeps winning at ranks where
        # the dense sweep would already re-evaluate
        row_local = (frac is not None and name in rl_closed
                     and 0.0 < frac
                     and frac <= rowlocal_crossover_fraction(
                         shape, workload.expected_rank()))
        kstar_rl = kstar if not row_local else \
            max(kstar, int(kstar / max(frac, 1e-9)))
        k_eff = max(1, int(kstar_rl / max(workload.cost_scale, 1e-12)))
        if hi < k_eff:
            strat, thr = "incremental", None
        elif lo >= k_eff:
            strat, thr = "reeval", None
        else:
            strat, thr = "hybrid", k_eff
        materialize = True
        if name not in never_lazy:
            n, m = shape
            k = workload.expected_rank()
            sweep_rows = n * frac if row_local else n
            maintain = 2.0 * k * sweep_rows * m        # per-firing sweep
            on_demand = workload.reads_per_firing * reeval_eff
            materialize = maintain <= on_demand
        # every statement view is depth-eligible; _resolve_depths then
        # clamps producers to their consumers' depth so per-firing delta
        # chains never read a stale deferred view
        order = _price_depth(workload, shape, reeval_eff)
        shapes[name], reeval_effs[name] = shape, reeval_eff
        views[name] = ViewPlan(view=name, strategy=strat,
                               threshold_rank=thr, materialize=materialize,
                               crossover_rank=kstar, reeval_flops=reeval,
                               order=order, row_local=row_local)
    if workload.chain_aware:
        _reprice_with_chain(compiled, binding, workload, lo, hi,
                            views, shapes, reeval_effs)
    _resolve_depths(program, views)

    from .trigger_cache import mesh_cache_key
    wl = workload
    if mesh is not None and wl.mesh_shape is None:
        wl = replace(wl, mesh_shape=tuple(mesh.shape.values()),
                     mesh_axes=tuple(mesh.axis_names))
    return MaintenancePlan(
        fingerprint=program_fingerprint(program, binding),
        workload=wl, views=views,
        mesh_key=mesh_cache_key(mesh, mesh_axis))


def _price_depth(workload: WorkloadDescriptor, shape: Tuple[int, int],
                 reeval_eff: float) -> int:
    """Smallest depth whose amortized fold cost beats the best depth-1
    per-firing cost by >= 2x (1 when none does, or max_order is 1).

    Depth-1 per-firing cost: min(sweep, re-evaluate).  Depth-o: one fold
    every ``w_eff`` firings — a stacked sweep at the window rank (capped
    by re-compression) or a re-evaluation, whichever wins — where
    ``w_eff = min(fold_window**(o-1), 1/reads_per_firing)`` because a
    read forces the fold early.  With reads on every firing (the default
    descriptor) w_eff is 1 and no depth is ever assigned: depth buys
    nothing without read sparsity, exactly the memory-vs-work trade-off
    docs/higher_order.md plots.
    """
    if workload.max_order < 2:
        return 1
    n, m = shape
    k = workload.expected_rank()
    scale = max(workload.cost_scale, 1e-12)
    rho = max(float(workload.reads_per_firing), 0.0)
    best_order = 1
    best = min(scale * 2.0 * k * n * m, reeval_eff)
    for o in range(2, int(workload.max_order) + 1):
        w = float(max(1, workload.fold_window) ** (o - 1))
        w_eff = max(1.0, min(w, (1.0 / rho) if rho > 0 else w))
        kw = float(k) * w_eff
        if workload.max_fold_rank:
            kw = min(kw, float(workload.max_fold_rank))
        fold_cost = min(scale * 2.0 * kw * n * m, reeval_eff)
        amortized = fold_cost / w_eff
        if amortized * 2.0 <= best:
            best_order, best = o, amortized
    return best_order


def _resolve_depths(program: Program, views: Dict[str, ViewPlan]) -> None:
    """Clamp each view's depth to its consumers' (reverse program order)
    and, if any depth >= 2 survives, force every view materialized —
    the engine's deferred cascade refuses lazy/deferred mixing."""
    names = {st.target.name for st in program.statements}
    consumers: Dict[str, List[str]] = {}
    for st in program.statements:
        for v in st.expr.free_vars():
            if v in names and v != st.target.name:
                consumers.setdefault(v, []).append(st.target.name)
    eff: Dict[str, int] = {}
    for st in reversed(program.statements):
        name = st.target.name
        o = views[name].order
        for c in consumers.get(name, ()):
            o = min(o, eff[c])
        eff[name] = o
    deferred = any(o >= 2 for o in eff.values())
    for name, vp in views.items():
        o = eff.get(name, 1)
        if o != vp.order or (deferred and not vp.materialize):
            views[name] = replace(vp, order=o,
                                  materialize=vp.materialize or deferred)


def trigger_chain_costs(trig, binding: Dict[str, int]
                        ) -> Tuple[Dict[str, float], Dict[str, FrozenSet[str]]]:
    """Price one trigger's shared delta chain.

    Returns ``(assign_flops, view_deps)``: FLOPs of each trigger assign
    at the trigger's compiled rank, and — per updated view — the
    transitive set of assign names its factor blocks read.  The chain is
    computed once per firing and shared by every view still maintained
    incrementally; these two maps are what lets a planner decide who
    pays for it when some views re-evaluate instead.
    """
    assign_flops: Dict[str, float] = {}
    assign_deps: Dict[str, FrozenSet[str]] = {}
    for a in trig.assigns:
        direct = set(a.expr.free_vars()) & set(assign_flops)
        closure = set(direct)
        for d in direct:
            closure |= assign_deps[d]
        assign_flops[a.name] = expr_cost(a.expr, binding).flops
        assign_deps[a.name] = frozenset(closure)
    view_deps: Dict[str, FrozenSet[str]] = {}
    for up in trig.updates:
        roots = {n for n in (up.u, up.v, up.d)
                 if n is not None and n in assign_flops}
        closure = set(roots)
        for r in roots:
            closure |= assign_deps[r]
        view_deps[up.view] = frozenset(closure)
    return assign_flops, view_deps


def _reprice_with_chain(compiled: CompiledProgram, binding, workload,
                        lo: int, hi: int, views: Dict[str, ViewPlan],
                        shapes, reeval_effs) -> None:
    """Chain-aware second pass over a freshly priced plan (in place).

    Per trigger, the delta-chain assigns a view's sweep reads are split
    evenly among the views that still read them incrementally; a view's
    per-rank sweep cost becomes ``2·n·m + chain_share`` and its
    crossover drops accordingly.  Demoting a view to re-evaluation
    shifts its chain share onto the surviving readers — so the pass
    iterates to a fixed point (≤ one demotion per round, bounded by the
    view count).  This is exactly the "lone incremental view keeps the
    shared chain alive" correction: with every sibling re-evaluated,
    the last reader bears the whole chain.
    """
    chains = [(trigger_chain_costs(trig, binding), max(trig.rank, 1))
              for trig in compiled.triggers.values()]
    for _ in range(len(views) + 1):
        # per-rank chain share each still-incremental view would bear
        share: Dict[str, float] = {}
        for (assign_flops, view_deps), rank in chains:
            live = [w for w, deps in view_deps.items()
                    if deps and w in views and views[w].strategy != "reeval"]
            users = {a: sum(1 for w in live if a in view_deps[w])
                     for a in assign_flops}
            for w in live:
                s = sum(assign_flops[a] / max(users[a], 1)
                        for a in view_deps[w]) / rank
                share[w] = max(share.get(w, 0.0), s)
        changed = False
        for name, s in share.items():
            vp = views[name]
            n, m = shapes[name]
            kstar = max(1, int(reeval_effs[name] / (2.0 * n * m + s)))
            k_eff = max(1, int(kstar / max(workload.cost_scale, 1e-12)))
            if hi < k_eff:
                strat, thr = "incremental", None
            elif lo >= k_eff:
                strat, thr = "reeval", None
            else:
                strat, thr = "hybrid", k_eff
            if (strat, thr, kstar) != (vp.strategy, vp.threshold_rank,
                                       vp.crossover_rank):
                changed = strat != vp.strategy or changed
                views[name] = replace(vp, strategy=strat,
                                      threshold_rank=thr,
                                      crossover_rank=kstar)
        if not changed:
            return


def firing_cost_flops(compiled: CompiledProgram, binding: Dict[str, int],
                      input_name: str, stacked_rank: int, *,
                      reeval_views: FrozenSet[str] = frozenset(),
                      workload: Optional[WorkloadDescriptor] = None,
                      view_orders: Optional[Dict[str, int]] = None,
                      affected_fraction: Optional[float] = None
                      ) -> float:
    """Planner-estimated FLOPs of one trigger firing at ``stacked_rank``.

    Prices the shared delta chain ONCE (only the assigns some
    incremental view still reads, scaled linearly to the stacked rank),
    one ``2·K·n·m`` factored sweep per incrementally maintained view,
    and a full re-evaluation per view in ``reeval_views``.  The sweep
    side is scaled by the workload's calibrated ``cost_scale`` so the
    number is in re-evaluation-FLOP equivalents — this is the cost term
    the fleet scheduler multiplies into its SLO priority, and the place
    the chain a lone incremental view keeps alive must not be
    underestimated (ROADMAP carried follow-up).

    ``view_orders`` (an engine's resolved per-view delta depths) prices
    a deferred order-``o`` view at its amortized fold share — one
    stacked, rank-capped sweep per ``fold_window**(o-1)`` firings,
    never worse than re-evaluation — instead of a full per-firing
    sweep, and keeps none of the delta chain alive per firing.
    Chain-aware fleet pricing would otherwise overcharge higher-order
    tenants by exactly the factor their depth buys back.

    ``affected_fraction`` (a row-local firing's ``r/n``, or the
    workload's expectation) scales the sweep of every view the compiler
    proved row-local under this trigger — the fleet's lease pricing
    must see the contained cost, or sparse tenants get overcharged by
    ``1/fraction`` and starve dense tenants of their fair share.
    """
    trig = compiled.triggers[input_name]
    assign_flops, view_deps = trigger_chain_costs(trig, binding)
    scale = workload.cost_scale if workload is not None else 1.0
    if affected_fraction is None and workload is not None:
        affected_fraction = workload.affected_fraction
    fold_window = workload.fold_window if workload is not None else 8
    max_fold_rank = workload.max_fold_rank if workload is not None else 64
    k = max(1, int(stacked_rank))
    by_name = {s.target.name: s for s in compiled.program.statements}
    total = 0.0
    live_assigns: set = set()
    for up in trig.updates:
        st = by_name.get(up.view)
        order = (view_orders or {}).get(up.view, 1)
        if order >= 2 and st is not None:
            w = float(max(1, fold_window) ** (order - 1))
            kw = k * w
            if max_fold_rank:
                kw = min(kw, float(max_fold_rank))
            n, m = shape_of(st.target, binding)
            kinds = expr_cost_kinds(st.expr, binding)
            re_eff = (workload.effective_reeval_flops(kinds)
                      if workload is not None else sum(kinds.values()))
            total += min(scale * 2.0 * kw * n * m, re_eff) / w
            continue
        if up.view in reeval_views and st is not None:
            kinds = expr_cost_kinds(st.expr, binding)
            total += (workload.effective_reeval_flops(kinds)
                      if workload is not None else sum(kinds.values()))
            continue
        target = st.target if st is not None \
            else compiled.program.inputs[up.view]
        n, m = shape_of(target, binding)
        rows = n
        if (affected_fraction is not None
                and trig.carriers.get(up.view) == "row_local"):
            rows = max(1.0, affected_fraction * n)
        total += scale * 2.0 * k * rows * m
        live_assigns |= view_deps[up.view]
    total += scale * sum(assign_flops[a] for a in live_assigns) \
        * (k / max(trig.rank, 1))
    return total


def plan_for_engine(engine, workload: WorkloadDescriptor) -> MaintenancePlan:
    """Plan against an engine's compiled program / binding / mesh."""
    return plan_program(engine.compiled, workload, binding=engine.binding,
                        mesh=engine.mesh, mesh_axis=engine.mesh_axis)


def static_plan(engine, strategy: str,
                workload: Optional[WorkloadDescriptor] = None
                ) -> MaintenancePlan:
    """The degenerate plan that forces one ``strategy`` on every view.

    The static baselines the adaptive planner is judged against
    (benchmarks, A/B tests): ``"incremental"`` reproduces the
    pre-planner engine behavior, ``"reeval"`` the paper's batched
    REEVAL baseline.  Every view stays materialized.
    """
    base = plan_for_engine(engine, workload or WorkloadDescriptor())
    views = {name: replace(vp, strategy=strategy, threshold_rank=None,
                           materialize=True, order=1)
             for name, vp in base.views.items()}
    return MaintenancePlan(fingerprint=base.fingerprint,
                           workload=base.workload, views=views,
                           mesh_key=base.mesh_key)


def solver_resolve_strategy(n: int, pending_rank: int, *,
                            cost_scale: float = 1.0) -> str:
    """Price a normal-equation re-solve against the maintained ring
    (repro.fivm): ``"update"`` applies ``pending_rank`` Cholesky
    rank-one update/downdates to the cached factor of ``G + λI``
    (``2kn²`` flops), ``"refactor"`` refactors from the maintained
    gram (``n³/3``) — the §7 incremental-vs-reeval crossover
    transplanted to the solver layer, crossing at ``k ≈ n/6``
    (:func:`repro.core.cost.solver_crossover_rank`).

    ``cost_scale`` biases the update side (>1 penalizes the Python-loop
    rank-one kernel against the BLAS refactor; calibrated by the fivm
    bench).  The back-substitution ``2n²p`` is common to both arms and
    drops out of the comparison.
    """
    if pending_rank <= 0:
        return "update"          # nothing pending: keep the factor
    upd = cholesky_update_cost(n, pending_rank).flops * cost_scale
    ref = cholesky_factor_cost(n).flops
    return "update" if upd < ref else "refactor"
