"""Online re-planning: watch the workload, re-plan when it drifts.

The static planner prices a plan against a *declared*
:class:`WorkloadDescriptor`; real update streams drift — adapter bursts
grow, batch coalescing changes T, a quiet corpus suddenly takes
high-rank refreshes.  :class:`AdaptivePlanner` closes the loop: the
engine reports every firing's observed stacked rank, and every
``replan_every`` firings the planner refits the descriptor to the
observed distribution (median / p10 / p90) and re-plans if the fit has
drifted past ``drift_tol``.  A re-plan that changes no per-view choice
is discarded; one that does is handed back to the engine, which
hot-swaps it (pending queues survive, cached triggers for already-seen
(bucket, partition) keys are reused from the trigger cache).
"""

from __future__ import annotations

from collections import deque
from dataclasses import replace
from typing import Deque, Dict, Optional

from .planner import (MaintenancePlan, WorkloadDescriptor, plan_program,
                      program_fingerprint)


class AdaptivePlanner:
    """Re-plans a :class:`MaintenancePlan` from observed firings.

    Construct unbound (``AdaptivePlanner(workload)``) and hand to
    ``IncrementalEngine(plan=...)`` — the engine binds it to its
    compiled program — or bind explicitly with :meth:`bind` for
    standalone use.
    """

    def __init__(self, workload: Optional[WorkloadDescriptor] = None, *,
                 replan_every: int = 8, drift_tol: float = 0.5,
                 history: int = 256):
        if replan_every < 1:
            raise ValueError(f"replan_every must be ≥ 1, got {replan_every}")
        self.workload = workload or WorkloadDescriptor()
        self.replan_every = replan_every
        self.drift_tol = drift_tol
        self._ranks: Deque[int] = deque(maxlen=history)
        self._batches: Deque[int] = deque(maxlen=history)
        self._fractions: Deque[float] = deque(maxlen=history)
        self._firings = 0
        self._reads = 0
        self._since_replan = 0
        self._force_replan = False
        self.replans = 0
        #: per-view count of sentinel-reported drift recoveries
        self.drift_counts: Dict[str, int] = {}
        self.plan: Optional[MaintenancePlan] = None
        self._compiled = None
        self._binding: Optional[Dict[str, int]] = None
        self._mesh = None
        self._mesh_axis = None

    # -- binding -------------------------------------------------------------
    def bind(self, compiled, binding: Optional[Dict[str, int]] = None,
             mesh=None, mesh_axis: Optional[str] = None) -> MaintenancePlan:
        """Attach to a compiled program and produce the initial plan.
        Re-binding to the same fingerprint keeps observation history."""
        fp = program_fingerprint(compiled.program, binding)
        if self.plan is not None and self.plan.fingerprint != fp:
            raise ValueError(
                "AdaptivePlanner is already bound to a different program "
                f"({self.plan.fingerprint} != {fp})")
        self._compiled = compiled
        self._binding = dict(compiled.program.dims
                             if binding is None else binding)
        self._mesh, self._mesh_axis = mesh, mesh_axis
        if self.plan is None:
            self.plan = plan_program(compiled, self.workload,
                                     binding=self._binding, mesh=mesh,
                                     mesh_axis=mesh_axis)
        return self.plan

    @property
    def bound(self) -> bool:
        return self._compiled is not None

    def adopt(self, plan: MaintenancePlan) -> None:
        """Accept an externally installed plan (engine hot-swap) as the
        new baseline, so the next drift check prices against it instead
        of silently reverting to the planner's own stale fit."""
        if self.plan is not None and self.plan.fingerprint != plan.fingerprint:
            raise ValueError(
                "cannot adopt a plan for a different program "
                f"({plan.fingerprint} != {self.plan.fingerprint})")
        self.plan = plan
        self.workload = plan.workload
        self._since_replan = 0

    # -- observation loop ----------------------------------------------------
    def observe(self, input_name: str, stacked_rank: int,
                batch_size: int,
                affected_fraction: Optional[float] = None) -> None:
        """Record one firing (pre-padding stacked rank, T updates).

        ``affected_fraction`` is the firing's observed row containment
        (``r/n`` for a row-local carrier, 1.0 for a dense firing) — the
        fitted descriptor carries its p90, so a stream that turns out
        contained re-prices row-local-closed views at the row-slab
        sweep cost, and one that widens drops the discount."""
        self._ranks.append(max(1, int(stacked_rank)))
        self._batches.append(max(1, int(batch_size)))
        self._fractions.append(1.0 if affected_fraction is None
                               else min(1.0, max(0.0, affected_fraction)))
        self._firings += 1
        self._since_replan += 1

    def observe_read(self) -> None:
        """Record one view read (engine ``output()``).  The observed
        reads-per-firing ratio is what makes depth pay: a stream of
        updates between sparse reads is exactly the window a deferred
        order-k cascade amortizes, so the fit feeds
        ``WorkloadDescriptor.reads_per_firing`` when ``max_order ≥ 2``.
        """
        self._reads += 1

    def observed_workload(self) -> Optional[WorkloadDescriptor]:
        """The empirical descriptor: median/p10/p90 of observed stacked
        ranks, with the median batch size factored out so the fitted
        (update_rank, batch_size) keep their declared meanings.  When
        the declared workload opts into depth (``max_order ≥ 2``) the
        fit also includes the observed reads-per-firing ratio — the
        signal :func:`repro.plan.planner.plan_program` prices depth-k
        maintenance against."""
        if not self._ranks:
            return None
        ranks, batches = sorted(self._ranks), sorted(self._batches)
        q = lambda xs, p: xs[min(len(xs) - 1, int(p * len(xs)))]
        t = max(1, q(batches, 0.5))
        k = max(1, round(q(ranks, 0.5) / t))
        fitted = replace(self.workload, update_rank=k, batch_size=t,
                         rank_lo=q(ranks, 0.1), rank_hi=q(ranks, 0.9))
        if self._fractions:
            # p90 (not mean): the discount must hold for the stream's
            # wide tail, or the plan underprices its worst firings
            frac = q(sorted(self._fractions), 0.9)
            fitted = replace(fitted,
                             affected_fraction=None if frac >= 1.0
                             else max(frac, 1e-6))
        if self.workload.max_order >= 2 and self._firings > 0:
            fitted = replace(fitted,
                             reads_per_firing=self._reads / self._firings)
        return fitted

    # -- external signals (guard / stats) ------------------------------------
    def note_drift(self, names) -> None:
        """The drift sentinel re-evaluated ``names`` back to exactness:
        their incremental maintenance is numerically too aggressive for
        this workload.  Record it and force a re-plan at the next
        firing (bypassing the drift-tolerance gate) so the pricing can
        react — e.g. a refitted rank distribution tipping the repeat
        offender to hybrid/re-evaluation."""
        for n in names:
            self.drift_counts[n] = self.drift_counts.get(n, 0) + 1
        self._force_replan = True

    def refit_from_stats(self, stats) -> Optional[float]:
        """Refit ``cost_scale`` online from an engine's measured rates.

        ``stats`` is an :class:`~repro.core.runtime.EngineStats` whose
        timed counters pair wall-clock with the FLOPs they covered:
        sweep seconds-per-FLOP over re-evaluation seconds-per-FLOP *is*
        the workload's ``cost_scale`` (the calibration
        :func:`repro.plan.calibrate_cost_scale` measures offline).
        Needs both paths to have run with ``block=True`` at least once;
        returns the fitted scale (or ``None`` when unmeasurable).  A
        material change (> ``drift_tol`` relative) updates the workload
        and forces a re-plan.
        """
        sweep_f = getattr(stats, "sweep_flops_timed", 0.0)
        reeval_f = getattr(stats, "reeval_flops_timed", 0.0)
        if (sweep_f <= 0 or reeval_f <= 0
                or stats.trigger_seconds <= 0 or stats.reeval_seconds <= 0):
            return None
        sweep_rate = stats.trigger_seconds / sweep_f
        reeval_rate = stats.reeval_seconds / reeval_f
        scale = max(sweep_rate / reeval_rate, 1e-3)
        old = self.workload.cost_scale
        if abs(scale - old) > self.drift_tol * max(old, 1e-12):
            self.workload = replace(self.workload, cost_scale=scale)
            self._force_replan = True
        return scale

    def maybe_replan(self) -> Optional[MaintenancePlan]:
        """Re-plan if due and drifted; returns the new plan only when a
        per-view choice actually changed (else ``None``).  A pending
        :meth:`note_drift` / :meth:`refit_from_stats` signal forces the
        re-plan regardless of cadence or rank drift."""
        force, self._force_replan = self._force_replan, False
        if (not self.bound or self.plan is None
                or (self._since_replan < self.replan_every and not force)):
            self._force_replan = force  # keep the signal until due
            return None
        self._since_replan = 0
        fitted = self.observed_workload()
        if fitted is None:
            if not force:
                return None
            fitted = self.workload
        if not force:
            expected = self.workload.expected_rank()
            if abs(fitted.expected_rank() - expected) <= \
                    self.drift_tol * max(expected, 1):
                return None
        self.workload = fitted
        new = plan_program(self._compiled, fitted, binding=self._binding,
                           mesh=self._mesh, mesh_axis=self._mesh_axis)
        if new.views == self.plan.views:
            self.plan = new  # same choices, fresher pricing
            return None
        self.plan = new
        self.replans += 1
        return new
