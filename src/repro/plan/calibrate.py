"""Wall-clock calibration of the planner's FLOP cost model.

The §7 crossover ``K* = reeval_flops / (2·n·m)`` treats every FLOP as
equal, but the two sides run at very different rates: re-evaluation is
dense matmuls at peak BLAS throughput, while a rank-K factored sweep is
skinny matmuls and rank updates that CPU backends execute at a >10x
worse rate.  Deciding strategies from raw FLOPs therefore keeps views
incremental far past the rank where re-evaluation already wins the
wall-clock race.

:func:`calibrate_cost_scale` measures the ratio on the machine that
will execute the plan: it fires the all-incremental and the all-reeval
static plan at a probe stacked rank, prices both firings under the FLOP
model, and returns

    cost_scale = (t_incr / sweep_flops) / (t_reeval / reeval_flops)

— the wall-clock cost of one sweep FLOP in units of re-evaluation
FLOPs.  Feed it to :class:`~repro.plan.WorkloadDescriptor(cost_scale=…)`
and the planner prices every view against the *effective* crossover
``K*/cost_scale``.  One probe per (program, backend) suffices: the
ratio is a property of the kernels, not of the batch size.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.compiler import batch_bucket
from repro.core.cost import expr_cost, shape_of

from .planner import WorkloadDescriptor, static_plan


def _probe_updates(n: int, m: int, rank: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [(rng.normal(scale=0.01, size=(n, 1)).astype(np.float32),
             rng.normal(scale=0.01, size=(m, 1)).astype(np.float32))
            for _ in range(rank)]


def calibrate_cost_scale(make_engine, inputs: Dict, input_name: str, *,
                         probe_rank: int = 32, samples: int = 9,
                         trigger_cache=None) -> float:
    """Measure ``WorkloadDescriptor.cost_scale`` for one program.

    ``make_engine`` builds a fresh :class:`IncrementalEngine` (called
    twice — the two static baselines must not share view state);
    ``inputs`` initializes it; the probe fires ``probe_rank`` stacked
    rank-1 updates to ``input_name``.  Returns the measured ratio,
    clamped to ≥ 1e-3; timing keeps the best of ``samples``
    steady-state firings per side so a scheduler stall cannot skew the scale.
    """
    from repro.core.runtime import IncrementalEngine  # avoid import cycle

    engines: Dict[str, IncrementalEngine] = {}
    flops: Dict[str, float] = {}
    ups = _probe_updates(*np.shape(inputs[input_name]), probe_rank)
    for strategy in ("incremental", "reeval"):
        eng = make_engine()
        if not isinstance(eng, IncrementalEngine):
            raise TypeError("make_engine must return an IncrementalEngine")
        if trigger_cache is not None:
            eng._trigger_cache = trigger_cache
        eng.set_plan(static_plan(eng, strategy))
        eng.initialize(dict(inputs))
        engines[strategy] = eng

        total = 0.0
        for up in eng.compiled.triggers[input_name].updates:
            st = next((s for s in eng.program.statements
                       if s.target.name == up.view), None)
            if st is None:
                continue
            if strategy == "incremental":
                if up.kind != "lowrank":
                    continue  # dense-kind updates are not a rank-K sweep
                shape = shape_of(st.target, eng.binding)
                # the firing executes at the padded pow2 bucket rank,
                # so price the sweep at that rank, not the raw probe
                total += 2.0 * batch_bucket(probe_rank) * shape[0] * shape[1]
            else:
                total += expr_cost(st.expr, eng.binding).flops
        flops[strategy] = max(total, 1.0)

    def firing(eng):
        eng.apply_updates(input_name, ups)
        jax.block_until_ready(eng.views)

    # interleaved probe, order re-randomized each round — both
    # strategies see the same container conditions AND the same mix of
    # predecessors (a firing inherits its predecessor's allocator/L3
    # pollution), so the rate ratio survives load drift and order bias
    # that would skew back-to-back blocks
    raw: Dict[str, list] = {s: [] for s in engines}
    names = list(engines)
    order = np.random.default_rng(0)
    for eng in engines.values():
        firing(eng)  # jit warmup
    for _ in range(samples):
        for idx in order.permutation(len(names)):
            t0 = time.perf_counter()
            firing(engines[names[idx]])
            raw[names[idx]].append(time.perf_counter() - t0)
    # min, not median: the best window is the true rate — container
    # stall episodes can outlast half the probe, but each side only
    # needs one quiet window, and nothing ever runs too fast
    times = {s: float(np.min(v)) for s, v in raw.items()}

    scale = ((times["incremental"] / flops["incremental"])
             / (times["reeval"] / flops["reeval"]))
    return max(float(scale), 1e-3)


def calibrate_op_cost_scales(n: int = 512, samples: int = 5,
                             seed: int = 0) -> Dict[str, float]:
    """Measure ``WorkloadDescriptor.op_cost_scales`` on this backend.

    Times one representative kernel per cost-model op kind at size
    ``n`` — dense matmul (``"matmul"``), LU factorization+solve behind
    ``Inverse`` (``"inverse"``), elementwise add (``"other"``) — and
    returns each kind's measured seconds-per-FLOP relative to the
    matmul rate.  A kind's scale > 1 means its FLOPs run slower than
    the dense-matmul FLOPs the raw count implicitly assumes, pushing
    the §7 crossover of views dominated by that kind upward.  Best-of-
    ``samples`` timing, same rationale as :func:`calibrate_cost_scale`.
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    spd = a @ a.T + n * jnp.eye(n, dtype=np.float32)  # safely invertible
    ops = {
        "matmul": (lambda: a @ b, 2.0 * n ** 3),
        "inverse": (lambda: jnp.linalg.inv(spd),
                    (2.0 / 3.0) * n ** 3 + 2.0 * n ** 2),
        "other": (lambda: a + b, float(n) * n),
    }
    rates: Dict[str, float] = {}
    for kind, (fn, op_flops) in ops.items():
        jax.block_until_ready(fn())  # jit/BLAS warmup
        best = float("inf")
        for _ in range(samples):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        rates[kind] = best / op_flops
    base = rates["matmul"]
    return {k: max(float(r / base), 1e-3) for k, r in rates.items()}
