"""Vendored micro-dependencies (containers here have no pip access)."""
