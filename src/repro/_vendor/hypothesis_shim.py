"""A tiny, deterministic stand-in for the ``hypothesis`` API surface the
test suite uses (``given``/``settings``/``strategies.integers``/
``sampled_from``/``floats``).

The real hypothesis is not installed in this container (ROADMAP open
item), which used to skip two whole test modules.  This shim keeps those
property tests running as seeded random parametrization:

  * each ``@given`` test draws ``max_examples`` example tuples from a
    ``numpy`` Generator seeded from the test's qualified name, so runs
    are reproducible and failures repeat;
  * on failure, the draw that failed is attached to the assertion so it
    can be reproduced as a plain test case.

This is NOT hypothesis: there is no shrinking, no database, no coverage-
guided search.  If the real package is importable, ``tests/conftest.py``
prefers it and this module stays dormant.

Install with :func:`install`, which registers ``hypothesis`` and
``hypothesis.strategies`` module objects in ``sys.modules`` so existing
``from hypothesis import given, settings, strategies as st`` imports
work unchanged.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib
from typing import Any, Callable, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class SearchStrategy:
    """A draw rule: Generator → value."""

    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 label: str):
        self._draw = draw
        self.label = label

    def example_with(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:
        return self.label


def integers(min_value: int = 0, max_value: int = 1 << 30) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})")


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    els = list(elements)
    if not els:
        raise ValueError("sampled_from needs a non-empty sequence")
    return SearchStrategy(
        lambda rng: els[int(rng.integers(len(els)))],
        f"sampled_from({els!r})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(2)), "booleans()")


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


class settings:
    """Decorator form only (what the suite uses); other knobs ignored."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline: Any = None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(**strategies: SearchStrategy):
    """Run the test once per drawn example (keyword strategies only)."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                draw = {name: s.example_with(rng)
                        for name, s in strategies.items()}
                try:
                    fn(*args, **draw, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i + 1}/{n}: "
                        f"{draw!r}") from e

        # pytest resolves fixtures via inspect.signature, which follows
        # __wrapped__ back to the original and would mistake the drawn
        # parameters for fixtures — hide the link, and expose the
        # residual signature (original minus drawn params) so fixtures
        # and @pytest.mark.parametrize arguments still compose with
        # @given, as they do under the real hypothesis.
        del wrapper.__wrapped__
        residual = [p for name, p in
                    inspect.signature(fn).parameters.items()
                    if name not in strategies]
        wrapper.__signature__ = inspect.Signature(residual)
        return wrapper

    return decorate


def assume(condition: bool) -> None:
    """Degraded assume: a failed assumption just skips nothing and must
    be handled by the strategy; raise to surface misuse loudly."""
    if not condition:
        raise _UnsatisfiedAssumption(
            "shim assume() cannot discard examples; restrict the strategy")


class _UnsatisfiedAssumption(Exception):
    pass


def install() -> types.ModuleType:
    """Register shim ``hypothesis`` / ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.__version__ = "0.0-shim"
    mod.__is_repro_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return mod
