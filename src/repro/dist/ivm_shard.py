"""Row-sharded IVM execution (paper §6, Data Partitioning / Fig. 3f).

The paper's parallelization claim, executed: a compiled trigger is a
straight-line chain of (big × skinny) matmuls followed by rank-k view
sweeps, so placing every maintained n×m view **row-sharded** across the
mesh makes each firing embarrassingly parallel —

  * factor blocks like ``A·u`` read only local rows of ``A``;
  * transposed reads (``Aᵀ·q``) reduce to an all-gather of a *skinny*
    (n × k) intermediate, O(n·k) on the wire;
  * the ``M += U Vᵀ`` sweeps are purely local row updates.

Re-evaluation on the same layout moves whole matrices: one n×n matmul
between two row-sharded operands all-gathers O(n²) bytes.  That gap is
the paper's Fig. 3f finding (INCR is far less sensitive to cluster size
than REEVAL), reproduced structurally by ``benchmarks/bench_scaling.py``
from the compiled collective schedules of the two functions below.

Placement is declared with ``with_sharding_constraint`` inside the staged
computation and GSPMD inserts the minimal collectives — the trigger body
itself is the *same* code the single-device engine runs
(:func:`repro.core.codegen.evaluate`), so distributed output matches
single-device output to fp32 tolerance by construction.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.codegen import evaluate, trigger_touched_views
from repro.core.compiler import Trigger
from repro.core.program import Program

Array = jax.Array
Env = Dict[str, Array]


def row_spec(mesh: Mesh, axis: str, shape: Tuple[int, ...]) -> P:
    """Row-sharding spec when the leading dim divides the mesh axis,
    else replicated (skinny factors, scalars, ragged views)."""
    n_shards = mesh.shape[axis]
    if len(shape) == 2 and shape[0] >= n_shards and shape[0] % n_shards == 0:
        return P(axis, None)
    return P()


def _constrainer(mesh: Mesh, axis: str) -> Callable[[Array], Array]:
    def constrain(x: Array) -> Array:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, row_spec(mesh, axis, x.shape)))
    return constrain


def _replicate(mesh: Mesh, x: Array) -> Array:
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def shard_views(views: Env, mesh: Mesh, axis: Optional[str] = None) -> Env:
    """Place a view store row-sharded on ``mesh`` (eager ``device_put``).

    The engine calls this once at initialize time so steady-state trigger
    firings start from device-resident shards instead of resharding per
    call.
    """
    axis = axis or mesh.axis_names[0]
    out = {}
    for name, x in views.items():
        x = jnp.asarray(x)
        out[name] = jax.device_put(
            x, NamedSharding(mesh, row_spec(mesh, axis, x.shape)))
    return out


def build_distributed_trigger(trigger: Trigger, program: Program, mesh: Mesh,
                              *, jit: bool = True,
                              axis: Optional[str] = None
                              ) -> Callable[[Env, Array, Array], Env]:
    """Stage a compiled trigger for row-sharded execution on ``mesh``.

    Returns ``fn(views, U, V) -> views`` with the same contract as
    :func:`repro.core.codegen.build_trigger_fn`: ``views`` must contain
    every view the trigger touches; the returned dict carries the updated
    values (untouched views pass through).  ``axis`` defaults to the
    mesh's first axis name.

    With ``jit=False`` the returned function is a pure trace-able body
    (no internal jit) so callers can ``jax.jit(fn).lower(...)`` it to
    inspect the collective schedule.
    """
    axis = axis or mesh.axis_names[0]
    binding = dict(program.dims)
    written, read_only = trigger_touched_views(trigger)
    constrain = _constrainer(mesh, axis)

    def core(written_vals: Tuple[Array, ...], read_vals: Tuple[Array, ...],
             u: Array, v: Array) -> Tuple[Array, ...]:
        env: Env = {}
        for name, val in zip(written + read_only,
                             tuple(written_vals) + tuple(read_vals)):
            env[name] = constrain(val)
        # update factors are skinny: replicate them to every shard
        env[trigger.u_var.name] = _replicate(mesh, u)
        env[trigger.v_var.name] = _replicate(mesh, v)
        cache: Dict[int, Array] = {}
        for a in trigger.assigns:
            env[a.name] = evaluate(a.expr, env, binding, cache)
        for up in trigger.updates:
            if up.kind == "lowrank":
                new = env[up.view] + env[up.u] @ env[up.v].T
            else:
                new = env[up.view] + env[up.d]
            env[up.view] = constrain(new)
        return tuple(env[name] for name in written)

    if jit:
        core = jax.jit(core)

    def run(views: Env, u: Array, v: Array) -> Env:
        new_vals = core(tuple(views[n] for n in written),
                        tuple(views[n] for n in read_only),
                        jnp.asarray(u), jnp.asarray(v))
        out = dict(views)
        out.update(zip(written, new_vals))
        return out

    return run


def build_distributed_planned_trigger(trigger: Trigger, program: Program,
                                      mesh: Mesh, *, reeval_views=(),
                                      lazy_views=(), jit: bool = True,
                                      axis: Optional[str] = None
                                      ) -> Callable[[Env, Array, Array], Env]:
    """The planned firing (per-view incremental/reeval/lazy partition,
    see :func:`repro.core.codegen.build_planned_trigger_fn`) staged for
    row-sharded execution on ``mesh``.

    The plan partition changes *what* is computed, not *where*: factor
    blocks and rank-k sweeps stay row-local, and an in-firing
    re-evaluation of a view is the same row-sharded matmul chain the
    re-evaluation baseline runs — GSPMD inserts the collectives either
    way, so distributed planned output matches the single-device
    planned output to fp32 tolerance by construction.  Plans carry the
    mesh key (``repro.plan.trigger_cache.mesh_cache_key``) so engines
    on identical meshes share these compiled firings through the
    trigger cache instead of re-jitting per instance.
    """
    from repro.core.codegen import build_planned_trigger_fn
    axis = axis or mesh.axis_names[0]
    return build_planned_trigger_fn(
        trigger, program, dict(program.dims),
        reeval_views=reeval_views, lazy_views=lazy_views, jit=jit,
        apply_backend="xla", donate=False,
        constrain=_constrainer(mesh, axis),
        replicate=lambda x: _replicate(mesh, x))


def distributed_reeval_matmul(mesh: Mesh, *, jit: bool = True,
                              axis: Optional[str] = None
                              ) -> Callable[[Array, Array], Array]:
    """The re-evaluation baseline on the same layout: ``A @ B`` with both
    operands row-sharded.

    GSPMD must all-gather the right operand (O(n·m) wire bytes) before
    the local matmuls — exactly the re-evaluation data movement the paper
    charges against REEVAL in §6.  Output stays row-sharded, matching the
    view store layout.
    """
    axis = axis or mesh.axis_names[0]
    constrain = _constrainer(mesh, axis)

    def fn(a: Array, b: Array) -> Array:
        return constrain(constrain(a) @ constrain(b))

    return jax.jit(fn) if jit else fn
