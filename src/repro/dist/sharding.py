"""Mesh-aware placement: logical axes → mesh axes → shardings.

The models layer annotates arrays with *logical* axis names
(``shard(x, "batch", None, "ff")``, ``axes_mlp() -> {"w_in": ("fsdp",
"ff"), ...}``).  This module owns the translation to physical placement:

  * a :class:`ShardingCtx` (mesh + logical→mesh rules) is installed with
    the :func:`use_sharding` context manager;
  * :func:`shard` applies a ``with_sharding_constraint`` when a mesh is
    active and is an exact no-op otherwise — the models stay importable
    and correct on a single device;
  * :func:`resolve_spec` / :func:`named_sharding` / :func:`tree_shardings`
    build ``PartitionSpec`` / ``NamedSharding`` trees for pjit in/out
    shardings (the dry-run and the checkpoint restore path use these).

Resolution is *safe by construction*: a logical axis whose mesh axis is
absent from the active mesh, already used by an earlier dimension, or
does not divide the dimension size is silently dropped (the array stays
replicated along that dimension).  That is what lets one set of model
annotations serve the 512-chip dry-run mesh, an 8-device host mesh, and
the single-CPU smoke tests without per-target configuration.
"""

from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, None]
# one logical name may map to several mesh axes (e.g. batch → (pod, data))
Rules = Dict[str, Union[str, Tuple[str, ...], None]]

# Default logical→mesh rules for the production meshes
# (("data", "model") single-pod, ("pod", "data", "model") multi-pod).
# "seq_sp" (Megatron-style sequence parallelism) and "fsdp" are off by
# default; a hillclimb enables them via ``use_sharding(mesh, rules=...)``.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "ff": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "fsdp": None,
    "seq_sp": None,
    "cache_seq": None,
}


@dataclass(frozen=True)
class ShardingCtx:
    """Active placement context: a mesh plus logical→mesh axis rules."""

    mesh: Optional[Mesh] = None
    rules: Rules = field(default_factory=dict)

    def mesh_axes_for(self, logical: AxisName) -> Tuple[str, ...]:
        """Mesh axes a logical axis maps to on *this* mesh (may be ())."""
        if logical is None or self.mesh is None:
            return ()
        if logical in self.rules:
            mapped = self.rules[logical]
        elif logical in self.mesh.axis_names:
            mapped = logical          # direct mesh-axis reference
        else:
            mapped = None
        if mapped is None:
            return ()
        if isinstance(mapped, str):
            mapped = (mapped,)
        return tuple(a for a in mapped if a in self.mesh.axis_names)


_CTX: ContextVar[ShardingCtx] = ContextVar(
    "repro_sharding_ctx", default=ShardingCtx(mesh=None, rules=DEFAULT_RULES))


def current_ctx() -> ShardingCtx:
    """The innermost active context (mesh is None outside use_sharding)."""
    return _CTX.get()


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[Rules] = None):
    """Install ``mesh`` (plus optional rule overrides) for the duration.

    >>> with use_sharding(jax.make_mesh((4, 2), ("data", "model"))) as ctx:
    ...     state = init_train_state(model, rng)      # annotations resolve
    ...     step = jax.jit(make_train_step(model))
    """
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    ctx = ShardingCtx(mesh=mesh, rules=merged)
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def resolve_spec(axes: Sequence[AxisName],
                 shape: Optional[Sequence[int]],
                 ctx: Optional[ShardingCtx] = None) -> P:
    """Logical axes (one per dimension) → a PartitionSpec valid on the
    active mesh.

    Drops (replicates) any dimension whose mapped mesh axes are absent,
    already claimed by an earlier dimension, or do not divide the
    dimension size (checked when ``shape`` is given).
    """
    ctx = ctx or current_ctx()
    if ctx.mesh is None:
        return P()
    used: set = set()
    out = []
    for i, logical in enumerate(axes):
        mesh_axes = []
        for a in ctx.mesh_axes_for(logical):
            if a in used:
                continue
            size = ctx.mesh.shape[a]
            if shape is not None:
                dim = int(shape[i])
                span = size * math.prod(ctx.mesh.shape[x] for x in mesh_axes)
                if dim % span != 0 or span > dim:
                    continue
            mesh_axes.append(a)
            used.add(a)
        if not mesh_axes:
            out.append(None)
        elif len(mesh_axes) == 1:
            out.append(mesh_axes[0])
        else:
            out.append(tuple(mesh_axes))
    while out and out[-1] is None:          # trailing Nones are implicit
        out.pop()
    return P(*out)


def named_sharding(axes: Sequence[AxisName],
                   shape: Optional[Sequence[int]] = None,
                   ctx: Optional[ShardingCtx] = None) -> NamedSharding:
    """A :class:`NamedSharding` on the active mesh for one array.

    ``named_sharding((), None)`` is the replicated sharding (scalars,
    RNG keys, step counters).
    """
    ctx = ctx or current_ctx()
    if ctx.mesh is None:
        raise ValueError("named_sharding needs an active mesh "
                         "(wrap in use_sharding)")
    return NamedSharding(ctx.mesh, resolve_spec(axes, shape, ctx))


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(axes_tree: Any, shapes_tree: Any,
                   ctx: Optional[ShardingCtx] = None) -> Any:
    """Map a logical-axes pytree + matching shapes pytree → NamedShardings.

    ``axes_tree`` mirrors the parameter tree with per-leaf logical-axis
    tuples (``model.param_axes()``); ``shapes_tree`` holds arrays or
    ``ShapeDtypeStruct``s.  Used for pjit in/out shardings and for
    resharding a restored checkpoint onto a new mesh.
    """
    ctx = ctx or current_ctx()
    return jax.tree.map(
        lambda ax, s: named_sharding(ax, tuple(s.shape), ctx),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def shard(x: jax.Array, *axes: AxisName) -> jax.Array:
    """Constrain ``x``'s placement by logical axis names, one per dim.

    A no-op when no mesh is active (single-device tests) or when no axis
    resolves on the current mesh — the annotation is declarative, the
    context decides whether it binds.
    """
    ctx = current_ctx()
    if ctx.mesh is None:
        return x
    if len(axes) != getattr(x, "ndim", None):
        return x
    spec = resolve_spec(axes, x.shape, ctx)
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))
