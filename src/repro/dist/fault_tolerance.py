"""Fault tolerance control plane: detect, evict, replan, restart.

Three pieces, deliberately decoupled from jax so they unit-test with a
fake clock and drive any runner:

  * :class:`FaultTolerantController` — host liveness from heartbeats.
    A host is **failed** when its last heartbeat is older than
    ``heartbeat_timeout``; a host is a **straggler** when its reported
    step time exceeds ``straggler_factor ×`` the alive median for
    ``straggler_patience`` consecutive ticks (slow hardware stalls a
    synchronous mesh exactly like a dead host, just less honestly).
    Either eviction moves the run to ``RESHAPING``; dropping below
    ``min_hosts`` moves it to ``HALTED``.

  * :func:`plan_mesh` — elastic mesh replanning: given the surviving
    device count, produce the largest valid (data, model) — or
    (pod, data, model) — mesh shape, keeping model parallelism fixed
    (weights are sharded over it; resizing it would re-layout weights).

  * :class:`TrainingSupervisor` — the restart loop: run steps, save on
    a cadence, and on a reshape event restore from the newest checkpoint
    and continue on the surviving hosts.

State machine (documented in docs/dist.md):

    RUNNING --failure/straggler/rejoin--> RESHAPING --complete_reshape-->
    RUNNING;   RUNNING --alive < min_hosts--> HALTED (terminal until
    operator intervention).
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


class RunPhase(enum.Enum):
    RUNNING = "running"
    RESHAPING = "reshaping"
    HALTED = "halted"


@dataclass(frozen=True)
class FaultToleranceConfig:
    heartbeat_timeout: float = 30.0   # seconds of silence → failed
    straggler_factor: float = 0.0     # ×median step time; 0 disables
    straggler_patience: int = 3       # consecutive slow ticks → evicted
    min_hosts: int = 1                # fewer alive → HALTED


class FaultTolerantController:
    """Tracks host liveness; owns the RUNNING/RESHAPING/HALTED phase."""

    def __init__(self, n_hosts: int,
                 config: Optional[FaultToleranceConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 chaos=None):
        self.config = config or FaultToleranceConfig()
        self._clock = clock
        self._chaos = None
        if chaos is not None:
            from repro.guard import as_monkey
            self._chaos = as_monkey(chaos)
        now = clock()
        self._alive: Set[int] = set(range(n_hosts))
        self._last_seen: Dict[int, float] = {h: now for h in self._alive}
        self._step_time: Dict[int, float] = {}
        self._slow_ticks: Dict[int, int] = {}
        self.phase = RunPhase.RUNNING
        self.events: List[str] = []

    # -- inputs --------------------------------------------------------------
    def heartbeat(self, host: int, step_time: float) -> None:
        """Record one liveness report; beats from evicted hosts are
        ignored (re-admission is explicit via :meth:`rejoin`)."""
        if host not in self._alive:
            return
        if self._chaos is not None and self._chaos.should_kill_host(host):
            # injected host death: swallow the beat so the timeout
            # detector sees this host go silent
            return
        self._last_seen[host] = self._clock()
        self._step_time[host] = float(step_time)

    def rejoin(self, host: int) -> None:
        """Re-admit a host; forces a reshape to fold it into the mesh."""
        self._alive.add(host)
        self._last_seen[host] = self._clock()
        self._slow_ticks.pop(host, None)
        self._step_time.pop(host, None)
        self.events.append(f"rejoin host {host}")
        if self.phase != RunPhase.HALTED:
            self.phase = RunPhase.RESHAPING

    # -- evaluation ----------------------------------------------------------
    def tick(self) -> RunPhase:
        """Evaluate liveness now; returns the (possibly new) phase."""
        if self.phase == RunPhase.HALTED:
            return self.phase
        now = self._clock()
        cfg = self.config
        evicted = False

        for h in sorted(self._alive):
            if now - self._last_seen[h] > cfg.heartbeat_timeout:
                self._evict(h, f"failed host {h}: no heartbeat for "
                               f"{now - self._last_seen[h]:.1f}s")
                evicted = True

        if cfg.straggler_factor > 0 and len(self._alive) >= 2:
            times = sorted(self._step_time[h] for h in self._alive
                           if h in self._step_time)
            if times:
                median = times[len(times) // 2]
                for h in sorted(self._alive):
                    t = self._step_time.get(h)
                    if t is not None and t > cfg.straggler_factor * median:
                        n = self._slow_ticks.get(h, 0) + 1
                        self._slow_ticks[h] = n
                        if n >= cfg.straggler_patience:
                            self._evict(
                                h, f"straggler host {h}: {t:.2f}s vs "
                                   f"median {median:.2f}s for {n} ticks")
                            evicted = True
                    else:
                        self._slow_ticks.pop(h, None)

        if len(self._alive) < cfg.min_hosts:
            self.phase = RunPhase.HALTED
            self.events.append(
                f"halt: {len(self._alive)} hosts < min_hosts "
                f"{cfg.min_hosts}")
        elif evicted:
            self.phase = RunPhase.RESHAPING
        return self.phase

    def _evict(self, host: int, event: str) -> None:
        self._alive.discard(host)
        self._slow_ticks.pop(host, None)
        self._step_time.pop(host, None)
        self.events.append(event)

    def complete_reshape(self) -> None:
        """The runner rebuilt its mesh; resume stepping."""
        if self.phase == RunPhase.RESHAPING:
            self.phase = RunPhase.RUNNING

    # -- introspection -------------------------------------------------------
    def alive_hosts(self) -> Set[int]:
        return set(self._alive)


def plan_mesh(n_devices: int, model_parallel: int,
              multi_pod_size: Optional[int] = None
              ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """The largest valid mesh for ``n_devices`` surviving devices.

    Model parallelism stays fixed (weights are laid out over it); the
    data axis absorbs the loss, so after one 16-device host of a
    256-device pod dies, ``plan_mesh(240, 16) == ((15, 16), ...)``.
    With ``multi_pod_size`` set and more than one pod's worth of devices,
    a leading "pod" axis is planned (pods must be whole).

    Raises ``ValueError`` when the survivors cannot form a rectangular
    mesh at the requested model parallelism.
    """
    if n_devices <= 0 or model_parallel <= 0:
        raise ValueError(f"need positive device counts, got "
                         f"{n_devices=} {model_parallel=}")
    if multi_pod_size is not None and n_devices > multi_pod_size:
        if (n_devices % multi_pod_size != 0
                or multi_pod_size % model_parallel != 0):
            raise ValueError(
                f"{n_devices} devices do not form whole pods of "
                f"{multi_pod_size} at model={model_parallel}")
        pods = n_devices // multi_pod_size
        data = multi_pod_size // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    if n_devices % model_parallel != 0:
        raise ValueError(
            f"{n_devices} devices not divisible by model parallelism "
            f"{model_parallel}; evict down to a multiple or replan")
    return ((n_devices // model_parallel, model_parallel),
            ("data", "model"))


class TrainingSupervisor:
    """Drives a step loop under a controller: save on a cadence, restore
    + restart when the controller demands a reshape.

    ``run`` is runner-agnostic: the callables own the actual mesh and
    state.  ``step_fn(step)`` executes one (0-based) step and returns its
    duration; ``save_fn(completed)`` / ``restore_fn() -> completed``
    round-trip checkpoints labeled by the number of completed steps —
    ``restore_fn``'s return value is therefore the next step index to
    run, so a restored step is never re-executed;
    ``reporting_fn(step) -> hosts`` stands in for the heartbeat transport
    (defaults to "every alive host reports").
    """

    def __init__(self, controller: FaultTolerantController,
                 save_every: int = 100):
        self.controller = controller
        self.save_every = save_every

    def run(self, total_steps: int,
            step_fn: Callable[[int], float],
            save_fn: Callable[[int], None],
            restore_fn: Callable[[], int],
            reporting_fn: Optional[Callable[[int], Sequence[int]]] = None,
            start_step: int = 0) -> int:
        """Run steps ``start_step..total_steps`` to completion; returns
        the number of checkpoint restarts needed along the way.
        ``start_step`` lets a driver resume a checkpointed run under the
        same supervisor (the restore path already reports the restored
        step; this is the cold-resume equivalent)."""
        ctl = self.controller
        restarts = 0
        step = start_step
        last_dur = 0.0
        while step < total_steps:
            hosts = (reporting_fn(step) if reporting_fn is not None
                     else sorted(ctl.alive_hosts()))
            last_dur = step_fn(step)
            for h in hosts:
                ctl.heartbeat(h, last_dur)
            phase = ctl.tick()
            if phase == RunPhase.HALTED:
                break
            if phase == RunPhase.RESHAPING:
                ctl.complete_reshape()
                restarts += 1
                step = restore_fn()
                continue
            if self.save_every and (step + 1) % self.save_every == 0:
                save_fn(step + 1)  # checkpoints are labeled by steps COMPLETED
            step += 1
        return restarts
