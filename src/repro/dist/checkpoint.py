"""Checkpointing: full snapshots + LINVIEW factored incremental deltas.

The LINVIEW idea applied to training state: between two nearby steps most
large matrices change by a numerically low-rank delta (an optimizer step
driven by low-rank gradients, an adapter hot-swap, a single retrained
head row).  So instead of writing the full tree every time, the manager
writes

  * a **full** checkpoint every ``full_every`` steps (the *base*), and
  * **incremental** checkpoints in between: per matrix leaf the delta
    against the previous checkpoint is SVD-sketched to ``P Qᵀ`` with
    rank ≤ ``incremental_rank``; if the truncation error exceeds
    ``max_rel_err`` (the delta is genuinely high-rank) that leaf falls
    back to a raw copy — the §5.3 hybrid choice, per leaf, on disk.

On-disk format (see docs/dist.md):

  ``ckpt_<step>.json``   manifest: kind (full|incremental), base_step,
                         per-leaf entry {kind: full|lr|raw|same, shape,
                         dtype}
  ``ckpt_<step>.npz``    payload arrays keyed ``full::<leaf>``,
                         ``lr_p::<leaf>`` + ``lr_q::<leaf>``,
                         ``raw::<leaf>``

Restore walks the chain: latest full base, then every incremental up to
the requested step, applying ``leaf += P Qᵀ`` / replacements in order.
Deltas are always computed against the *reconstructed* previous
checkpoint (not the in-memory exact tree), so sketch truncation never
compounds across a chain.

Checkpoints are mesh-independent: leaves are fully gathered to host
numpy on save, and on restore each leaf is ``device_put`` to the
template leaf's sharding — restoring onto a smaller mesh after an
elastic resize needs no extra machinery.

Garbage collection keeps the last ``keep`` checkpoints *plus any base a
kept incremental (transitively) depends on* — an incremental whose base
was collected would be unrestorable.

Every payload array is written with a CRC32 content checksum in the
manifest; :meth:`CheckpointManager.restore` verifies them and, when a
checkpoint (or its chain) is corrupt, falls back to the newest earlier
step that reconstructs intact (``last_restored_step`` records which one
actually loaded — callers resuming training should trust it over
``latest_step``).
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FORMAT_VERSION = 1
_PREFIX = "ckpt_"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint's payload failed checksum verification (or could not
    be decoded at all)."""


def _crc(x: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(x).tobytes())


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    """Stable (path-string, leaf) pairs; path is the tree address."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _stage(leaf: Any) -> Any:
    """Caller-thread snapshot: an *owned* buffer the training loop can
    no longer touch, at device-copy (not device-to-host) cost.

    jax leaves get a device-side copy — dispatched asynchronously, never
    aliasing the argument — so the caller may immediately donate the
    original buffer to the next jitted step; the expensive D2H gather of
    the copy happens later, on the writer thread.  Host leaves are
    np.array-copied (asarray would alias: the loop could mutate a
    checkpoint that save() already returned from, and the incremental
    "same"-detection would compare a buffer against itself).
    """
    if isinstance(leaf, jax.Array):
        return jnp.copy(leaf)
    return np.array(leaf)


def _to_host(leaf: Any) -> np.ndarray:
    # writer-thread side of the snapshot: gather the staged (owned)
    # buffer to host numpy; this is the blocking D2H transfer.  Staged
    # numpy leaves already own their buffer (_stage np.array-copied
    # them), so only jax leaves pay a copy here.
    x = np.asarray(leaf) if isinstance(leaf, np.ndarray) else np.array(leaf)
    if x.dtype.kind not in "fiub" or x.dtype.itemsize == 0:
        # non-native dtypes (bfloat16 via ml_dtypes): stage as float32;
        # the manifest remembers the real dtype and restore casts back.
        x = x.astype(np.float32)
    return x


def _storage_dtype(x: np.ndarray) -> np.ndarray:
    return x if x.dtype.kind in "fiub" else x.astype(np.float32)


class CheckpointManager:
    """Save/restore pytrees with optional factored incremental deltas.

    Parameters
    ----------
    directory:          where ``ckpt_*.json`` / ``ckpt_*.npz`` live.
    async_save:         gather + encode + write on a background thread;
                        ``save`` returns after staging donation-safe
                        device-side copies (the state can keep training,
                        and may donate its buffers immediately).
                        ``blocking=True`` per call (or :meth:`wait`)
                        forces completion.
    keep:               GC budget — newest ``keep`` checkpoints survive,
                        plus the bases their chains need.
    incremental_rank:   rank cap for factored deltas; ``None`` disables
                        incremental checkpoints entirely (always full).
    full_every:         steps between full bases; an incremental is
                        written only while ``step - last_full < full_every``.
    max_rel_err:        Frobenius-relative truncation error above which a
                        leaf's delta abandons the sketch and stores raw.
    min_dim:            matrix leaves smaller than this on either side
                        are never sketched (factors would not pay).
    chaos:              optional :class:`repro.guard.ChaosConfig` /
                        ``ChaosMonkey`` — corrupts written payloads with
                        probability ``corrupt_checkpoint_p`` (testing the
                        checksum/fallback path).
    """

    def __init__(self, directory: str, *, async_save: bool = True,
                 keep: int = 5, incremental_rank: Optional[int] = None,
                 full_every: int = 10, max_rel_err: float = 1e-3,
                 min_dim: int = 8, chaos: Any = None):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.keep = keep
        self.incremental_rank = incremental_rank
        self.full_every = full_every
        self.max_rel_err = max_rel_err
        self.min_dim = min_dim
        self._chaos = None
        if chaos is not None:
            from repro.guard import as_monkey
            self._chaos = as_monkey(chaos)
        #: the step the most recent :meth:`restore` actually loaded —
        #: may be earlier than requested after a corruption fallback
        self.last_restored_step: Optional[int] = None
        self._executor = (ThreadPoolExecutor(max_workers=1,
                                             thread_name_prefix="ckpt")
                          if async_save else None)
        self._inflight: Optional[Future] = None
        self._lock = threading.Lock()
        # reconstructed value of the last checkpoint on disk (path → np);
        # incremental deltas diff against THIS, so sketch truncation does
        # not compound along a chain.
        self._base: Optional[Dict[str, np.ndarray]] = None
        self._base_step: Optional[int] = None
        self._last_full: Optional[int] = None

    # -- paths / listing -----------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"{_PREFIX}{step:08d}")

    def all_steps(self) -> List[int]:
        """Steps with a complete (manifest present) checkpoint, sorted."""
        self.wait()
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith(_PREFIX) and name.endswith(".json"):
                try:
                    steps.append(int(name[len(_PREFIX):-len(".json")]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        """Block until any in-flight async save has hit the disk."""
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> str:
        """Write ``tree`` as checkpoint ``step``; returns the path prefix
        (manifest at ``<path>.json``, payload at ``<path>.npz``).

        The caller thread only *stages* the snapshot: one donation-safe
        owned copy per leaf (device-side for jax arrays, dispatched
        async).  The device-to-host gather, the full/incremental
        encoding and the disk write all happen on the writer thread
        when ``async_save`` — the training loop can donate its buffers
        to the next step the moment this returns.  ``save`` waits for
        any previous in-flight save first, so the writer-side encoder
        state (``_base``/``_last_full``) is single-threaded.
        """
        self.wait()
        staged: Dict[str, Any] = {}
        dtypes: Dict[str, str] = {}
        for p, x in _leaf_paths(tree):
            dtypes[p] = str(x.dtype if hasattr(x, "dtype")
                            else np.asarray(x).dtype)
            staged[p] = _stage(x)
        path = self._path(step)

        def gather_encode_write():
            host = {p: _to_host(x) for p, x in staged.items()}
            incremental = (
                self.incremental_rank is not None
                and self._base is not None
                and self._base_step is not None
                and self._last_full is not None
                and step - self._last_full < self.full_every
                and set(self._base) == set(host)
            )
            if incremental:
                payload, manifest, recon = self._encode_incremental(
                    step, host, dtypes)
            else:
                payload = {f"full::{p}": _storage_dtype(x)
                           for p, x in host.items()}
                manifest = {"format_version": FORMAT_VERSION, "kind": "full",
                            "step": step, "base_step": None,
                            "leaves": {p: {"kind": "full",
                                           "shape": list(host[p].shape),
                                           "dtype": dtypes[p]}
                                       for p in host}}
                recon = host
                self._last_full = step
            manifest["checksums"] = {k: _crc(v) for k, v in payload.items()}
            self._base = recon
            self._base_step = step
            with self._lock:
                np.savez(path + ".npz", **payload)
                if self._chaos is not None:
                    self._chaos.maybe_corrupt_checkpoint(path + ".npz")
                with open(path + ".json", "w") as f:
                    json.dump(manifest, f, indent=1)
                self._gc()

        if self._executor is not None and not blocking:
            self._inflight = self._executor.submit(gather_encode_write)
        else:
            gather_encode_write()
        return path

    def _encode_incremental(self, step: int, host: Dict[str, np.ndarray],
                            dtypes: Dict[str, str]):
        payload: Dict[str, np.ndarray] = {}
        leaves: Dict[str, Dict] = {}
        recon: Dict[str, np.ndarray] = {}
        rank = int(self.incremental_rank)
        for p, new in host.items():
            base = self._base[p]
            entry = {"shape": list(new.shape), "dtype": dtypes[p]}
            if new.shape == base.shape and np.array_equal(new, base):
                entry["kind"] = "same"
                recon[p] = base
            elif (new.ndim == 2 and new.shape == base.shape
                    and min(new.shape) >= max(self.min_dim, rank + 1)):
                delta = (new.astype(np.float32)
                         - base.astype(np.float32))
                P, Q, rel = _sketch_delta(delta, rank)
                if rel <= self.max_rel_err:
                    entry["kind"] = "lr"
                    payload[f"lr_p::{p}"] = P
                    payload[f"lr_q::{p}"] = Q
                    recon[p] = (base.astype(np.float32)
                                + P @ Q.T).astype(base.dtype)
                else:
                    entry["kind"] = "raw"
                    payload[f"raw::{p}"] = _storage_dtype(new)
                    recon[p] = new
            else:
                entry["kind"] = "raw"
                payload[f"raw::{p}"] = _storage_dtype(new)
                recon[p] = new
            leaves[p] = entry
        manifest = {"format_version": FORMAT_VERSION, "kind": "incremental",
                    "step": step, "base_step": self._base_step,
                    "leaves": leaves}
        return payload, manifest, recon

    # -- restore ------------------------------------------------------------
    def _manifest(self, step: int) -> Dict:
        with open(self._path(step) + ".json") as f:
            return json.load(f)

    def _chain(self, step: int) -> List[Dict]:
        """Manifests from the full base (first) up to ``step`` (last)."""
        chain = []
        s: Optional[int] = step
        while True:
            if s is None:
                raise FileNotFoundError(
                    f"broken incremental chain below step {step} in "
                    f"{self.directory}")
            man = self._manifest(s)
            chain.append(man)
            if man["kind"] == "full":
                return list(reversed(chain))
            s = man["base_step"]

    def _load_payload(self, man: Dict) -> Dict[str, np.ndarray]:
        """Load one checkpoint's payload, verifying content checksums
        (when the manifest has them — older checkpoints are trusted)."""
        path = self._path(man["step"]) + ".npz"
        checksums = man.get("checksums")
        data: Dict[str, np.ndarray] = {}
        try:
            with np.load(path) as npz:
                for k in npz.files:
                    data[k] = npz[k]
        except Exception as e:  # zip/zlib/ValueError: undecodable payload
            raise CheckpointCorruptError(
                f"checkpoint {man['step']}: unreadable payload "
                f"{path!r}: {e!r}") from e
        if checksums is not None:
            if set(checksums) != set(data):
                raise CheckpointCorruptError(
                    f"checkpoint {man['step']}: payload keys do not match "
                    f"manifest checksums")
            for k, want in checksums.items():
                if _crc(data[k]) != want:
                    raise CheckpointCorruptError(
                        f"checkpoint {man['step']}: checksum mismatch on "
                        f"{k!r}")
        return data

    def _reconstruct(self, step: int) -> Dict[str, np.ndarray]:
        leaves: Dict[str, np.ndarray] = {}
        for man in self._chain(step):
            data = self._load_payload(man)
            if man["kind"] == "full":
                leaves = {p: data[f"full::{p}"] for p in man["leaves"]}
                continue
            for p, info in man["leaves"].items():
                if info["kind"] == "same":
                    continue
                if info["kind"] == "raw":
                    leaves[p] = data[f"raw::{p}"]
                else:  # lr: leaf += P Qᵀ
                    base = leaves[p].astype(np.float32)
                    leaves[p] = base + data[f"lr_p::{p}"] @ data[f"lr_q::{p}"].T
        return leaves

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        """Rebuild checkpoint ``step`` (default: latest) shaped like
        ``template``: same pytree structure; each leaf is cast to the
        template leaf's dtype and placed on its sharding (so a restore
        onto a re-planned mesh reshards transparently).

        Payload checksums are verified along the whole chain.  When the
        requested checkpoint is corrupt (or its chain is broken), restore
        falls back to the newest *earlier* step that reconstructs intact
        — ``last_restored_step`` records the step actually loaded, so
        resuming callers can replay from the right place."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints in {self.directory}")
        leaves = None
        errors: List[str] = []
        for s in [c for c in reversed(self.all_steps()) if c <= step]:
            try:
                leaves = self._reconstruct(s)
            except (CheckpointCorruptError, FileNotFoundError) as e:
                errors.append(str(e))
                continue
            self.last_restored_step = s
            break
        if leaves is None:
            raise CheckpointCorruptError(
                f"no intact checkpoint at or below step {step} in "
                f"{self.directory}: " + "; ".join(errors))
        flat, tdef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for kp, tleaf in flat:
            p = jax.tree_util.keystr(kp)
            if p not in leaves:
                raise KeyError(f"checkpoint {step} has no leaf {p!r}")
            val = np.asarray(leaves[p])
            tarr = np.asarray(tleaf)
            val = val.astype(tarr.dtype).reshape(tarr.shape)
            sharding = getattr(tleaf, "sharding", None)
            if isinstance(sharding, jax.sharding.NamedSharding):
                # explicitly sharded template: reshard onto its mesh
                out.append(jax.device_put(val, sharding))
            else:
                # leave uncommitted so a jit with in-body constraints can
                # place it on whatever mesh is now active (elastic resize)
                out.append(jax.numpy.asarray(val))
        return jax.tree_util.tree_unflatten(tdef, out)

    # -- GC -----------------------------------------------------------------
    def _gc(self) -> None:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith(_PREFIX) and name.endswith(".json"):
                try:
                    steps.append(int(name[len(_PREFIX):-len(".json")]))
                except ValueError:
                    continue
        steps.sort()
        retained = set(steps[-self.keep:]) if self.keep else set(steps)
        # keep every base a retained incremental chain still needs
        frontier = list(retained)
        while frontier:
            s = frontier.pop()
            try:
                man = self._manifest(s)
            except FileNotFoundError:
                continue
            base = man.get("base_step")
            if base is not None and base not in retained:
                retained.add(base)
                frontier.append(base)
        for s in steps:
            if s in retained:
                continue
            for suffix in (".json", ".npz"):
                try:
                    os.remove(self._path(s) + suffix)
                except FileNotFoundError:
                    pass


def _sketch_delta(delta: np.ndarray, rank: int
                  ) -> Tuple[np.ndarray, np.ndarray, float]:
    """SVD-truncate ``delta`` to ``P Qᵀ`` with rank ≤ ``rank``.

    Returns (P, Q, relative Frobenius truncation error).  The factored
    payload is the LINVIEW representation: ``(n + m)·r`` floats instead
    of ``n·m``.
    """
    u, s, vt = np.linalg.svd(delta, full_matrices=False)
    total = float(np.sqrt(np.sum(s * s)))
    if total == 0.0:
        return (np.zeros((delta.shape[0], 0), np.float32),
                np.zeros((delta.shape[1], 0), np.float32), 0.0)
    r = min(rank, int(np.sum(s > 0)))
    r = max(r, 1)
    rel = float(np.sqrt(np.sum(s[r:] * s[r:]))) / total
    P = (u[:, :r] * s[:r]).astype(np.float32)
    Q = vt[:r].T.astype(np.float32)
    return P, Q, rel
