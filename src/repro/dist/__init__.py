"""repro.dist — the distributed runtime (paper §6, Data Partitioning).

LINVIEW's parallelization argument: a factored trigger is a chain of
(big × skinny) matmuls, so row-sharding the big views distributes every
trigger firing with only O(n·k) factor traffic, while re-evaluation moves
whole O(n²) matrices.  This package carries that argument end to end:

  :mod:`~repro.dist.sharding`         mesh-aware placement: logical-axis
                                      rules, ``use_sharding`` context,
                                      ``shard`` constraints (the models
                                      layer's annotations resolve here)
  :mod:`~repro.dist.ivm_shard`        row-sharded execution of compiled
                                      triggers + the re-eval baseline
  :mod:`~repro.dist.checkpoint`       full + LINVIEW factored incremental
                                      checkpoints (delta = P Qᵀ on disk)
  :mod:`~repro.dist.fault_tolerance`  heartbeat failure detection,
                                      straggler eviction, elastic mesh
                                      replanning, supervised restarts

See ``docs/dist.md`` for the architecture guide.
"""

from . import checkpoint, fault_tolerance, ivm_shard, sharding
from .checkpoint import CheckpointManager
from .fault_tolerance import (FaultToleranceConfig, FaultTolerantController,
                              RunPhase, TrainingSupervisor, plan_mesh)
from .ivm_shard import (build_distributed_trigger, distributed_reeval_matmul,
                        shard_views)
from .sharding import (ShardingCtx, current_ctx, named_sharding, resolve_spec,
                       shard, tree_shardings, use_sharding)

__all__ = [
    "sharding", "ivm_shard", "checkpoint", "fault_tolerance",
    "ShardingCtx", "current_ctx", "named_sharding", "resolve_spec",
    "shard", "tree_shardings", "use_sharding",
    "build_distributed_trigger", "distributed_reeval_matmul", "shard_views",
    "CheckpointManager",
    "FaultToleranceConfig", "FaultTolerantController", "RunPhase",
    "TrainingSupervisor", "plan_mesh",
]
