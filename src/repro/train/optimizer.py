"""Optimizers (AdamW, momentum-SGD) with mixed precision + ZeRO-1 sharding.

Params live in the compute dtype (bf16 on the pod); the optimizer state
carries fp32 master weights and moments.  The *state* gets the 'opt_fsdp'
logical axis appended to the params' own axes, so on the production mesh
m/v/master are additionally sharded over the data axis (ZeRO-1) — the
update math is elementwise, so GSPMD keeps it fully local and all-gathers
only the bf16 params after the update.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    master: Any        # fp32 params
    m: Any             # first moment
    v: Any             # second moment


def adamw_init(params) -> OptState:
    # copy=True: for f32 params astype would alias the param buffer, and a
    # donated TrainState would then donate the same buffer twice.
    f32 = functools.partial(jax.tree.map,
                            lambda p: jnp.array(p, jnp.float32, copy=True))
    zeros = functools.partial(jax.tree.map,
                              lambda p: jnp.zeros(p.shape, jnp.float32))
    return OptState(step=jnp.zeros((), jnp.int32), master=f32(params),
                    m=zeros(params), v=zeros(params))


def adamw_update(grads, state: OptState, params, *, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new params in compute dtype, state, metrics)."""
    step = state.step + 1
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(gf)
    if grad_clip is not None:
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        gf = jax.tree.map(lambda g: g * scale, gf)

    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        w_new = w - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * w)
        return m_new, v_new, w_new

    flat_g, treedef = jax.tree.flatten(gf)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_w = treedef.flatten_up_to(state.master)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        a, b, c = upd(g, m, v, w)
        new_m.append(a)
        new_v.append(b)
        new_w.append(c)
    master = jax.tree.unflatten(treedef, new_w)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    st = OptState(step=step, master=master,
                  m=jax.tree.unflatten(treedef, new_m),
                  v=jax.tree.unflatten(treedef, new_v))
    return new_params, st, {"grad_norm": gnorm}


def sgdm_init(params):
    return {"step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)}


def sgdm_update(grads, state, params, *, lr, momentum: float = 0.9):
    mom = jax.tree.map(
        lambda b, g: momentum * b + g.astype(jnp.float32), state["mom"], grads)
    new_params = jax.tree.map(
        lambda p, b: (p.astype(jnp.float32) - lr * b).astype(p.dtype),
        params, mom)
    return new_params, {"step": state["step"] + 1, "mom": mom}, {}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def opt_state_axes(param_axes) -> Dict:
    """Logical axes for OptState given the params' axes: moments/master get
    'opt_fsdp' by replacing the leading *unsharded* axis — in practice we
    keep the same layout as params (already fsdp-sharded when enabled);
    ZeRO-1 falls out of the 'fsdp'/'opt_fsdp' rules."""
    return {"step": (), "master": param_axes, "m": param_axes,
            "v": param_axes}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
