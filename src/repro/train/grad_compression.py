"""LINVIEW low-rank gradient compression (beyond-paper integration #1).

The paper's core insight — "communicate only the low-rank factors, never
the full matrix" (§6 Data Partitioning / §4.2) — applied to the data-
parallel gradient all-reduce.  PowerSGD-shaped:

    P = G·Q₀;  P = orth(P);  Q = Gᵀ·P;   Ĝ = P·Qᵀ

Only P (n×k) and Q (m×k) cross the ICI instead of G (n×m): the DP
collective shrinks by ~min(n,m)/2k.  An error-feedback buffer keeps the
compression unbiased over time (E_{t+1} = G − Ĝ accumulated into the next
step's gradient), which preserves convergence.

Two execution paths:
  * ``compress_tree`` / ``decompress_tree`` — representation-level, used
    by the optimizer wrapper and the incremental checkpointer.
  * ``compressed_psum`` — an explicit shard_map all-reduce over the data
    axis that psums factors instead of gradients; this is the version the
    dry-run's collective-bytes parse sees (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class CompressionState(NamedTuple):
    q: Any       # per-leaf right factors (warm-started between steps)
    err: Any     # error-feedback buffers


def _is_compressible(x: jax.Array, min_dim: int) -> bool:
    return x.ndim >= 2 and min(_matrix_shape(x)) >= min_dim


def _matrix_shape(x: jax.Array) -> Tuple[int, int]:
    """Collapse leading dims: (a, b, …, z) → (a·b·…, z)."""
    return (int(x.size // x.shape[-1]), int(x.shape[-1]))


def init_compression(params, rank: int = 4, min_dim: int = 128, seed: int = 0
                     ) -> CompressionState:
    def q_init(path, p):
        if not _is_compressible(p, min_dim):
            return None
        n, m = _matrix_shape(p)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), hash(path) % (2**31))
        return jax.random.normal(key, (m, rank), jnp.float32)

    def e_init(p):
        return (jnp.zeros(_matrix_shape(p), jnp.float32)
                if _is_compressible(p, min_dim) else None)

    # jax.tree.map_with_path only exists on newer jax; use the stable alias
    q = jax.tree_util.tree_map_with_path(lambda kp, p: q_init(str(kp), p),
                                         params)
    err = jax.tree.map(e_init, params)
    return CompressionState(q=q, err=err)


def _orthonormalize(p: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR (k is tiny, cost O(nk²))."""
    q, _ = jnp.linalg.qr(p)
    return q


def compress_leaf(g: jax.Array, q0: Optional[jax.Array],
                  err: Optional[jax.Array]):
    """One power-iteration step → (P, Q, new_err).  Non-matrix leaves pass
    through untouched (returned as (g, None, None))."""
    if q0 is None:
        return g, None, None
    gm = g.reshape(_matrix_shape(g)).astype(jnp.float32) + err
    p = gm @ q0                       # (n, k)
    p = _orthonormalize(p)
    q = gm.T @ p                      # (m, k)
    approx = p @ q.T
    return (p, q, gm - approx)


def decompress_leaf(g_shape, dtype, p, q):
    return (p @ q.T).reshape(g_shape).astype(dtype)


def compress_tree(grads, state: CompressionState):
    """→ (compressed pytree of (P,Q)|raw, new state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_q = tdef.flatten_up_to(state.q)
    flat_e = tdef.flatten_up_to(state.err)
    out, new_q, new_e = [], [], []
    for g, q0, e in zip(flat_g, flat_q, flat_e):
        if q0 is None:
            out.append(("raw", g))
            new_q.append(None)
            new_e.append(None)
        else:
            p, q, err = compress_leaf(g, q0, e)
            out.append(("lowrank", (p, q, g.shape, g.dtype)))
            new_q.append(q)
            new_e.append(err)
    return (tdef, out), CompressionState(q=jax.tree.unflatten(tdef, new_q),
                                         err=jax.tree.unflatten(tdef, new_e))


def decompress_tree(compressed):
    tdef, out = compressed
    leaves = []
    for kind, payload in out:
        if kind == "raw":
            leaves.append(payload)
        else:
            p, q, shape, dtype = payload
            leaves.append(decompress_leaf(shape, dtype, p, q))
    return jax.tree.unflatten(tdef, leaves)


def compression_ratio(compressed) -> float:
    """Communicated bytes: factored / raw."""
    _, out = compressed
    num = den = 0
    for kind, payload in out:
        if kind == "raw":
            g = payload
            num += g.size
            den += g.size
        else:
            p, q, shape, _ = payload
            num += p.size + q.size
            den += int(jnp.prod(jnp.asarray(shape)))
    return num / max(den, 1)


# ---------------------------------------------------------------------------
# explicit shard_map compressed all-reduce (visible in dry-run HLO)
# ---------------------------------------------------------------------------


def compressed_psum(mesh, axis: str, grads, state: CompressionState,
                    rank: int = 4):
    """All-reduce data-parallel gradients by psumming *factors*.

    Per shard: local G_s → (P_s, Q_s) → psum(P), psum(Q) → Ĝ = P̄ Q̄ᵀ / p.
    Bytes on the wire per matrix: 2·n·k instead of n·m.  Matrix leaves
    only; the rest get a plain psum.
    """
    from jax.experimental.shard_map import shard_map

    flat_g, tdef = jax.tree.flatten(grads)
    flat_q = tdef.flatten_up_to(state.q)

    def body(*gs):
        outs = []
        for g, q0 in zip(gs, flat_q):
            if q0 is None:
                outs.append(jax.lax.pmean(g, axis))
            else:
                # PowerSGD two-round schedule: reduce P, orthonormalize the
                # REDUCED P, project, reduce Q.  Wire bytes per matrix:
                # k(n+m) instead of n·m.
                gm = g.reshape(_matrix_shape(g)).astype(jnp.float32)
                p_bar = jax.lax.psum(gm @ q0, axis)
                p_orth = _orthonormalize(p_bar)
                q_bar = jax.lax.pmean(gm.T @ p_orth, axis)
                approx = p_orth @ q_bar.T
                outs.append(approx.reshape(g.shape).astype(g.dtype))
        return tuple(outs)

    spec = P(axis)  # grads arrive batch-sharded over the DP axis
    fn = shard_map(body, mesh=mesh,
                   in_specs=tuple(P() for _ in flat_g),
                   out_specs=tuple(P() for _ in flat_g),
                   check_rep=False)
    return jax.tree.unflatten(tdef, list(fn(*flat_g)))
