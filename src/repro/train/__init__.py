"""Training substrate: optimizers, train step, gradient compression."""

from .optimizer import adamw_init, adamw_update, OptState
from .train_step import make_train_step, TrainState

__all__ = ["adamw_init", "adamw_update", "OptState", "make_train_step",
           "TrainState"]
