"""Train step factory: loss → grads → (optional LINVIEW compression) →
AdamW, with microbatch gradient accumulation and buffer donation.

``make_train_step`` returns a pure function suitable for jax.jit with
in/out shardings from the sharding rules; ``launch/train.py`` and
``launch/dryrun.py`` are the two callers.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LM
from .optimizer import OptState, adamw_init, adamw_update, cosine_schedule
from . import grad_compression as gc


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: jax.Array


def init_train_state(model: LM, rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params), rng=rng)


def make_train_step(model: LM, *, lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000,
                    microbatches: int = 1,
                    compression: Optional[gc.CompressionState] = None,
                    weight_decay: float = 0.1,
                    grad_clip: float = 1.0) -> Callable:
    """→ train_step(state, batch) → (state, metrics)."""
    schedule = cosine_schedule(lr, warmup, total_steps)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_grads(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accum_grads(params, batch):
        """Microbatch accumulation: split the batch leading dim."""
        def micro(batch_i):
            return single_grads(params, batch_i)

        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def body(carry, batch_i):
            loss_acc, grads_acc = carry
            loss, _, grads = micro(batch_i)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grads_acc, grads)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss_sum, grads_sum), _ = jax.lax.scan(
            body, (jnp.zeros(()), zeros), split)
        inv = 1.0 / microbatches
        return (loss_sum * inv, {},
                jax.tree.map(lambda g: g * inv, grads_sum))

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if microbatches > 1:
            loss, metrics, grads = accum_grads(state.params, batch)
        else:
            loss, metrics, grads = single_grads(state.params, batch)

        if compression is not None:
            compressed, _ = gc.compress_tree(grads, compression)
            grads = gc.decompress_tree(compressed)

        step_lr = schedule(state.opt.step + 1)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr=step_lr,
            weight_decay=weight_decay, grad_clip=grad_clip)
        out_metrics = {"loss": loss, "lr": step_lr, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt,
                          rng=state.rng), out_metrics

    return train_step
