"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rank_update(m: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """Oracle for kernels.rank_update: ``m + u @ v.T``."""
    return m + u @ v.T


def rank_update_batched(m: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """Oracle for kernels.rank_update_batched: ``m + Σ_t u[t] @ v[t].T``
    with u: (T, n, k), v: (T, p, k)."""
    return m + jnp.einsum("tnk,tpk->np", u, v)


def dual_matmul(a: jax.Array, u: jax.Array, v: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for kernels.dual_matmul: ``(a @ u, a.T @ v)``."""
    return a @ u, a.T @ v


def sherman_morrison_delta(w: jax.Array, u: jax.Array, v: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the fused SM delta: Δ(E⁻¹) = L Rᵀ (paper §4.1)."""
    u = u.reshape(-1, 1)
    v = v.reshape(-1, 1)
    wu = w @ u
    wtv = w.T @ v
    denom = 1.0 + (v.T @ wu)[0, 0]
    return -wu / denom, wtv


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 length: jax.Array | None = None) -> jax.Array:
    """Oracle for kernels.flash_decode: single-query attention over a cache.

    q: (h, d), k/v: (s, h_kv, d) with h a multiple of h_kv (GQA).
    ``length``: number of valid cache entries (rest masked).
    """
    s, h_kv, d = k.shape
    h = q.shape[0]
    group = h // h_kv
    qg = q.reshape(h_kv, group, d)
    logits = jnp.einsum("hgd,shd->hgs", qg, k) / jnp.sqrt(d).astype(q.dtype)
    if length is not None:
        mask = jnp.arange(s)[None, None, :] < length
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hgs,shd->hgd", p, v)
    return out.reshape(h, d)


def flash_attention(q, k, v, causal: bool = True):
    """Oracle for kernels.flash_attention: full softmax attention.

    q/k/v: (s, hd) → (s, hd), causal mask optional."""
    s_len, hd = q.shape
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
