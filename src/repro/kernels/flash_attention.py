"""Pallas TPU kernel: fused flash-attention forward (training/prefill).

EXPERIMENTS.md §Perf Cell A ends with: the remaining memory term of the
dense-train cells is the f32 logits/softmax traffic that XLA materializes
between fusion boundaries — exactly what this kernel removes on TPU by
keeping the (bq × bk) logits tile and the online-softmax state in VMEM.

Layout: one (batch, head) slice per call (vmap outside).
  q: (S, hd), k/v: (S, hd) → out (S, hd), with causal masking.

Grid: (nq, nk) with the KV loop innermost; the accumulator/max/sum blocks
have q-indexed maps (constant in the inner dim → consecutive revisits,
pipeline-legal).  Causal skip: kv blocks strictly above the diagonal are
masked entirely (the pl.when guard skips their FLOPs on TPU).
Normalization (acc / l) happens on the final kv block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                      *, bq: int, bk: int, seq: int, causal: bool,
                      scale: float):
    qi = pl.program_id(0)
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: block (qi, kj) is live iff kj*bk <= qi*bq + bq - 1
    live = (not causal) or (kj * bk <= qi * bq + bq - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[...]
        k = k_ref[...]
        v = v_ref[...]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 0)
            k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jnp.dot(p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
        o_ref[...] = o_ref[...] * alpha + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    # final kv block: normalize
    @pl.when(kj == nk - 1)
    def _norm():
        o_ref[...] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, bq: int = 256, bk: int = 256,
                           causal: bool = True,
                           interpret: bool = True) -> jax.Array:
    """Single (batch, head) flash attention: q/k/v (S, hd) → (S, hd)."""
    s, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    while s % bq:
        bq -= 1
    while s % bk:
        bk -= 1
    grid = (s // bq, s // bk)
    scale = hd ** -0.5
    kern = functools.partial(_flash_fwd_kernel, bq=bq, bk=bk, seq=s,
                             causal=causal, scale=scale)
    out, _, _ = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, hd), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, hd), jnp.float32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out.astype(q.dtype)
