"""Jit'd public wrappers around the Pallas kernels.

Each wrapper:
  * validates/normalizes shapes (padding ragged edges where needed),
  * picks block sizes against a VMEM budget,
  * runs the kernel in interpret mode on CPU (the container target) and
    compiled mode on TPU (``interpret=None`` → auto by backend).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .dual_matmul import dual_matmul_pallas
from .flash_attention import flash_attention_pallas
from .flash_decode import flash_decode_pallas
from .rank_update import rank_update_batched_pallas, rank_update_pallas
from .rank_update_rows import rank_update_rows_pallas, rank_update_rows_ref

VMEM_BUDGET = 12 * 1024 * 1024  # bytes we allow a kernel's working set


def _interpret_default(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=4096)
def _divisors(n: int) -> Tuple[int, ...]:
    """Sorted divisors of n via O(√n) complement-pair enumeration."""
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


@functools.lru_cache(maxsize=4096)
def _pick_block(n: int, cap: int, align: int = 8) -> int:
    """Largest divisor of n that is ≤ cap, preferring multiples of align.

    Runs on every kernel-wrapper call, so it enumerates divisors in O(√n)
    (not the O(n) scan this replaced) and memoizes: repeated calls with the
    warm jit cache cost a dict lookup.
    """
    best = 1
    for b in _divisors(n):
        if b > cap:
            break
        if b % align == 0 or b == n or b < align:
            best = b
    return best


def _shrink_block(n: int, b: int) -> int:
    """Next divisor of n strictly below b (1 if none)."""
    cands = [d for d in _divisors(n) if d < b]
    return cands[-1] if cands else 1


def rank_update(m: jax.Array, u: jax.Array, v: jax.Array,
                interpret: Optional[bool] = None) -> jax.Array:
    """``m + u @ v.T`` — in-place rank-k view update (trigger apply step)."""
    n, p = m.shape
    k = u.shape[1]
    # block choice: tile bytes = 4*(bm*bn + k*(bm+bn)) ≤ budget
    bm = _pick_block(n, 512)
    bn = _pick_block(p, 512)
    while 4 * (bm * bn + k * (bm + bn)) > VMEM_BUDGET and (bm > 8 or bn > 8):
        bm = max(8, bm // 2) if bm >= bn else bm
        bn = max(8, bn // 2) if bn > bm else bn
    if n % bm or p % bn:
        return ref.rank_update(m, u, v)  # ragged fallback
    return rank_update_pallas(m, u, v, bm=bm, bn=bn,
                              interpret=_interpret_default(interpret))


def rank_update_batched(m: jax.Array, u: jax.Array, v: jax.Array,
                        interpret: Optional[bool] = None) -> jax.Array:
    """``m + Σ_t u[t] @ v[t].T`` — T coalesced trigger applies, one pass.

    u: (T, n, k), v: (T, p, k).  Accepts 2-D (n, k)/(p, k) factors as the
    T=1 degenerate case.  The block picker budgets the full stacked panel
    (T·k columns of U and V per tile) against VMEM.
    """
    if u.ndim == 2:
        u = u[None]
        v = v[None]
    n, p = m.shape
    t, _, k = u.shape
    bm = _pick_block(n, 512)
    bn = _pick_block(p, 512)
    # tile bytes = 4*(bm*bn + T*k*(bm+bn)) ≤ budget; back off along the
    # divisor lattice (plain halving can step off it and needlessly lose
    # the kernel to the ragged fallback)
    while 4 * (bm * bn + t * k * (bm + bn)) > VMEM_BUDGET and (bm > 1 or bn > 1):
        if bm >= bn:
            bm = _shrink_block(n, bm)
        else:
            bn = _shrink_block(p, bn)
    if n % bm or p % bn:
        return ref.rank_update_batched(m, u, v)  # ragged fallback
    return rank_update_batched_pallas(m, u, v, bm=bm, bn=bn,
                                      interpret=_interpret_default(interpret))


def slab_plan(n: int, rows, *, max_fraction: float = 0.25
              ) -> Optional[Tuple[int, "jnp.ndarray"]]:
    """Host-side slab plan for a row-local sweep: ``(slab, slab_ids)``.

    Groups the affected rows (concrete, host-visible indices) into
    ``slab``-row blocks and pads the touched-slab id list to a power-of-
    two bucket with **distinct untouched** slab ids, so repeated row
    patterns reuse one compiled kernel per bucket and the aliased
    in-place write stays order-independent (each slab visited once).
    Returns ``None`` when the slab sweep cannot win — touched fraction
    above ``max_fraction`` after padding, or too few untouched slabs to
    pad with — and the caller should take the dense kernel instead.
    """
    import numpy as np
    rows = np.asarray(rows)
    if rows.size == 0:
        return None
    slab = _pick_block(n, 256)
    if slab >= n:
        return None
    ids = np.unique(rows // slab)
    bucket = 1 << (int(ids.size) - 1).bit_length()
    num_slabs = n // slab
    if bucket * slab > max_fraction * n or bucket > num_slabs:
        return None
    if bucket > ids.size:
        touched = np.zeros(num_slabs, dtype=bool)
        touched[ids] = True
        free = np.flatnonzero(~touched)[:bucket - ids.size]
        if free.size < bucket - ids.size:
            return None
        ids = np.concatenate([ids, free])
    return slab, jnp.asarray(ids.astype(np.int32))


def rank_update_rows(m: jax.Array, rows, block, v: jax.Array,
                     *, max_fraction: float = 0.25,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Row-local rank-k view update: ``m + scatter(rows, block) @ v.T``.

    ``rows`` (r,) are the affected row indices (host-concrete), ``block``
    (r, k) the compact left factor, ``v`` (p, k).  Sweeps only the
    touched row slabs through the Pallas kernel — HBM traffic scales
    with r, not n — and falls back to the dense batched kernel when the
    affected fraction exceeds ``max_fraction`` (past the crossover the
    slab gather costs more than it saves) or the shapes don't tile.
    """
    import numpy as np
    n, p = m.shape
    rows = np.asarray(rows)
    block = jnp.asarray(block)
    k = v.shape[1]
    plan = slab_plan(n, rows, max_fraction=max_fraction)
    dense_u = None
    if plan is None:
        dense_u = jnp.zeros((n, k), v.dtype).at[jnp.asarray(rows)].set(block)
        return rank_update(m, dense_u, v, interpret=interpret)
    slab, slab_ids = plan
    bn = _pick_block(p, 512)
    while 4 * (slab * bn + k * (slab + bn)) > VMEM_BUDGET and bn > 8:
        bn = max(8, bn // 2)
    if p % bn:
        return rank_update_rows_ref(m, jnp.asarray(rows.astype(np.int32)),
                                    block, v)
    u = jnp.zeros((n, k), v.dtype).at[jnp.asarray(rows)].set(block)
    return rank_update_rows_pallas(m, slab_ids, u, v, slab=slab, bn=bn,
                                   interpret=_interpret_default(interpret))


def dual_matmul(a: jax.Array, u: jax.Array, v: jax.Array,
                interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Fused ``(a @ u, a.T @ v)`` — one HBM pass over ``a``."""
    n, m = a.shape
    k = u.shape[1]
    bn = _pick_block(m, 512)
    # panel bytes = 4*(n*bn + n*k + bn*k + n*k)
    while 4 * n * (bn + 2 * k) > VMEM_BUDGET and bn > 8:
        bn = max(8, bn // 2)
    if m % bn:
        return ref.dual_matmul(a, u, v)
    return dual_matmul_pallas(a, u, v, bn=bn,
                              interpret=_interpret_default(interpret))


def sherman_morrison_delta(w: jax.Array, u: jax.Array, v: jax.Array,
                           interpret: Optional[bool] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Fused Sherman–Morrison factored delta (paper §4.1) built on the
    dual-matmul kernel: one pass over W produces both W·u and Wᵀ·v."""
    u = u.reshape(-1, 1)
    v = v.reshape(-1, 1)
    wu, wtv = dual_matmul(w, u, v, interpret=interpret)
    denom = 1.0 + (v.T @ wu)[0, 0]
    return -wu / denom, wtv


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 length: Optional[jax.Array] = None, chunk: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Single-token GQA decode attention over a cache.

    q: (h, d); k, v: (s, h_kv, d).  vmaps the per-kv-head kernel across
    the GQA groups.  Returns (h, d).
    """
    h, d = q.shape
    s, h_kv, _ = k.shape
    group = h // h_kv
    if length is None:
        length = jnp.asarray(s, dtype=jnp.int32)
    qg = q.reshape(h_kv, group, d)
    kt = k.transpose(1, 0, 2)  # (h_kv, s, d)
    vt = v.transpose(1, 0, 2)
    interp = _interpret_default(interpret)
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1

    def per_head(qh, kh, vh):
        acc, m, l = flash_decode_pallas(qh, kh, vh, length, chunk=chunk,
                                        interpret=interp)
        return acc / l

    out = jax.vmap(per_head)(qg, kt, vt)  # (h_kv, g, d)
    return out.reshape(h, d)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 256, bk: int = 256,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Fused multi-head flash attention (training/prefill hot path).

    q: (b, s, h, hd); k/v: (b, s, h, hd) — expand GQA before calling.
    vmaps the per-(batch, head) kernel.
    """
    interp = _interpret_default(interpret)

    def per_bh(qh, kh, vh):
        return flash_attention_pallas(qh, kh, vh, bq=bq, bk=bk,
                                      causal=causal, interpret=interp)

    # outer vmap over heads (axis 2), inner over batch (axis 0)
    bh = jax.vmap(jax.vmap(per_bh), in_axes=2, out_axes=2)
    return bh(q, k, v)
