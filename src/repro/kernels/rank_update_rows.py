"""Pallas TPU kernel: row-local rank-k view update (sparse trigger hot loop).

A row-local carrier touches ``r`` of ``n`` rows (``ΔM = scatter(rows, B) Vᵀ``
with row support ⊆ ``rows``).  The dense kernel in
:mod:`repro.kernels.rank_update` still streams all ``n·m`` of M through
VMEM; at 1% affected rows that is a 100x overshoot in HBM traffic for an
op that was memory-bound to begin with.  This kernel sweeps only the
**touched row slabs**:

  * the affected rows are grouped into ``slab``-row blocks; the ids of
    the touched slabs are *scalar-prefetched* (``PrefetchScalarGridSpec``)
    so the BlockSpec index maps gather exactly those M/U slabs — the
    pipeline's double-buffered DMA then only ever moves touched slabs;
  * M is updated in place via input/output aliasing; untouched slabs are
    never fetched or written (the alias keeps their bytes);
  * the left factor U is the dense-shaped ``(n, k)`` array the trigger
    already computed — zero outside the affected rows for any
    row-support-preserving view — so gathering its slabs via the same
    prefetched ids is exact, and a *padding* slab id (an untouched slab,
    used to keep the grid static) contributes ``+ 0``.

Exactness contract: padding slab ids must reference **distinct untouched
slabs** (each grid row writes its slab once — a repeated id would make
the aliased read-modify-write order-dependent).  ``ops.rank_update_rows``
enforces this and falls back to the dense kernel when the affected
fraction makes slab sweeping pointless.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rows_kernel(ids_ref, m_ref, u_ref, v_ref, o_ref):
    # one (slab, bn) tile of a touched M slab; U slab (1, slab, k);
    # V tile (bn, k).  ids_ref is consumed by the index maps only.
    del ids_ref
    upd = jnp.dot(u_ref[0], v_ref[...].T,
                  preferred_element_type=jnp.float32)
    o_ref[...] = (m_ref[...].astype(jnp.float32) + upd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("slab", "bn", "interpret"))
def rank_update_rows_pallas(m: jax.Array, slab_ids: jax.Array,
                            u: jax.Array, v: jax.Array, *,
                            slab: int, bn: int,
                            interpret: bool = True) -> jax.Array:
    """``m + u @ v.T`` sweeping only the row slabs named by ``slab_ids``.

    m: (n, p); u: (n, k) with row support contained in the listed slabs;
    v: (p, k); slab_ids: (S,) int32 — **distinct** slab indices, touched
    slabs plus optional untouched-slab padding (u is zero there).  The
    grid is (S, p/bn): wall-clock scales with the touched row count, not
    n.  Jit-compatible — slab ids are data, their count is static.
    """
    n, p = m.shape
    k = u.shape[1]
    s = slab_ids.shape[0]
    assert u.shape == (n, k) and v.shape == (p, k), (m.shape, u.shape, v.shape)
    if n % slab or p % bn:
        raise ValueError(f"shape ({n},{p}) not divisible by ({slab},{bn})")
    u_slabs = u.reshape(n // slab, slab, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, p // bn),
        in_specs=[
            pl.BlockSpec((slab, bn), lambda i, j, ids: (ids[i], j)),     # M
            pl.BlockSpec((1, slab, k), lambda i, j, ids: (ids[i], 0, 0)),  # U
            pl.BlockSpec((bn, k), lambda i, j, ids: (j, 0)),             # V
        ],
        out_specs=pl.BlockSpec((slab, bn), lambda i, j, ids: (ids[i], j)),
    )
    return pl.pallas_call(
        _rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, p), m.dtype),
        input_output_aliases={1: 0},  # in-place on M (arg 0 is slab_ids)
        interpret=interpret,
    )(slab_ids, m, u_slabs, v)


def rank_update_rows_ref(m: jax.Array, rows: jax.Array, block: jax.Array,
                         v: jax.Array) -> jax.Array:
    """XLA scatter reference: ``m.at[rows].add(block[rows-compact] @ v.T)``.

    ``rows`` may be padded with the out-of-bounds sentinel ``n`` (matching
    ``block`` rows zero): JAX drops out-of-bounds scatter indices, so the
    padding contributes nothing — this is what lets callers keep a static
    row bucket under jit.
    """
    # no unique_indices promise: sentinel padding repeats the value n
    return m.at[rows].add(jnp.dot(block, v.T,
                                  preferred_element_type=jnp.float32),
                          indices_are_sorted=True)
