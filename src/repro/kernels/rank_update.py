"""Pallas TPU kernel: rank-k view update  ``M += U Vᵀ``  (the trigger hot loop).

Every LINVIEW trigger ends in one rank-k GER per maintained view (paper
Alg. 1's ``+=`` statements).  With k ≪ n the op is memory-bound
(arithmetic intensity ≈ k/6 FLOP/byte in f32), so the kernel's job is to
stream M through VMEM exactly once at full HBM bandwidth while the MXU
computes the (bm × k) @ (k × bn) tile products.

TPU adaptation (vs the paper's BLAS GER):
  * M is tiled (bm × bn), both multiples of the (8, 128) f32 VREG tile and
    128-aligned for the MXU; U/V tiles live in VMEM across a whole row /
    column of the grid (they are k-skinny, so their footprint is tiny).
  * the update is done in place via input/output aliasing — M is read and
    written once, the roofline optimum for this op.
  * rank k is padded to the lane width (128) by ``ops.rank_update`` when
    it pays off on the MXU; the kernel itself takes any static k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (256, 256)


def _rank_update_kernel(m_ref, u_ref, v_ref, o_ref):
    # one (bm, bn) tile of M; U tile (bm, k); V tile (bn, k).
    # accumulate in f32 on the MXU, store back in the view dtype.
    upd = jnp.dot(u_ref[...], v_ref[...].T,
                  preferred_element_type=jnp.float32)
    o_ref[...] = (m_ref[...].astype(jnp.float32) + upd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def rank_update_pallas(m: jax.Array, u: jax.Array, v: jax.Array,
                       *, bm: int = DEFAULT_BLOCK[0], bn: int = DEFAULT_BLOCK[1],
                       interpret: bool = True) -> jax.Array:
    """``m + u @ v.T`` with m: (n, p), u: (n, k), v: (p, k)."""
    n, p = m.shape
    k = u.shape[1]
    assert u.shape == (n, k) and v.shape == (p, k), (m.shape, u.shape, v.shape)
    bm = min(bm, n)
    bn = min(bn, p)
    if n % bm or p % bn:
        raise ValueError(f"shape ({n},{p}) not divisible by block ({bm},{bn})")
    grid = (n // bm, p // bn)
    return pl.pallas_call(
        _rank_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # M tile
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),    # U row-panel
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),    # V row-panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), m.dtype),
        input_output_aliases={0: 0},                        # in-place on M
        interpret=interpret,
    )(m, u, v)


def _rank_update_batched_kernel(m_ref, u_ref, v_ref, o_ref):
    # one (bm, bn) tile of M; U stack (T, bm, k); V stack (T, bn, k).
    # All T tile-products accumulate in a VMEM f32 register tile; M is
    # read once and written once — the single-pass contract that makes a
    # batch of T updates cost one HBM sweep instead of T.
    t = u_ref.shape[0]
    acc = m_ref[...].astype(jnp.float32)

    def body(i, acc):
        return acc + jnp.dot(u_ref[i], v_ref[i].T,
                             preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, t, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def rank_update_batched_pallas(m: jax.Array, u: jax.Array, v: jax.Array,
                               *, bm: int = DEFAULT_BLOCK[0],
                               bn: int = DEFAULT_BLOCK[1],
                               interpret: bool = True) -> jax.Array:
    """``m + Σ_t u[t] @ v[t].T`` — the batched trigger hot loop.

    m: (n, p); u: (T, n, k); v: (T, p, k) — a stream of T rank-k updates
    applied in ONE tiled pass over m.  The sequential path streams m
    through HBM T times (arithmetic intensity k/6); the batched kernel
    streams it once (intensity T·k/6), which is exactly the §6 batching
    argument restated on the roofline.
    """
    n, p = m.shape
    t, _, k = u.shape
    assert u.shape == (t, n, k) and v.shape == (t, p, k), \
        (m.shape, u.shape, v.shape)
    bm = min(bm, n)
    bn = min(bn, p)
    if n % bm or p % bn:
        raise ValueError(f"shape ({n},{p}) not divisible by block ({bm},{bn})")
    grid = (n // bm, p // bn)
    return pl.pallas_call(
        _rank_update_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),      # M tile
            pl.BlockSpec((t, bm, k), lambda i, j: (0, i, 0)),  # U panels
            pl.BlockSpec((t, bn, k), lambda i, j: (0, j, 0)),  # V panels
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), m.dtype),
        input_output_aliases={0: 0},                           # in-place on M
        interpret=interpret,
    )(m, u, v)
