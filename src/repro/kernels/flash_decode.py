"""Pallas TPU kernel: single-token decode attention over a long KV cache.

Serving hot-spot for the ``decode_32k`` / ``long_500k`` shapes: one query
token attends over an s-long cache.  The op is strictly memory-bound
(intensity ≈ 1 FLOP/byte on K/V), so the kernel streams K/V chunks through
VMEM once with an online-softmax running state — the TPU analogue of
flash-decoding (the GPU original splits across SMs; here the split across
cores happens one level up via shard_map over the sequence axis, and this
kernel handles the per-core chunk loop).

Layout: one kv-head group per call (vmap over kv heads / batch outside).
  q: (g, d)       — the g query heads sharing this kv head (GQA group)
  k, v: (s, d)    — this kv head's cache
  length: (1, 1)  — valid prefix of the cache (rest masked)

Grid: 1-D over cache chunks; running (acc, m, l) live in revisited
constant-index output blocks (consecutive revisits — pipeline-legal).
Normalization ``acc / l`` happens in ops.flash_decode after the call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref,
                         acc_ref, m_ref, l_ref, *, chunk: int, scale: float):
    j = pl.program_id(0)
    start = j * chunk
    q = q_ref[...]                               # (g, d)
    k = k_ref[...]                               # (chunk, d)
    v = v_ref[...]                               # (chunk, d)
    length = len_ref[0, 0]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    idx = start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(idx < length, logits, NEG_INF)

    m_new = jnp.max(logits, axis=1, keepdims=True)          # (g, 1)
    p = jnp.exp(logits - m_new)                              # (g, chunk)
    l_new = jnp.sum(p, axis=1, keepdims=True)                # (g, 1)
    pv = jnp.dot(p, v, preferred_element_type=jnp.float32)   # (g, d)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j != 0)
    def _merge():
        m_old = m_ref[...]
        m_run = jnp.maximum(m_old, m_new)
        a_old = jnp.exp(m_old - m_run)
        a_new = jnp.exp(m_new - m_run)
        acc_ref[...] = acc_ref[...] * a_old + pv * a_new
        l_ref[...] = l_ref[...] * a_old + l_new * a_new
        m_ref[...] = m_run


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        length: jax.Array, *, chunk: int = 512,
                        interpret: bool = True):
    """Returns (acc, m, l); attention output = acc / l.

    q: (g, d); k, v: (s, d); length: scalar int32 array.
    """
    g, d = q.shape
    s = k.shape[0]
    assert k.shape == (s, d) and v.shape == (s, d)
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"s={s} not divisible by chunk={chunk}")
    grid = (s // chunk,)
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_flash_decode_kernel, chunk=chunk, scale=scale)
    acc, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda j: (0, 0)),       # length
            pl.BlockSpec((g, d), lambda j: (0, 0)),       # q
            pl.BlockSpec((chunk, d), lambda j: (j, 0)),   # k chunk
            pl.BlockSpec((chunk, d), lambda j: (j, 0)),   # v chunk
        ],
        out_specs=[
            pl.BlockSpec((g, d), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, d), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(length.reshape(1, 1).astype(jnp.int32), q, k, v)
    return acc, m, l
