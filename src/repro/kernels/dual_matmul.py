"""Pallas TPU kernel: fused dual tall-skinny matmul  ``(A·U, Aᵀ·V)``.

Factored delta propagation (paper §4.3, Example 4.6) evaluates, for every
squaring-style statement, *both* ``B·U`` and ``Bᵀ·V`` against the same big
view B.  Done as two XLA matmuls, B is streamed from HBM twice; both are
memory-bound (intensity ≈ k/2), so the second pass is pure waste.  This
kernel reads each column panel of B once and feeds both products —
halving HBM traffic for the dominant term of the trigger.

Grid design (TPU revisit-safety): a 1-D grid over column panels of A.
  * ``P = A·U`` accumulates into a single (n × k) output block whose index
    map is constant — consecutive revisits, the standard reduction
    pattern, allowed by the Mosaic pipeline.
  * ``Q[j] = A_panelᵀ·V`` hits each (bn × k) output block exactly once.
The column panel (n × bn) must fit VMEM; ``ops`` picks bn accordingly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dual_matmul_kernel(a_ref, u_ref, v_ref, p_ref, q_ref):
    j = pl.program_id(0)
    a = a_ref[...]                       # (n, bn) column panel
    # Q_j = A_panelᵀ V  — written once
    q_ref[...] = jnp.dot(a.T, v_ref[...], preferred_element_type=jnp.float32)
    # P += A_panel U_j  — accumulated across the grid
    pu = jnp.dot(a, u_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        p_ref[...] = pu

    @pl.when(j != 0)
    def _acc():
        p_ref[...] = p_ref[...] + pu


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def dual_matmul_pallas(a: jax.Array, u: jax.Array, v: jax.Array,
                       *, bn: int = 256, interpret: bool = True):
    """Returns ``(a @ u, a.T @ v)``; a: (n, m), u: (m, k), v: (n, k)."""
    n, m = a.shape
    k = u.shape[1]
    assert u.shape == (m, k) and v.shape == (n, k), (a.shape, u.shape, v.shape)
    bn = min(bn, m)
    if m % bn:
        raise ValueError(f"m={m} not divisible by panel bn={bn}")
    grid = (m // bn,)
    return pl.pallas_call(
        _dual_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bn), lambda j: (0, j)),   # A column panel
            pl.BlockSpec((bn, k), lambda j: (j, 0)),   # U panel
            pl.BlockSpec((n, k), lambda j: (0, 0)),    # V (whole, k-skinny)
        ],
        out_specs=[
            pl.BlockSpec((n, k), lambda j: (0, 0)),    # P (accumulated)
            pl.BlockSpec((bn, k), lambda j: (j, 0)),   # Q panel
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.float32),
        ],
        interpret=interpret,
    )(a, u, v)
