"""Training driver: data pipeline → train_step → checkpoints → fault
tolerance, on whatever mesh the host provides.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --reduced --steps 100 --batch 8 --seq 128

On a pod this is the per-host entrypoint: the mesh comes from
``make_production_mesh`` (or ``plan_mesh`` after an elastic resize), the
pipeline shards by host id, and the supervisor drives restart logic.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import TokenPipeline, synth_batch
from repro.dist.checkpoint import CheckpointManager
from repro.dist.fault_tolerance import (FaultToleranceConfig,
                                        FaultTolerantController, RunPhase,
                                        TrainingSupervisor)
from repro.dist.sharding import use_sharding
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train import grad_compression as gc
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)


def custom_100m() -> ModelConfig:
    """The ~100M end-to-end example config (llama-style dense)."""
    return ModelConfig(
        name="custom-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64,
        mlp_gated=True, dtype="float32", fsdp=False, remat="none",
        source="example")


def custom_10m() -> ModelConfig:
    """CPU-friendly variant for the checked-in convergence demo."""
    return ModelConfig(
        name="custom-10m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=768, vocab=8192, head_dim=64,
        mlp_gated=True, dtype="float32", fsdp=False, remat="none",
        source="example")


def resolve_config(args) -> ModelConfig:
    if args.arch == "custom-100m":
        return custom_100m()
    if args.arch == "custom-10m":
        return custom_10m()
    cfg = get_config(args.arch)
    return cfg.reduced() if args.reduced else cfg


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          lr: float = 3e-4, seed: int = 0, ckpt_dir: Optional[str] = None,
          save_every: int = 100, compression_rank: int = 0,
          mesh=None, log_every: int = 10, resume: bool = True,
          controller: Optional[FaultTolerantController] = None,
          ft_config: Optional[FaultToleranceConfig] = None,
          chaos=None) -> Dict:
    """Train ``cfg`` for ``steps`` steps under the fault-tolerance
    control plane: every step heartbeats the
    :class:`FaultTolerantController`, and the
    :class:`TrainingSupervisor` owns the loop — on an eviction or
    rejoin it restores from the newest checkpoint and continues, on
    ``HALTED`` it stops.  A healthy single-host run takes exactly the
    same step sequence as the bare loop it replaced.

    ``controller`` injects a pre-built controller (tests drive failures
    through it); by default one is built over ``jax.process_count()``
    hosts with ``ft_config``.  ``chaos`` (a
    :class:`repro.guard.ChaosConfig` / ``ChaosMonkey``) threads fault
    injection through the checkpoint manager (payload corruption) and
    the controller (host kills) — the chaos-harness entry point for
    end-to-end recovery drills.
    """
    if chaos is not None:
        from repro.guard import as_monkey
        chaos = as_monkey(chaos)
    model = build_model(cfg)
    shape = ShapeConfig("train", seq, batch, "train")
    state = init_train_state(model, jax.random.PRNGKey(seed))
    comp = (gc.init_compression(state.params, rank=compression_rank)
            if compression_rank else None)
    step_fn = make_train_step(model, lr=lr, warmup=min(50, steps // 10 + 1),
                              total_steps=steps, compression=comp)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    mgr = (CheckpointManager(ckpt_dir, async_save=True, chaos=chaos)
           if ckpt_dir else None)
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        state = mgr.restore(state, step=mgr.latest_step())
        # a checksum fallback may have loaded an earlier intact step;
        # resume from what was actually restored, not what was asked for
        start = mgr.last_restored_step
        print(f"[train] resumed from step {start}")

    ctl = controller or FaultTolerantController(
        n_hosts=max(jax.process_count(), 1), config=ft_config, chaos=chaos)
    supervisor = TrainingSupervisor(ctl, save_every=save_every if mgr else 0)

    # the supervisor owns the loop; the closures own the state
    box = {"state": state, "t_last": time.perf_counter()}
    history: list = []

    def run_step(t: int) -> float:
        t0 = time.perf_counter()
        batch_np = synth_batch(cfg, shape, seed=seed, step=t)
        box["state"], metrics = step_fn(box["state"],
                                        {k: jnp.asarray(v)
                                         for k, v in batch_np.items()})
        if (t + 1) % log_every == 0 or t == steps - 1:
            loss = float(metrics["loss"])
            dt = (time.perf_counter() - box["t_last"]) / log_every
            box["t_last"] = time.perf_counter()
            tok_s = batch * seq / dt
            print(f"[train] step {t+1:5d} loss {loss:7.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"{dt*1e3:7.1f} ms/step {tok_s:9.0f} tok/s",
                  flush=True)
            history.append({"step": t + 1, "loss": loss,
                            "ms_per_step": dt * 1e3})
        return time.perf_counter() - t0

    def save(t: int) -> None:
        if mgr:
            mgr.save(t, box["state"])

    def restore() -> int:
        if mgr is None or mgr.latest_step() is None:
            # nothing to restore from: restart the run from scratch
            box["state"] = init_train_state(model, jax.random.PRNGKey(seed))
            return 0
        from repro.dist.checkpoint import CheckpointCorruptError
        try:
            box["state"] = mgr.restore(box["state"], step=mgr.latest_step())
        except CheckpointCorruptError as e:
            print(f"[train] every checkpoint corrupt ({e}); "
                  f"restarting from scratch")
            box["state"] = init_train_state(model, jax.random.PRNGKey(seed))
            history[:] = []
            return 0
        # restore() falls back past corrupt checkpoints; replay from the
        # step it actually loaded, not the newest one on disk
        s = mgr.last_restored_step
        # drop log entries from steps the restart will replay, so
        # history/--out never carry duplicate step records
        history[:] = [h for h in history if h["step"] <= s]
        print(f"[train] restart: restored step {s} "
              f"({len(ctl.alive_hosts())} hosts alive)")
        return s

    ctx = use_sharding(mesh) if mesh is not None else _null_ctx()
    with ctx:
        restarts = supervisor.run(steps, run_step, save, restore,
                                  start_step=start)
        if mgr and ctl.phase != RunPhase.HALTED:
            mgr.save(steps, box["state"], blocking=True)
    if ctl.phase == RunPhase.HALTED:
        print(f"[train] HALTED: {ctl.events[-1] if ctl.events else ''}")
    return {"history": history,
            "final_loss": history[-1]["loss"] if history else None,
            "restarts": restarts,
            "phase": ctl.phase.value,
            "ft_events": list(ctl.events)}


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="custom-10m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--compression-rank", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "local"], default="none")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0)
    ap.add_argument("--straggler-factor", type=float, default=0.0,
                    help="evict hosts slower than this × median step time "
                         "(0 disables)")
    ap.add_argument("--min-hosts", type=int, default=1)
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-corrupt-ckpt-p", type=float, default=0.0,
                    help="probability of corrupting each written "
                         "checkpoint payload (recovery drill)")
    ap.add_argument("--chaos-kill-host-p", type=float, default=0.0,
                    help="per-heartbeat probability of killing a host")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = resolve_config(args)
    mesh = (make_local_mesh(args.model_parallel)
            if args.mesh == "local" else None)
    ft = FaultToleranceConfig(heartbeat_timeout=args.heartbeat_timeout,
                              straggler_factor=args.straggler_factor,
                              min_hosts=args.min_hosts)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}×{args.seq}")
    chaos = None
    if args.chaos_corrupt_ckpt_p > 0 or args.chaos_kill_host_p > 0:
        from repro.guard import ChaosConfig
        chaos = ChaosConfig(seed=args.chaos_seed,
                            corrupt_checkpoint_p=args.chaos_corrupt_ckpt_p,
                            kill_host_p=args.chaos_kill_host_p)
    result = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                   lr=args.lr, ckpt_dir=args.ckpt_dir,
                   save_every=args.save_every,
                   compression_rank=args.compression_rank, mesh=mesh,
                   ft_config=ft, chaos=chaos)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
