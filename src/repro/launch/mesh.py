"""Production mesh construction.

A function, not a module constant: importing this module never touches
jax device state (device count is locked at first backend init, and smoke
tests must see 1 CPU device while the dry-run sees 512 placeholders).

Shapes come from :func:`repro.dist.fault_tolerance.plan_mesh` so the
launch path and the elastic-resize path (a supervisor replanning after an
eviction) can never disagree about what a valid mesh looks like.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.dist.fault_tolerance import plan_mesh

POD_CHIPS = 256
MODEL_PARALLEL = 16


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    n = 2 * POD_CHIPS if multi_pod else POD_CHIPS
    shape, axes = plan_mesh(n, MODEL_PARALLEL,
                            multi_pod_size=POD_CHIPS if multi_pod else None)
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(n_devices: int, model_parallel: int = MODEL_PARALLEL,
                      multi_pod_size: Optional[int] = None):
    """The mesh for however many devices survived — the supervisor calls
    this after an eviction (e.g. 240 devices → (15, 16))."""
    shape, axes = plan_mesh(n_devices, model_parallel,
                            multi_pod_size=multi_pod_size)
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Whatever devices exist, data-major — used by tests/examples."""
    n = len(jax.devices())
    if n % model_parallel:
        raise ValueError(f"{n} devices % model={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel), axis_names)
