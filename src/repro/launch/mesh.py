"""Production mesh construction.

A function, not a module constant: importing this module never touches
jax device state (device count is locked at first backend init, and smoke
tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Whatever devices exist, data-major — used by tests/examples."""
    n = len(jax.devices())
    if n % model_parallel:
        raise ValueError(f"{n} devices % model={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel), axis_names)
