"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b \
      --reduced --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine
from .train import custom_10m, custom_100m


def serve_fivm(args) -> None:
    """Models-as-views serving (docs/fivm.md): data arrival and model
    refresh are decoupled — ingest banks factored deltas into the
    ring's deferred windows, each read folds and re-solves — and the
    same ring shape runs as a fleet tenant so staleness is accounted
    against the tenant SLO."""
    from repro.apps import get_app
    from repro.data import labeled_stream
    from repro.fivm.registry import RingRegistry, submit_event
    from repro.fleet import FleetConfig, FleetScheduler

    app = get_app("fivm_learning")(
        features=args.fivm_features, capacity=args.fivm_capacity,
        order=2, churn=0.3)
    app.ingest(8)
    app.refresh()          # compile + first solve outside the ledger
    out = app.serve_demo(bursts=args.fivm_bursts,
                         burst_size=args.fivm_burst_size)
    print(f"[serve] fivm decoupled ring: {out['events']} events "
          f"({out['live']:.0f} live), "
          f"ingest {out['ingest_us_per_event']:.0f} us/event, "
          f"reads {[f'{t:.1f}ms' for t in out['read_ms']]}, "
          f"folds={out['folds']} strategies={out['strategies']}")

    # fleet-hosted ring tenant: same carriers, lease-claimed refresh,
    # SLO staleness accounting
    spec = app.spec
    fleet = FleetScheduler(FleetConfig(lease_ttl=0.5,
                                       workers=args.fleet_workers))
    reg = RingRegistry()
    reg.add_fleet_tenant(fleet, spec, "fivm-ring", slo_s=0.5)
    stream = labeled_stream(spec.features, targets=spec.targets,
                            capacity=spec.capacity, churn=0.3, seed=1)
    fleet.start()
    try:
        t0 = time.perf_counter()
        n = args.fivm_bursts * args.fivm_burst_size
        for ev in stream.events(n):
            submit_event(fleet, "fivm-ring", spec.capacity, ev)
        fleet.drain(["fivm-ring"])
        dt = time.perf_counter() - t0
        G = fleet.read_views("fivm-ring")["G"]
        health = fleet.tenant_health()[0]
        print(f"[serve] fivm fleet tenant: {n} events in {dt:.2f}s "
              f"({3 * n / dt:.0f} firings/s), G={tuple(G.shape)}, "
              f"staleness={health['staleness_s']:.3f}s "
              f"(slo={health['slo_s']}s) health={health}")
    finally:
        fleet.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="custom-10m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--logit-view", action="store_true",
                    help="attach a guarded incremental lm_head logit "
                         "view, drive hot-swap deltas through it, and "
                         "print per-view serving health")
    ap.add_argument("--corpus", type=int, default=64,
                    help="--logit-view corpus size (cached hidden rows)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve N fleet tenants (one logit view each) "
                         "through repro.fleet: lease-claimed refresh "
                         "workers, admission control, shared trigger "
                         "cache; prints fleet health + stats")
    ap.add_argument("--fleet-workers", type=int, default=2)
    ap.add_argument("--fivm", action="store_true",
                    help="serve the repro.fivm learning views instead "
                         "of token generation: a maintained gram ring "
                         "in decoupled (order=2, bank-on-ingest, "
                         "fold-on-read) mode, plus a fleet-hosted ring "
                         "tenant with SLO staleness accounting")
    ap.add_argument("--fivm-features", type=int, default=24)
    ap.add_argument("--fivm-capacity", type=int, default=256)
    ap.add_argument("--fivm-bursts", type=int, default=8)
    ap.add_argument("--fivm-burst-size", type=int, default=48)
    args = ap.parse_args()

    if args.fivm:
        serve_fivm(args)
        return

    if args.arch == "custom-10m":
        cfg = custom_10m()
    elif args.arch == "custom-100m":
        cfg = custom_100m()
    else:
        cfg = get_config(args.arch)
        cfg = cfg.reduced() if args.reduced else cfg
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    degrade = None
    if args.logit_view:
        from repro.guard import DegradePolicy
        degrade = DegradePolicy()
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_seq=args.max_seq, temperature=args.temperature,
                      degrade=degrade)
    rng = np.random.default_rng(0)
    if args.logit_view:
        # guarded corpus logit view over a synthetic cached-hidden corpus:
        # hot-swap a burst of lm_head deltas, then report serving health
        from repro.serve.incremental_views import IncrementalLogitView
        d = cfg.d_model
        hidden = rng.standard_normal((args.corpus, d)).astype(np.float32)
        head = rng.standard_normal((cfg.vocab, d)).astype(np.float32) * 0.02
        eng.attach_logit_view("lm_head",
                              IncrementalLogitView(hidden, head))
        for _ in range(8):
            u = rng.standard_normal((cfg.vocab, 1)).astype(np.float32) * .01
            v = rng.standard_normal((d, 1)).astype(np.float32) * .01
            eng.hot_swap("lm_head", u, v)
        eng.flush_views()
        logits = eng.view_logits("lm_head")
        print(f"[serve] logit view: {logits.shape} "
              f"health={eng.view_health()['lm_head']}")
    if args.fleet > 0:
        # multi-tenant serving: N tenants, each its own corpus logit
        # view, refreshed by a shared lease-coordinated worker pool.
        # Same-shape tenants share compiled triggers (fleet cache).
        from repro.fleet import FleetConfig, FleetScheduler, TenantSpec
        from repro.serve.incremental_views import build_logit_view_program
        d, p = cfg.d_model, cfg.vocab
        fleet = FleetScheduler(FleetConfig(lease_ttl=0.5,
                                           workers=args.fleet_workers))
        tenant_of = {}
        for i in range(args.fleet):
            tid = f"tenant-{i}"
            prog = build_logit_view_program(args.corpus, d, p)
            inputs = {
                "H": rng.standard_normal((args.corpus, d)
                                         ).astype(np.float32),
                "W": rng.standard_normal((p, d)).astype(np.float32) * .02,
            }
            fleet.add_tenant(TenantSpec(tid, prog, {"W": 1}, slo_s=0.25,
                                        quota_rate=200.0, quota_burst=32),
                             inputs)
            tenant_of[f"lm_head.{i}"] = tid
        eng.attach_fleet(fleet, tenant_of)
        fleet.start()
        try:
            for _ in range(8):
                for path in tenant_of:
                    u = rng.standard_normal((p, 1)).astype(np.float32) * .01
                    v = rng.standard_normal((d, 1)).astype(np.float32) * .01
                    eng.hot_swap(path, u, v)
            eng.flush_views()
            for path in tenant_of:
                logits = eng.view_logits(path)
                print(f"[serve] fleet view {path}: {logits.shape} "
                      f"health={eng.view_health()[path]}")
            print(f"[serve] fleet stats: {fleet.fleet_stats()}")
        finally:
            fleet.stop()
    prompts = rng.integers(1, cfg.vocab, size=(args.batch, args.prompt_len)
                           ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
