"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b \
      --reduced --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine
from .train import custom_10m, custom_100m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="custom-10m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--logit-view", action="store_true",
                    help="attach a guarded incremental lm_head logit "
                         "view, drive hot-swap deltas through it, and "
                         "print per-view serving health")
    ap.add_argument("--corpus", type=int, default=64,
                    help="--logit-view corpus size (cached hidden rows)")
    args = ap.parse_args()

    if args.arch == "custom-10m":
        cfg = custom_10m()
    elif args.arch == "custom-100m":
        cfg = custom_100m()
    else:
        cfg = get_config(args.arch)
        cfg = cfg.reduced() if args.reduced else cfg
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    degrade = None
    if args.logit_view:
        from repro.guard import DegradePolicy
        degrade = DegradePolicy()
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_seq=args.max_seq, temperature=args.temperature,
                      degrade=degrade)
    rng = np.random.default_rng(0)
    if args.logit_view:
        # guarded corpus logit view over a synthetic cached-hidden corpus:
        # hot-swap a burst of lm_head deltas, then report serving health
        from repro.serve.incremental_views import IncrementalLogitView
        d = cfg.d_model
        hidden = rng.standard_normal((args.corpus, d)).astype(np.float32)
        head = rng.standard_normal((cfg.vocab, d)).astype(np.float32) * 0.02
        eng.attach_logit_view("lm_head",
                              IncrementalLogitView(hidden, head))
        for _ in range(8):
            u = rng.standard_normal((cfg.vocab, 1)).astype(np.float32) * .01
            v = rng.standard_normal((d, 1)).astype(np.float32) * .01
            eng.hot_swap("lm_head", u, v)
        eng.flush_views()
        logits = eng.view_logits("lm_head")
        print(f"[serve] logit view: {logits.shape} "
              f"health={eng.view_health()['lm_head']}")
    prompts = rng.integers(1, cfg.vocab, size=(args.batch, args.prompt_len)
                           ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
