"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b \
      --reduced --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine
from .train import custom_10m, custom_100m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="custom-10m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.arch == "custom-10m":
        cfg = custom_10m()
    elif args.arch == "custom-100m":
        cfg = custom_100m()
    else:
        cfg = get_config(args.arch)
        cfg = cfg.reduced() if args.reduced else cfg
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_seq=args.max_seq, temperature=args.temperature)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(args.batch, args.prompt_len)
                           ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
