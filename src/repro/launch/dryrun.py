import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

512 placeholder CPU devices stand in for 2 pods × 256 chips.  Nothing is
allocated: params/optimizer/caches enter as ShapeDtypeStructs, the cell is
``jit(step).lower(...).compile()``, and the proof artifacts are
``compiled.memory_analysis()`` (fits per chip) and ``cost_analysis()`` +
the parsed collective schedule (roofline terms, EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-32b --shape train_4k
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_batch_specs
from repro.dist.sharding import (ShardingCtx, named_sharding, resolve_spec,
                                 tree_shardings, use_sharding)
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM, build_model
from repro.roofline.analysis import (analyze_compiled, model_bytes_estimate,
                                     model_flops_estimate)
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.train.optimizer import OptState
from repro.train.train_step import TrainState, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _spec_tree(axes_tree, shapes_tree, ctx):
    return tree_shardings(axes_tree, shapes_tree, ctx)


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def _dryrun_config(cfg: ModelConfig, overrides: Optional[Dict] = None
                   ) -> ModelConfig:
    """Dry-run defaults: full remat (activation fit at pod scale)."""
    base = dataclasses.replace(cfg, remat="full")
    if overrides:
        base = dataclasses.replace(base, **overrides)
    return base


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict] = None,
               rules: Optional[Dict] = None,
               microbatches: int = 1):
    """Returns (lowered, ctx, meta) for one cell."""
    cfg = _dryrun_config(get_config(arch), overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)

    with use_sharding(mesh, rules=rules) as ctx:
        param_shapes = jax.eval_shape(model.init, rng)
        param_axes = model.param_axes()
        param_sh = _spec_tree(param_axes, param_shapes, ctx)
        rep = named_sharding((), None, ctx)

        if shape.kind == "train":
            step_fn = make_train_step(model, microbatches=microbatches)
            opt_shapes = OptState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                master=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    param_shapes),
                m=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    param_shapes),
                v=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    param_shapes))
            opt_sh = OptState(step=rep,
                              master=_spec_tree(param_axes, opt_shapes.master,
                                                ctx),
                              m=_spec_tree(param_axes, opt_shapes.m, ctx),
                              v=_spec_tree(param_axes, opt_shapes.v, ctx))
            state_shapes = TrainState(
                params=param_shapes, opt=opt_shapes,
                rng=jax.ShapeDtypeStruct((2,), jnp.uint32))
            state_sh = TrainState(params=param_sh, opt=opt_sh, rng=rep)
            batch_shapes = make_batch_specs(cfg, shape)
            batch_sh = {
                k: named_sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                  v.shape, ctx)
                for k, v in batch_shapes.items()}
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_shapes)

        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model)
            batch_shapes = make_batch_specs(cfg, shape)
            batch_sh = {
                k: named_sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                  v.shape, ctx)
                for k, v in batch_shapes.items()}
            jitted = jax.jit(step_fn, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(param_shapes, batch_shapes)

        else:  # decode
            long_ctx = shape.seq_len > 100_000
            step_fn = make_serve_step(model)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         long_context=long_ctx))
            cache_axes = model.cache_axes(long_context=long_ctx)
            cache_sh = _spec_tree(cache_axes, cache_shapes, ctx)
            token_sh = named_sharding(("batch", None),
                                      (shape.global_batch, 1), ctx)
            token_shape = jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32)
            pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step_fn,
                             in_shardings=(param_sh, cache_sh, token_sh, rep),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_shapes, cache_shapes, token_shape,
                                   pos_shape)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "model_flops": model_flops_estimate(cfg, shape),
            "model_bytes": model_bytes_estimate(cfg, shape),
            "bf16": cfg.dtype == "bfloat16"}
    return lowered, meta


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, overrides: Optional[Dict] = None,
             rules: Optional[Dict] = None, tag: str = "baseline",
             microbatches: int = 1, verbose: bool = True) -> Dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    key = f"{arch}__{shape_name}__{mesh_name}__{tag}".replace("/", "_")
    out_path = os.path.join(RESULTS_DIR, key + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = time.time()
    try:
        lowered, meta = build_cell(arch, shape_name, multi_pod,
                                   overrides=overrides, rules=rules,
                                   microbatches=microbatches)
    except SkipCell as e:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "tag": tag, "status": "skipped", "reason": str(e)}
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        if verbose:
            print(f"[dryrun] SKIP {key}: {e}", flush=True)
        return result

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    report = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=meta["chips"], model_flops=meta["model_flops"],
        model_bytes=meta["model_bytes"], bf16_model=meta["bf16"])
    mem = compiled.memory_analysis()
    result = {**meta, "tag": tag, "status": "ok",
              "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
              "memory_analysis": report.memory_per_chip,
              "roofline": report.to_dict()}
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    if verbose:
        print(f"[dryrun] OK {key}: compile {t_compile:.0f}s | "
              f"mem/chip arg={report.memory_per_chip['argument_bytes']/2**30:.2f}GiB "
              f"temp={report.memory_per_chip['temp_bytes']/2**30:.2f}GiB | "
              f"T(comp/mem/coll)={report.t_compute*1e3:.1f}/"
              f"{report.t_memory*1e3:.1f}/{report.t_collective*1e3:.1f} ms | "
              f"bottleneck={report.bottleneck} "
              f"frac={report.roofline_fraction:.2f} "
              f"bwfrac={report.bandwidth_fraction:.2f}", flush=True)
        print(f"         memory_analysis: {mem}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", type=str, default="baseline")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp, force=args.force, tag=args.tag)
        except Exception as e:
            traceback.print_exc()
            failures.append((a, s, mp, str(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"[dryrun] all {len(cells)} cells done")


if __name__ == "__main__":
    main()
