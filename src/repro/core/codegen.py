"""Codegen: symbolic expressions / triggers → jitted JAX callables.

The evaluator stages a trigger body into a single XLA program: every factor
block is a chain of (big × skinny) or (skinny × skinny) matmuls, and the
``+=`` updates donate the view buffers so the update happens in place.

Backends for the rank-k apply (``M += U Vᵀ``) are pluggable:
  - "xla": plain jnp (default everywhere),
  - "pallas": the VMEM-tiled TPU kernel from ``repro.kernels.rank_update``
    (interpret-mode on CPU; the kernel is the TPU hot path).
"""

from __future__ import annotations

import functools
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import expr as ex
from .compiler import Assign, CompiledProgram, Trigger, ViewUpdate
from .expr import Expr
from .factored import ColSlice, HStack
from .program import Program


Array = jax.Array
Env = Dict[str, Array]


def _dim(d, binding: Dict[str, int]) -> int:
    return binding[d.name] if isinstance(d, ex.Dim) else int(d)


def evaluate(e: Expr, env: Env, binding: Dict[str, int],
             cache: Optional[Dict[int, Array]] = None) -> Array:
    """Evaluate a symbolic expression against concrete arrays.

    ``cache`` keyed by interned node id gives cross-expression CSE: blocks
    of the same trigger share subcomputations for free.
    """
    if cache is None:
        cache = {}

    def go(x: Expr) -> Array:
        hit = cache.get(id(x))
        if hit is not None:
            return hit
        out = _eval_node(x, env, binding, go)
        cache[id(x)] = out
        return out

    return go(e)


def _eval_node(x: Expr, env: Env, binding, go) -> Array:
    if isinstance(x, ex.Var):
        try:
            return env[x.name]
        except KeyError:
            raise KeyError(f"unbound variable {x.name}; have {sorted(env)}")
    if isinstance(x, ex.Zero):
        return jnp.zeros((_dim(x.shape[0], binding), _dim(x.shape[1], binding)),
                         dtype=jnp.float32)
    if isinstance(x, ex.Identity):
        return jnp.eye(_dim(x.shape[0], binding), dtype=jnp.float32)
    if isinstance(x, ex.Const):
        return jnp.full((1, 1), x.value, dtype=jnp.float32)
    if isinstance(x, ex.MatMul):
        return go(x.lhs) @ go(x.rhs)
    if isinstance(x, ex.Add):
        terms = [go(t) for t in x.terms]
        return functools.reduce(jnp.add, terms)
    if isinstance(x, ex.Scale):
        f = go(x.factor)
        if f.ndim == 2:  # (1,1) scalar view
            f = f[0, 0]
        return f * go(x.operand)
    if isinstance(x, ex.Transpose):
        return go(x.operand).T
    if isinstance(x, ex.Inverse):
        a = go(x.operand)
        if a.shape == (1, 1):
            return 1.0 / a
        return jnp.linalg.inv(a)
    if isinstance(x, HStack):
        return jnp.concatenate([go(b) for b in x.blocks], axis=1)
    if isinstance(x, ColSlice):
        return go(x.operand)[:, x.col:x.col + 1]
    raise TypeError(f"cannot evaluate {type(x).__name__}")


# ---------------------------------------------------------------------------
# program re-evaluation (the paper's baseline strategy)
# ---------------------------------------------------------------------------


def build_evaluator(program: Program,
                    binding: Optional[Dict[str, int]] = None,
                    jit: bool = True) -> Callable[[Env], Env]:
    """Full re-evaluation: returns {view name: value} for all statements."""
    binding = dict(program.dims if binding is None else binding)

    def run(inputs: Env) -> Env:
        env: Env = dict(inputs)
        cache: Dict[int, Array] = {}
        out: Env = {}
        for st in program.statements:
            val = evaluate(st.expr, env, binding, cache)
            env[st.target.name] = val
            out[st.target.name] = val
        return out

    return jax.jit(run) if jit else run


# ---------------------------------------------------------------------------
# trigger execution (the incremental strategy)
# ---------------------------------------------------------------------------


def _apply_lowrank_xla(view: Array, u: Array, v: Array) -> Array:
    return view + u @ v.T


def _get_apply_fn(backend: str):
    if backend == "xla":
        return _apply_lowrank_xla
    if backend == "pallas":
        # rank_update_batched subsumes the single-update case (a 2-D
        # (n, k) factor pair is the T=1 stack), so every trigger apply —
        # per-update or stacked batch — goes through the one-pass kernel.
        from repro.kernels import ops as rk_ops
        return rk_ops.rank_update_batched
    raise ValueError(f"unknown apply backend {backend!r}")


@functools.lru_cache(maxsize=256)
def _finite_check_jit(names: Tuple[str, ...]) -> Callable:
    def check(views: Env) -> Array:
        return jnp.stack([jnp.isfinite(views[n]).all() for n in names])
    return jax.jit(check)


def build_finite_check(names) -> Callable:
    """Jitted fused finiteness probe over the views in ``names``.

    Returns ``fn(views) -> bool[len(names)]`` (True = all-finite), one
    fused XLA program and one device sync for the whole set — the
    post-firing output validation (:func:`repro.guard.txn.check_finite`)
    runs this on every guarded firing, so it must not retrace or probe
    view-by-view.  Cached on the name tuple; views may hold extra keys.
    """
    return _finite_check_jit(tuple(names))


def trigger_touched_views(trigger: Trigger) -> Tuple[Tuple[str, ...],
                                                     Tuple[str, ...]]:
    """(written, read-only) view names a trigger actually touches.

    ``written`` are the ``+=`` targets; ``read-only`` are views referenced
    by the factor-block assigns but never updated.  Everything else in the
    store is invisible to the trigger and must not cross the jit boundary.
    """
    local = {trigger.u_var.name, trigger.v_var.name}
    local.update(a.name for a in trigger.assigns)
    written = tuple(dict.fromkeys(up.view for up in trigger.updates))
    read = set()
    for a in trigger.assigns:
        read |= set(a.expr.free_vars())
    read -= local
    read -= set(written)
    return written, tuple(sorted(read))


_donation_warned = False


def _warn_donation_ignored() -> None:
    """One-time capability warning: ``donate=True`` on a backend that
    silently ignores donation (CPU) still pays a full copy of every
    written view per firing.  Roofline comparisons of the dense vs
    row-slab sweeps are misread without this — the "in-place" dense
    sweep is really write-allocate + copy there, flattering the slab
    path by exactly one ``n·m`` write.  Fires once per process."""
    global _donation_warned
    if _donation_warned:
        return
    if jax.default_backend() == "cpu":
        _donation_warned = True
        warnings.warn(
            "buffer donation requested but the CPU backend silently "
            "ignores it: written views are copied, not updated in place. "
            "Interpret sweep rooflines (dense vs row-slab) accordingly; "
            "donation is honored on TPU/GPU.",
            RuntimeWarning, stacklevel=3)


def build_trigger_fn(trigger: Trigger, program: Program,
                     binding: Optional[Dict[str, int]] = None,
                     jit: bool = True,
                     apply_backend: str = "xla",
                     donate: bool = False) -> Callable[[Env, Array, Array], Env]:
    """Stage a trigger into ``(views, U, V) -> views``.

    ``views`` must contain the input matrices and every maintained view;
    the dict is updated **in place** with the new values and returned.
    Only the views the trigger touches cross the jit boundary — the
    untouched rest of the store is never copied, traced, or dispatched
    (the old implementation round-tripped the whole dict through XLA on
    every firing).  With ``donate=True`` the written views' buffers are
    donated, so the update is genuinely in-place on device; read-only
    views are never donated (callers may hold references).
    """
    binding = dict(program.dims if binding is None else binding)
    apply_fn = _get_apply_fn(apply_backend)
    written, read_only = trigger_touched_views(trigger)
    if donate:
        _warn_donation_ignored()

    def core(written_vals: Tuple[Array, ...], read_vals: Tuple[Array, ...],
             u: Array, v: Array) -> Tuple[Array, ...]:
        env: Env = dict(zip(written, written_vals))
        env.update(zip(read_only, read_vals))
        env[trigger.u_var.name] = u
        env[trigger.v_var.name] = v
        cache: Dict[int, Array] = {}
        for a in trigger.assigns:
            env[a.name] = evaluate(a.expr, env, binding, cache)
        for up in trigger.updates:
            if up.kind == "lowrank":
                env[up.view] = apply_fn(env[up.view], env[up.u], env[up.v])
            else:
                env[up.view] = env[up.view] + env[up.d]
        return tuple(env[name] for name in written)

    if jit:
        core = jax.jit(core, donate_argnums=(0,) if donate else ())

    def run(views: Env, u: Array, v: Array) -> Env:
        new_vals = core(tuple(views[n] for n in written),
                        tuple(views[n] for n in read_only), u, v)
        views.update(zip(written, new_vals))
        return views

    return run


# ---------------------------------------------------------------------------
# row-slab trigger execution (row-local carriers, §3–§5 containment)
# ---------------------------------------------------------------------------


def _expr_refs(e: Expr, names) -> bool:
    """Whether ``e`` references any :class:`~repro.core.expr.Var` in
    ``names`` (iterative — factor chains can be deep)."""
    stack = [e]
    while stack:
        x = stack.pop()
        if isinstance(x, ex.Var) and x.name in names:
            return True
        stack.extend(x.children)
    return False


def _compact_left_safe(e: Expr, left) -> bool:
    """Whether a left factor-block expression can be evaluated with the
    update's **compact** ``(r, k)`` row block bound in place of the dense
    ``(n, k)`` scattered factor.

    This is :func:`~repro.core.delta.row_support_preserved` sharpened
    into an execution contract: every constructor that preserves row
    support also *commutes with the row gather* — ``(α·L)[rows] =
    α·L[rows]``, ``(L @ B)[rows] = L[rows] @ B``, and ``Add`` /
    ``HStack`` / ``ColSlice`` act per-row or per-column — provided no
    compact-shaped value ever reaches a dense position (a ``MatMul``
    right operand, a ``Scale`` factor).  ``Zero`` is excluded: its
    staged shape comes from the binding's dense dims.  A ``False`` here
    only costs the dense-chain rebuild the trigger always supported.
    """
    if isinstance(e, ex.Var):
        return e.name in left
    if isinstance(e, ex.Scale):
        return (not _expr_refs(e.factor, left)
                and _compact_left_safe(e.operand, left))
    if isinstance(e, ex.MatMul):
        return (_compact_left_safe(e.lhs, left)
                and not _expr_refs(e.rhs, left))
    if isinstance(e, ex.Add):
        return all(_compact_left_safe(t, left) for t in e.terms)
    if isinstance(e, HStack):
        return all(_compact_left_safe(b, left) for b in e.blocks)
    if isinstance(e, ColSlice):
        return _compact_left_safe(e.operand, left)
    return False


def compact_chain_names(trigger: Trigger):
    """The trigger's left-factor vars that stay compact end to end, or
    ``None`` if this trigger cannot run its factor chain compactly.

    A trigger qualifies when every maintained view is a row-local
    low-rank update and every assign that (transitively) consumes the
    update's left factor is :func:`_compact_left_safe` — then the whole
    chain can be evaluated on the ``(r, k)`` row block and no dense
    ``(n, k)`` factor is ever materialized."""
    if any(up.kind != "lowrank" for up in trigger.updates):
        return None
    if any(trigger.carriers.get(up.view) != "row_local"
           for up in trigger.updates):
        return None
    left = {trigger.u_var.name}
    for a in trigger.assigns:
        if not _expr_refs(a.expr, left):
            continue
        if not _compact_left_safe(a.expr, left):
            return None
        left.add(a.name)
    for up in trigger.updates:
        if up.u not in left or up.v in left:
            return None
    return left


def _np_evaluate(e: Expr, env: Env, binding: Dict[str, int],
                 cache: Dict[int, "np.ndarray"]):
    """Numpy twin of :func:`evaluate` for the in-place compact path.

    A compact firing's factor chain is a handful of skinny matmuls on
    `(r, k)`-sized arrays — eager jax dispatch overhead dwarfs the
    arithmetic there, so the host path evaluates with numpy directly
    (same op semantics, float32 throughout)."""
    import numpy as np

    def go(x: Expr):
        hit = cache.get(id(x))
        if hit is not None:
            return hit
        if isinstance(x, ex.Var):
            out = np.asarray(env[x.name])
        elif isinstance(x, ex.Zero):
            out = np.zeros((_dim(x.shape[0], binding),
                            _dim(x.shape[1], binding)), np.float32)
        elif isinstance(x, ex.Identity):
            out = np.eye(_dim(x.shape[0], binding), dtype=np.float32)
        elif isinstance(x, ex.Const):
            out = np.full((1, 1), x.value, np.float32)
        elif isinstance(x, ex.MatMul):
            out = go(x.lhs) @ go(x.rhs)
        elif isinstance(x, ex.Add):
            out = functools.reduce(np.add, [go(t) for t in x.terms])
        elif isinstance(x, ex.Scale):
            f = go(x.factor)
            if f.ndim == 2:  # (1,1) scalar view
                f = f[0, 0]
            out = f * go(x.operand)
        elif isinstance(x, ex.Transpose):
            out = go(x.operand).T
        elif isinstance(x, ex.Inverse):
            a = go(x.operand)
            out = 1.0 / a if a.shape == (1, 1) else np.linalg.inv(a)
        elif isinstance(x, HStack):
            out = np.concatenate([go(b) for b in x.blocks], axis=1)
        elif isinstance(x, ColSlice):
            out = go(x.operand)[:, x.col:x.col + 1]
        else:
            raise TypeError(f"cannot evaluate {type(x).__name__}")
        cache[id(x)] = out
        return out

    return go(e)


def build_rowlocal_inplace_fn(trigger: Trigger, program: Program,
                              binding: Optional[Dict[str, int]] = None):
    """In-place CPU apply for a fully row-local trigger, or ``None``.

    XLA on CPU ignores buffer donation, so every jitted firing rewrites
    each written view in full — a copy floor that swamps the row-slab
    win no matter how contained the update is (at serving shapes the
    floor is tens of milliseconds of pure memcpy).  When the trigger's
    whole factor chain is compact (:func:`compact_chain_names`), none
    of that machinery is needed: this builder returns
    ``run(views, rows, block, v) -> views`` which evaluates the chain
    eagerly on the compact ``(r, k)`` factors and mutates each view's
    rows **in place** — ``view[rows] += L @ Rᵀ`` on mutable ``np``
    storage — touching exactly ``r·m`` elements per view and nothing
    else.  No padding, no rank buckets, no compile cache: shapes are
    data, not program structure.

    Views still held as jax arrays are converted to ``np`` storage once
    (a final copy); later jit firings re-ingest them transparently, so
    mixed carrier/dense streams stay exact and pay one conversion per
    regime switch instead of a copy floor per firing.  Engines engage
    this path only when unguarded (transactional rollback needs the
    staged copy-on-write firing) — see
    ``IncrementalEngine(rowlocal_apply=...)``.
    """
    names = compact_chain_names(trigger)
    if names is None:
        return None
    binding = dict(program.dims if binding is None else binding)
    written, read_only = trigger_touched_views(trigger)
    import numpy as np

    def run(views: Env, rows, block, v) -> Env:
        rows = np.asarray(rows, dtype=np.int32)
        env: Env = {}
        for name in written:
            arr = views[name]
            if not isinstance(arr, np.ndarray):
                arr = np.array(arr, dtype=np.float32)
                views[name] = arr
            env[name] = arr
        for name in read_only:
            env[name] = views[name]
        env[trigger.u_var.name] = np.asarray(block, dtype=np.float32)
        env[trigger.v_var.name] = np.asarray(v, dtype=np.float32)
        cache: Dict[int, "np.ndarray"] = {}
        for a in trigger.assigns:
            env[a.name] = _np_evaluate(a.expr, env, binding, cache)
        for up in trigger.updates:
            L = env[up.u]
            R = env[up.v]
            views[up.view][rows] += L @ R.T
        return views

    return run


def build_rowlocal_trigger_fn(trigger: Trigger, program: Program,
                              binding: Optional[Dict[str, int]] = None,
                              row_bucket: int = 8,
                              jit: bool = True,
                              apply_backend: str = "xla",
                              donate: bool = False
                              ) -> Callable[[Env, Array, Array, Array], Env]:
    """Stage a trigger for row-local carriers: ``(views, rows, B, V) -> views``.

    ``rows`` is the affected-row index vector padded to the static
    ``row_bucket`` with the **out-of-bounds sentinel** ``n`` (``B``
    padded with zero rows).  JAX's scatter drops out-of-bounds indices
    and its gather clamps them, so the padding is exact end-to-end: the
    scattered dense-shaped ``u`` never sees the sentinel rows, and the
    clamped garbage a factor gather picks up is scattered right back
    out of bounds.

    Execution has two regimes.  When the whole trigger is row-local
    and every left factor-block expression is compact-safe
    (:func:`compact_chain_names`), the factor chain runs **compactly**:
    the ``(row_bucket, k)`` block is bound directly as the update's
    left factor, every downstream left factor stays ``(row_bucket, k)``
    (row-preserving constructors commute with the row gather), and each
    view updates by ``view.at[rows].add(L_compact @ Rᵀ)`` — no dense
    ``(n, k)`` factor is ever materialized, so the firing's traffic is
    the written views plus ``O(r·(k + m))``.  Otherwise the dense-shaped
    ``u`` is rebuilt by scatter, the chain is evaluated exactly as the
    dense trigger would, row-local views take the row-slab gather-GER-
    scatter (``view.at[rows].add(L[rows] @ Rᵀ)``) and widened views the
    ordinary dense sweep.  With ``apply_backend="pallas"`` the row-slab
    update of closed views goes through the touched-slab Pallas kernel
    (:func:`repro.kernels.rank_update_rows_pallas`) whenever the
    concrete rows admit a slab plan (the kernel consumes the
    dense-shaped factor, so the slab-plan path keeps the dense chain).

    Bit-exactness caveat: ``at[].add`` sums ``L[rows] @ Rᵀ`` into the
    view rather than forming ``view + u vᵀ``, so float rounding can
    differ from the dense path by ~1 ulp; the property suite pins the
    agreement tolerance.
    """
    binding = dict(program.dims if binding is None else binding)
    apply_fn = _get_apply_fn(apply_backend)
    written, read_only = trigger_touched_views(trigger)
    if donate:
        _warn_donation_ignored()
    x = program.inputs[trigger.input_name]
    n_in = _dim(x.shape[0], binding)
    k = trigger.rank
    use_pallas = apply_backend == "pallas"
    compact_names = compact_chain_names(trigger)

    def _compact_core():
        # fully row-local trigger: the factor chain runs on the compact
        # (row_bucket, k) block — sentinel-padded rows carry zero block
        # rows through every preserving constructor and their scatter
        # contributions are dropped as out-of-bounds, so no dense (n, k)
        # factor exists anywhere in the program
        def core(written_vals, read_vals, rows, block, v, slab_ids):
            env: Env = dict(zip(written, written_vals))
            env.update(zip(read_only, read_vals))
            env[trigger.u_var.name] = block
            env[trigger.v_var.name] = v
            cache: Dict[int, Array] = {}
            for a in trigger.assigns:
                env[a.name] = evaluate(a.expr, env, binding, cache)
            for up in trigger.updates:
                L, R = env[up.u], env[up.v]
                env[up.view] = env[up.view].at[rows].add(
                    jnp.dot(L, R.T, preferred_element_type=jnp.float32),
                    indices_are_sorted=True)
            return tuple(env[name] for name in written)

        if jit:
            return jax.jit(core, donate_argnums=(0,) if donate else ())
        return core

    def _core(slab: Optional[int], num_slabs: int):
        if slab is None and compact_names is not None:
            return _compact_core()
        # one staged body per slab plan shape (None = XLA scatter path)
        def core(written_vals, read_vals, rows, block, v, slab_ids):
            env: Env = dict(zip(written, written_vals))
            env.update(zip(read_only, read_vals))
            u = jnp.zeros((n_in, k), jnp.float32).at[rows].add(
                block, indices_are_sorted=True)
            env[trigger.u_var.name] = u
            env[trigger.v_var.name] = v
            cache: Dict[int, Array] = {}
            for a in trigger.assigns:
                env[a.name] = evaluate(a.expr, env, binding, cache)
            for up in trigger.updates:
                if up.kind != "lowrank":
                    env[up.view] = env[up.view] + env[up.d]
                    continue
                L, R = env[up.u], env[up.v]
                if trigger.carriers.get(up.view) != "row_local":
                    env[up.view] = apply_fn(env[up.view], L, R)
                    continue
                view = env[up.view]
                if slab is not None and view.shape[0] % slab == 0:
                    from repro.kernels import ops as rk_ops
                    bn = rk_ops._pick_block(view.shape[1], 512)
                    if view.shape[1] % bn == 0:
                        from repro.kernels.rank_update_rows import \
                            rank_update_rows_pallas
                        env[up.view] = rank_update_rows_pallas(
                            view, slab_ids, L, R, slab=slab, bn=bn,
                            interpret=rk_ops._interpret_default(None))
                        continue
                # gather-GER-scatter: clamped OOB gather rows are
                # dropped again by the OOB scatter — exact
                env[up.view] = view.at[rows].add(
                    jnp.dot(L[rows], R.T,
                            preferred_element_type=jnp.float32),
                    indices_are_sorted=True)
            return tuple(env[name] for name in written)

        if jit:
            return jax.jit(core, donate_argnums=(0,) if donate else ())
        return core

    cores: Dict[Tuple[Optional[int], int], Callable] = {}

    def run(views: Env, rows, block, v) -> Env:
        import numpy as np
        rows = np.asarray(rows, dtype=np.int32)
        slab = None
        slab_ids = np.zeros((0,), np.int32)
        if use_pallas:
            from repro.kernels import ops as rk_ops
            plan = rk_ops.slab_plan(n_in, rows[rows < n_in])
            if plan is not None:
                slab, slab_ids = plan
        key = (slab, int(np.shape(slab_ids)[0]))
        core = cores.get(key)
        if core is None:
            core = cores[key] = _core(*key)
        new_vals = core(tuple(views[n] for n in written),
                        tuple(views[n] for n in read_only),
                        rows, block, v, slab_ids)
        views.update(zip(written, new_vals))
        return views

    return run


# ---------------------------------------------------------------------------
# planned trigger execution (repro.plan: per-view strategy in one firing)
# ---------------------------------------------------------------------------


def planned_trigger_sets(trigger: Trigger, program: Program,
                         reeval_views=(), lazy_views=()):
    """Partition a trigger's work under a maintenance plan.

    ``reeval_views`` are re-evaluated from their defining statements
    inside the firing (the §7 fallback for views whose delta lost to
    recomputation); ``lazy_views`` are skipped entirely (unmaterialized
    intermediates, recomputed on read) — unless a re-evaluated view's
    statement reads them, in which case they are pulled into the
    recompute closure so re-evaluation stays exact.

    Returns ``(kept_assigns, kept_updates, recompute_stmts, skipped)``:
    the dead-code-eliminated factor-block assigns and ``+=`` updates
    that still run incrementally, the statements to re-evaluate in
    program order, and the lazy views this firing leaves stale.
    """
    reeval = set(reeval_views)
    lazy = set(lazy_views) - reeval
    if trigger.input_name in reeval or trigger.input_name in lazy:
        raise ValueError(
            f"input {trigger.input_name!r} is the base fact: it cannot be "
            f"re-evaluated or left unmaterialized")
    kept_updates = [up for up in trigger.updates
                    if up.view not in reeval and up.view not in lazy]
    # recompute closure, discovered right-to-left: a lazy view is
    # recomputed only if a later recomputed statement reads it
    needed: set = set()
    recompute_names: set = set()
    for st in reversed(program.statements):
        name = st.target.name
        if name in reeval or (name in lazy and name in needed):
            recompute_names.add(name)
            needed |= set(st.expr.free_vars())
    recompute = [st for st in program.statements
                 if st.target.name in recompute_names]
    skipped = tuple(sorted(lazy - recompute_names))
    # assign DCE, same direction: keep only blocks the kept updates
    # (transitively) reference
    need: set = set()
    for up in kept_updates:
        need |= {x for x in (up.u, up.v, up.d) if x}
    kept_assigns: List[Assign] = []
    for a in reversed(trigger.assigns):
        if a.name in need:
            kept_assigns.append(a)
            need |= set(a.expr.free_vars())
    kept_assigns.reverse()
    return kept_assigns, kept_updates, recompute, skipped


def build_planned_trigger_fn(trigger: Trigger, program: Program,
                             binding: Optional[Dict[str, int]] = None,
                             *, reeval_views=(), lazy_views=(),
                             jit: bool = True, apply_backend: str = "xla",
                             donate: bool = False,
                             constrain: Optional[Callable] = None,
                             replicate: Optional[Callable] = None
                             ) -> Callable[[Env, Array, Array], Env]:
    """Stage one *planned* firing: incremental updates for the winning
    views, in-firing re-evaluation for the losing ones, lazy skip for
    unmaterialized intermediates — one XLA program, same ``(views, U,
    V) -> views`` contract as :func:`build_trigger_fn`.

    Execution order keeps the firing exact: factor blocks are evaluated
    against *old* view values (the delta derivation's contract), the
    surviving ``+=`` updates land, then re-evaluated statements are
    recomputed **in program order** against the already-updated store —
    every view ends at its exact post-update value either way.

    ``constrain`` / ``replicate`` are sharding hooks for the
    distributed path (:mod:`repro.dist.ivm_shard`); identity when None.
    """
    binding = dict(program.dims if binding is None else binding)
    apply_fn = _get_apply_fn(apply_backend)
    assigns, updates, recompute, skipped = planned_trigger_sets(
        trigger, program, reeval_views, lazy_views)
    written = tuple(dict.fromkeys(
        [up.view for up in updates] + [st.target.name for st in recompute]))
    local = {trigger.u_var.name, trigger.v_var.name}
    local.update(a.name for a in assigns)
    read: set = set()
    for a in assigns:
        read |= set(a.expr.free_vars())
    for st in recompute:
        read |= set(st.expr.free_vars())
    read -= local
    read -= set(written)
    read_only = tuple(sorted(read))
    cst = constrain if constrain is not None else (lambda x: x)
    rep = replicate if replicate is not None else (lambda x: x)

    def core(written_vals: Tuple[Array, ...], read_vals: Tuple[Array, ...],
             u: Array, v: Array) -> Tuple[Array, ...]:
        env: Env = {}
        for name, val in zip(written + read_only,
                             tuple(written_vals) + tuple(read_vals)):
            env[name] = cst(val)
        env[trigger.u_var.name] = rep(u)
        env[trigger.v_var.name] = rep(v)
        cache: Dict[int, Array] = {}
        for a in assigns:
            env[a.name] = evaluate(a.expr, env, binding, cache)
        for up in updates:
            if up.kind == "lowrank":
                env[up.view] = cst(apply_fn(env[up.view], env[up.u],
                                            env[up.v]))
            else:
                env[up.view] = cst(env[up.view] + env[up.d])
        # fresh cache: the assign-phase cache holds pre-update values
        rcache: Dict[int, Array] = {}
        for st in recompute:
            env[st.target.name] = cst(evaluate(st.expr, env, binding, rcache))
        return tuple(env[name] for name in written)

    if jit:
        core = jax.jit(core, donate_argnums=(0,) if donate else ())

    def run(views: Env, u: Array, v: Array) -> Env:
        if not jit:  # jitted cores convert np factors on the C++ arg path
            u, v = jnp.asarray(u), jnp.asarray(v)
        new_vals = core(tuple(views[n] for n in written),
                        tuple(views[n] for n in read_only), u, v)
        views.update(zip(written, new_vals))
        return views

    run.reeval_views = tuple(sorted(reeval_views))
    run.recomputes = tuple(st.target.name for st in recompute)
    run.skipped = skipped
    run.incr_views = tuple(up.view for up in updates)
    return run


def trigger_flops(trigger: Trigger, program: Program,
                  binding: Optional[Dict[str, int]] = None) -> float:
    """Analytic FLOP count of one trigger firing (cost-model §3)."""
    from .cost import apply_update_cost, expr_cost, shape_of
    binding = dict(program.dims if binding is None else binding)
    total = 0.0
    seen: Dict[int, bool] = {}
    from .cost import _expr_cost_shared
    for a in trigger.assigns:
        total += _expr_cost_shared(a.expr, binding, seen).flops
    name_to_var = {**{k: v for k, v in program.inputs.items()},
                   **{s.target.name: s.target for s in program.statements}}
    for up in trigger.updates:
        base = up.view
        if base not in name_to_var and base.startswith("__d"):
            # ΔᵈV auxiliary views share the base view's shape
            base = base.split("__", 2)[-1]
        view = name_to_var[base]
        n, m = shape_of(view, binding)
        if up.kind == "lowrank":
            k = next(a.expr for a in trigger.assigns if a.name == up.u).shape[1] \
                if any(a.name == up.u for a in trigger.assigns) else trigger.rank
            k = k if isinstance(k, int) else binding[k.name]
            total += apply_update_cost((n, m), k).flops
        else:
            total += n * m
    return total
