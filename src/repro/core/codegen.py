"""Codegen: symbolic expressions / triggers → jitted JAX callables.

The evaluator stages a trigger body into a single XLA program: every factor
block is a chain of (big × skinny) or (skinny × skinny) matmuls, and the
``+=`` updates donate the view buffers so the update happens in place.

Backends for the rank-k apply (``M += U Vᵀ``) are pluggable:
  - "xla": plain jnp (default everywhere),
  - "pallas": the VMEM-tiled TPU kernel from ``repro.kernels.rank_update``
    (interpret-mode on CPU; the kernel is the TPU hot path).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import expr as ex
from .compiler import Assign, CompiledProgram, Trigger, ViewUpdate
from .expr import Expr
from .factored import ColSlice, HStack
from .program import Program


Array = jax.Array
Env = Dict[str, Array]


def _dim(d, binding: Dict[str, int]) -> int:
    return binding[d.name] if isinstance(d, ex.Dim) else int(d)


def evaluate(e: Expr, env: Env, binding: Dict[str, int],
             cache: Optional[Dict[int, Array]] = None) -> Array:
    """Evaluate a symbolic expression against concrete arrays.

    ``cache`` keyed by interned node id gives cross-expression CSE: blocks
    of the same trigger share subcomputations for free.
    """
    if cache is None:
        cache = {}

    def go(x: Expr) -> Array:
        hit = cache.get(id(x))
        if hit is not None:
            return hit
        out = _eval_node(x, env, binding, go)
        cache[id(x)] = out
        return out

    return go(e)


def _eval_node(x: Expr, env: Env, binding, go) -> Array:
    if isinstance(x, ex.Var):
        try:
            return env[x.name]
        except KeyError:
            raise KeyError(f"unbound variable {x.name}; have {sorted(env)}")
    if isinstance(x, ex.Zero):
        return jnp.zeros((_dim(x.shape[0], binding), _dim(x.shape[1], binding)),
                         dtype=jnp.float32)
    if isinstance(x, ex.Identity):
        return jnp.eye(_dim(x.shape[0], binding), dtype=jnp.float32)
    if isinstance(x, ex.Const):
        return jnp.full((1, 1), x.value, dtype=jnp.float32)
    if isinstance(x, ex.MatMul):
        return go(x.lhs) @ go(x.rhs)
    if isinstance(x, ex.Add):
        terms = [go(t) for t in x.terms]
        return functools.reduce(jnp.add, terms)
    if isinstance(x, ex.Scale):
        f = go(x.factor)
        if f.ndim == 2:  # (1,1) scalar view
            f = f[0, 0]
        return f * go(x.operand)
    if isinstance(x, ex.Transpose):
        return go(x.operand).T
    if isinstance(x, ex.Inverse):
        a = go(x.operand)
        if a.shape == (1, 1):
            return 1.0 / a
        return jnp.linalg.inv(a)
    if isinstance(x, HStack):
        return jnp.concatenate([go(b) for b in x.blocks], axis=1)
    if isinstance(x, ColSlice):
        return go(x.operand)[:, x.col:x.col + 1]
    raise TypeError(f"cannot evaluate {type(x).__name__}")


# ---------------------------------------------------------------------------
# program re-evaluation (the paper's baseline strategy)
# ---------------------------------------------------------------------------


def build_evaluator(program: Program,
                    binding: Optional[Dict[str, int]] = None,
                    jit: bool = True) -> Callable[[Env], Env]:
    """Full re-evaluation: returns {view name: value} for all statements."""
    binding = dict(program.dims if binding is None else binding)

    def run(inputs: Env) -> Env:
        env: Env = dict(inputs)
        cache: Dict[int, Array] = {}
        out: Env = {}
        for st in program.statements:
            val = evaluate(st.expr, env, binding, cache)
            env[st.target.name] = val
            out[st.target.name] = val
        return out

    return jax.jit(run) if jit else run


# ---------------------------------------------------------------------------
# trigger execution (the incremental strategy)
# ---------------------------------------------------------------------------


def _apply_lowrank_xla(view: Array, u: Array, v: Array) -> Array:
    return view + u @ v.T


def _get_apply_fn(backend: str):
    if backend == "xla":
        return _apply_lowrank_xla
    if backend == "pallas":
        from repro.kernels import ops as rk_ops
        return rk_ops.rank_update
    raise ValueError(f"unknown apply backend {backend!r}")


def build_trigger_fn(trigger: Trigger, program: Program,
                     binding: Optional[Dict[str, int]] = None,
                     jit: bool = True,
                     apply_backend: str = "xla",
                     donate: bool = True) -> Callable[[Env, Array, Array], Env]:
    """Stage a trigger into ``(views, U, V) -> new views``.

    ``views`` must contain the input matrices and every maintained view.
    The returned dict contains updated values for the affected entries and
    passes through the rest.
    """
    binding = dict(program.dims if binding is None else binding)
    apply_fn = _get_apply_fn(apply_backend)

    def run(views: Env, u: Array, v: Array) -> Env:
        env: Env = dict(views)
        env[trigger.u_var.name] = u
        env[trigger.v_var.name] = v
        cache: Dict[int, Array] = {}
        for a in trigger.assigns:
            env[a.name] = evaluate(a.expr, env, binding, cache)
        out = dict(views)
        for up in trigger.updates:
            if up.kind == "lowrank":
                out[up.view] = apply_fn(env[up.view], env[up.u], env[up.v])
            else:
                out[up.view] = env[up.view] + env[up.d]
        return out

    if jit:
        run = jax.jit(run, donate_argnums=(0,) if donate else ())
    return run


def trigger_flops(trigger: Trigger, program: Program,
                  binding: Optional[Dict[str, int]] = None) -> float:
    """Analytic FLOP count of one trigger firing (cost-model §3)."""
    from .cost import apply_update_cost, expr_cost, shape_of
    binding = dict(program.dims if binding is None else binding)
    total = 0.0
    seen: Dict[int, bool] = {}
    from .cost import _expr_cost_shared
    for a in trigger.assigns:
        total += _expr_cost_shared(a.expr, binding, seen).flops
    name_to_var = {**{k: v for k, v in program.inputs.items()},
                   **{s.target.name: s.target for s in program.statements}}
    for up in trigger.updates:
        view = name_to_var[up.view]
        n, m = shape_of(view, binding)
        if up.kind == "lowrank":
            k = next(a.expr for a in trigger.assigns if a.name == up.u).shape[1] \
                if any(a.name == up.u for a in trigger.assigns) else trigger.rank
            k = k if isinstance(k, int) else binding[k.name]
            total += apply_update_cost((n, m), k).flops
        else:
            total += n * m
    return total
