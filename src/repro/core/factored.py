"""Factored delta representation (paper §4.2, §4.3).

A delta matrix is maintained as a sum of outer products of *blocks*,
``ΔM = Σ_i  L_i · R_iᵀ`` where each ``L_i`` is ``(n × k_i)`` and each
``R_i`` is ``(m × k_i)``.  Equivalently ``ΔM = P Qᵀ`` for the horizontal
stacks ``P = [L_1 … L_b]``, ``Q = [R_1 … R_b]`` — the paper's block-matrix
form.  Ranks ``k_i`` are static Python ints, so every staged computation
has static shapes.

``DenseDelta`` is the paper's *hybrid* representation (§5.3): the delta is
kept as a single (possibly full-rank) matrix expression.  The cost model
decides which representation each statement uses.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from . import expr as ex
from .expr import Expr, Shape


def _block_rank(e: Expr) -> int:
    k = e.shape[1]
    if not isinstance(k, int):
        raise ex.ShapeError(f"factored block must have static rank, got {e.shape}")
    return k


@dataclass(frozen=True)
class LowRank:
    """Factored delta ``Σ_i left[i] @ right[i].T`` (rank = Σ_i k_i)."""

    left: Tuple[Expr, ...]
    right: Tuple[Expr, ...]

    def __post_init__(self):
        assert len(self.left) == len(self.right)
        for l, r in zip(self.left, self.right):
            if _block_rank(l) != _block_rank(r):
                raise ex.ShapeError(
                    f"block rank mismatch: {l.shape} vs {r.shape}")

    @property
    def rank(self) -> int:
        return sum(_block_rank(l) for l in self.left)

    @property
    def shape(self) -> Shape:
        if not self.left:
            raise ValueError("rank-0 delta has no shape; use LowRank.zero_like")
        return (self.left[0].shape[0], self.right[0].shape[0])

    def is_zero(self) -> bool:
        return not self.left

    def transpose(self) -> "LowRank":
        return LowRank(self.right, self.left)

    def scale(self, factor) -> "LowRank":
        return LowRank(tuple(ex.scale(factor, l) for l in self.left), self.right)

    def to_expr(self) -> Expr:
        """The dense expression ``Σ L_i R_iᵀ`` (used by the hybrid path)."""
        if self.is_zero():
            raise ValueError("rank-0 delta")
        return ex.add(*[ex.matmul(l, ex.transpose(r)) for l, r in
                        zip(self.left, self.right)])

    @staticmethod
    def zero() -> "LowRank":
        return LowRank((), ())

    @staticmethod
    def outer(u: Expr, v: Expr) -> "LowRank":
        """Single-block factored delta ``u vᵀ``."""
        return LowRank((u,), (v,))


@dataclass(frozen=True)
class DenseDelta:
    """Hybrid representation: the delta as one matrix expression."""

    value: Expr

    @property
    def shape(self) -> Shape:
        return self.value.shape

    def is_zero(self) -> bool:
        return self.value.is_zero()

    def transpose(self) -> "DenseDelta":
        return DenseDelta(ex.transpose(self.value))

    def scale(self, factor) -> "DenseDelta":
        return DenseDelta(ex.scale(factor, self.value))


DeltaRep = Union[LowRank, DenseDelta]


def combine_blocks(blocks: Sequence[Tuple[Expr, Expr]]) -> LowRank:
    """Common-factor extraction (§4.3).

    Given monomial outer products ``Σ l_i r_iᵀ``, group terms that share a
    right block and sum their left sides (then symmetrically group by left
    block).  With the hash-consed IR, "shares a factor" is pointer equality.
    This is the syntactic factoring the paper uses: it does not guarantee
    minimal rank (that would need value inspection) but reproduces the
    paper's 2×-per-squaring growth instead of 3×.
    """
    # group by right factor
    by_right: Dict[int, Tuple[Expr, List[Expr]]] = {}
    order: List[int] = []
    for l, r in blocks:
        key = id(r)
        if key not in by_right:
            by_right[key] = (r, [])
            order.append(key)
        by_right[key][1].append(l)
    stage1: List[Tuple[Expr, Expr]] = []
    for key in order:
        r, ls = by_right[key]
        stage1.append((ex.add(*ls) if len(ls) > 1 else ls[0], r))
    # group by left factor
    by_left: Dict[int, Tuple[Expr, List[Expr]]] = {}
    order = []
    for l, r in stage1:
        key = id(l)
        if key not in by_left:
            by_left[key] = (l, [])
            order.append(key)
        by_left[key][1].append(r)
    left: List[Expr] = []
    right: List[Expr] = []
    for key in order:
        l, rs = by_left[key]
        left.append(l)
        right.append(ex.add(*rs) if len(rs) > 1 else rs[0])
    return LowRank(tuple(left), tuple(right))


def lowrank_matmul(d1: LowRank, e1: Expr, d2: LowRank, e2: Expr) -> LowRank:
    """Product rule for factored deltas (§4.1 + §4.3 factoring):

    ``Δ(E1·E2) = ΔE1·E2 + E1·ΔE2 + ΔE1·ΔE2`` with ``ΔE1 = P1 Q1ᵀ``,
    ``ΔE2 = P2 Q2ᵀ`` becomes, grouped by common factors,

        left  = [P1,  E1·P2 + P1·(Q1ᵀ P2)]
        right = [E2ᵀ·Q1,  Q2]

    which is exactly the paper's Example 4.6 shape: rank k1 + k2, every new
    product is (big × skinny) or (skinny × skinny) — O(k n²) work.
    """
    blocks: List[Tuple[Expr, Expr]] = []
    # (ΔE1) E2  →  P1 (E2ᵀ Q1)ᵀ
    for l, r in zip(d1.left, d1.right):
        blocks.append((l, ex.matmul(ex.transpose(e2), r)))
    # E1 (ΔE2)  →  (E1 P2) Q2ᵀ
    for l, r in zip(d2.left, d2.right):
        blocks.append((ex.matmul(e1, l), r))
    # (ΔE1)(ΔE2)  →  (P1 (Q1ᵀ P2)) Q2ᵀ   — k×k inner products stay tiny
    for l1, r1 in zip(d1.left, d1.right):
        for l2, r2 in zip(d2.left, d2.right):
            blocks.append((ex.matmul(l1, ex.matmul(ex.transpose(r1), l2)), r2))
    return combine_blocks(blocks)


def lowrank_add(*deltas: LowRank) -> LowRank:
    blocks: List[Tuple[Expr, Expr]] = []
    for d in deltas:
        blocks.extend(zip(d.left, d.right))
    return combine_blocks(blocks)


def lowrank_inverse_woodbury(view: Expr, d: LowRank,
                             sequential: bool = False) -> LowRank:
    """Incremental inverse under a factored update (Sherman–Morrison /
    Woodbury, §4.1).

    For ``W = E⁻¹`` (materialized, pre-update) and ``ΔE = P Qᵀ`` (rank k):

        Δ(E⁻¹) = −W P (I_k + Qᵀ W P)⁻¹ Qᵀ W
                = L Rᵀ,   L = −W P (I_k + Qᵀ W P)⁻¹,  R = Wᵀ Q

    The only inversion is k×k.  With ``sequential=True`` the paper-faithful
    Example 4.3 path is produced instead: k successive rank-1
    Sherman–Morrison applications (same result, more statements).
    """
    if d.is_zero():
        return LowRank.zero()
    if sequential:
        return _sherman_morrison_chain(view, d)
    # stack blocks: P = [L_1 … L_b]  — symbolically a single block if b == 1,
    # otherwise we keep per-block structure by concatenating via hstack expr.
    P = _hstack(d.left)
    Q = _hstack(d.right)
    k = sum(_block_rank(l) for l in d.left)
    WP = ex.matmul(view, P)
    cap = ex.add(ex.identity(k), ex.matmul(ex.transpose(Q), WP))  # k×k
    L = ex.scale(-1.0, ex.matmul(WP, ex.inverse(cap)))
    R = ex.matmul(ex.transpose(view), Q)
    return LowRank((L,), (R,))


def _sherman_morrison_chain(view: Expr, d: LowRank) -> LowRank:
    """Example 4.3: apply rank-1 Sherman–Morrison per outer product in turn.

    Each step must use the *current* inverse ``W + Σ previous deltas``; the
    deltas are themselves rank-1 so the chain stays factored.  Blocks of
    rank > 1 are split into rank-1 column slices first.
    """
    ones: List[Tuple[Expr, Expr]] = []
    for l, r in zip(d.left, d.right):
        k = _block_rank(l)
        if k == 1:
            ones.append((l, r))
        else:
            for j in range(k):
                ones.append((ColSlice.make(l, j), ColSlice.make(r, j)))
    d = LowRank(tuple(l for l, _ in ones), tuple(r for _, r in ones))
    out_blocks: List[Tuple[Expr, Expr]] = []

    def current_apply(x: Expr) -> Expr:
        """(W + Σ l_j r_jᵀ) · x  evaluated factored."""
        terms = [ex.matmul(view, x)]
        for l, r in out_blocks:
            terms.append(ex.matmul(l, ex.matmul(ex.transpose(r), x)))
        return ex.add(*terms)

    def current_apply_t(x: Expr) -> Expr:
        """(W + Σ l_j r_jᵀ)ᵀ · x."""
        terms = [ex.matmul(ex.transpose(view), x)]
        for l, r in out_blocks:
            terms.append(ex.matmul(r, ex.matmul(ex.transpose(l), x)))
        return ex.add(*terms)

    for u, v in zip(d.left, d.right):
        if _block_rank(u) != 1:
            raise ValueError("sequential Sherman–Morrison needs rank-1 blocks")
        Wu = current_apply(u)                      # n×1
        Wtv = current_apply_t(v)                   # n×1
        denom = ex.add(ex.const(1.0), ex.matmul(ex.transpose(v), Wu))  # 1×1
        L = ex.scale(-1.0, ex.matmul(Wu, ex.inverse(denom)))
        out_blocks.append((L, Wtv))
    return LowRank(tuple(l for l, _ in out_blocks),
                   tuple(r for _, r in out_blocks))


def _hstack(blocks: Sequence[Expr]) -> Expr:
    if len(blocks) == 1:
        return blocks[0]
    return HStack.make(tuple(blocks))


@dataclass(frozen=True, eq=False)
class ColSlice(Expr):
    """Column ``j`` of a block, as an (n, 1) matrix."""

    operand: Expr
    col: int

    @staticmethod
    def make(operand: Expr, col: int) -> "ColSlice":
        node = ColSlice(operand, col)
        object.__setattr__(node, "shape", (operand.shape[0], 1))
        object.__setattr__(node, "children", (operand,))
        return node

    def __repr__(self) -> str:
        return f"{self.operand!r}[:,{self.col}]"


@dataclass(frozen=True, eq=False)
class HStack(Expr):
    """Horizontal concatenation of column blocks — the paper's block matrix.

    Introduced only where a genuinely stacked operand is needed (Woodbury
    capacitance); everywhere else blocks stay separate to avoid copies.
    """

    blocks: Tuple[Expr, ...]

    @staticmethod
    def make(blocks: Tuple[Expr, ...]) -> "HStack":
        n = blocks[0].shape[0]
        k = 0
        for b in blocks:
            if b.shape[0] != n:
                raise ex.ShapeError("hstack row mismatch")
            k += _block_rank(b)
        node = HStack(blocks)
        object.__setattr__(node, "shape", (n, k))
        object.__setattr__(node, "children", tuple(blocks))
        return node

    def __repr__(self) -> str:
        return "[" + " ".join(map(repr, self.blocks)) + "]"


# ---------------------------------------------------------------------------
# batched update streams (§4.2 avalanche containment across the batch dim)
# ---------------------------------------------------------------------------
#
# A stream of T factored updates {(U_t, V_t)} to one input is itself a
# factored delta with stacked blocks  P = [U_1 … U_T],  Q = [V_1 … V_T]:
#
#     Σ_t U_t V_tᵀ  =  P Qᵀ,      rank ≤ Σ_t k_t.
#
# The helpers below are *numeric* (host-side): they run at batch-flush
# time, outside jit, so the resulting rank is a static Python int the
# compiler can bucket triggers by.


def stack_update_arrays(updates: Sequence[Tuple["np.ndarray", "np.ndarray"]]
                        ) -> Tuple["np.ndarray", "np.ndarray"]:
    """Stack T factored updates ``[(u_t, v_t)]`` into ``(P, Q)``.

    Each ``u_t`` is (n, k_t), ``v_t`` is (m, k_t); 1-D vectors are treated
    as rank-1 columns.  Returns float32 ``P: (n, K)``, ``Q: (m, K)`` with
    ``K = Σ_t k_t``.
    """
    import numpy as np
    if not updates:
        raise ValueError("empty update batch")
    us, vs = [], []
    for u, v in updates:
        u = np.asarray(u, dtype=np.float32)
        v = np.asarray(v, dtype=np.float32)
        if u.ndim == 1:
            u = u[:, None]
        if v.ndim == 1:
            v = v[:, None]
        if u.shape[1] != v.shape[1]:
            raise ex.ShapeError(f"update rank mismatch: {u.shape} vs {v.shape}")
        us.append(u)
        vs.append(v)
    return np.concatenate(us, axis=1), np.concatenate(vs, axis=1)


def recompress_factors(P: "np.ndarray", Q: "np.ndarray",
                       max_rank: Optional[int] = None,
                       tol: float = 1e-7
                       ) -> Tuple["np.ndarray", "np.ndarray"]:
    """Re-compress a stacked factored delta ``P Qᵀ`` to minimal rank.

    The paper's §4.2 avalanche containment applied across the batch
    dimension: repeated stacking grows K = Σ k_t without bound, but the
    *numerical* rank is often far smaller (e.g. Zipf-skewed row updates
    that keep hitting the same rows).  Thin-QR both factors, SVD the small
    (K × K) core, and truncate:

        P = Q_p R_p,  Q = Q_q R_q,  R_p R_qᵀ = U Σ Vᵀ
        P' = Q_p U_r Σ_r,   Q' = Q_q V_r        (rank r ≤ K)

    Cost O((n + m) K² + K³) — independent of the view sizes the trigger
    will touch, which is what makes compaction pay before a rank-K
    trigger fires.  Singular values below ``tol · σ_max`` are dropped;
    ``max_rank`` caps the result (lossy beyond the numerical rank).
    """
    import numpy as np
    P = np.asarray(P, dtype=np.float32)
    Q = np.asarray(Q, dtype=np.float32)
    K = P.shape[1]
    if K != Q.shape[1]:
        raise ex.ShapeError(f"factor rank mismatch: {P.shape} vs {Q.shape}")
    qp, rp = np.linalg.qr(P)           # (n, K), (K, K)
    qq, rq = np.linalg.qr(Q)           # (m, K), (K, K)
    uc, s, vct = np.linalg.svd(rp @ rq.T)
    r = int(np.sum(s > tol * (s[0] if s.size else 0.0)))
    r = max(1, r)
    if max_rank is not None:
        r = min(r, max_rank)
    P2 = qp @ (uc[:, :r] * s[:r])      # (n, r)
    Q2 = qq @ vct[:r].T                # (m, r)
    return P2.astype(np.float32), Q2.astype(np.float32)


def pad_factors_to_rank(P: "np.ndarray", Q: "np.ndarray", rank: int
                        ) -> Tuple["np.ndarray", "np.ndarray"]:
    """Zero-pad stacked factors (n, K) → (n, rank) for a static bucket.

    Exact: zero columns contribute nothing to ``P Qᵀ``, and every trigger
    delta rule is well-defined under them (the Woodbury capacitance gains
    identity rows/cols, the Sherman–Morrison denominators become 1).
    """
    import numpy as np
    K = P.shape[1]
    if K > rank:
        raise ValueError(f"cannot pad rank {K} down to {rank}")
    if K == rank:
        return P, Q
    pad = ((0, 0), (0, rank - K))
    return np.pad(P, pad), np.pad(Q, pad)


# ---------------------------------------------------------------------------
# runtime delta carriers (sparsity-aware containment, §3–§5)
# ---------------------------------------------------------------------------
#
# The symbolic layer above describes delta *structure* at compile time;
# the carriers below describe one concrete update at run time.  The
# engine historically took an implicit dense-shaped ``(P, Q)`` pair —
# so a 3-rows-touched update paid the same rank-k dense sweep as a
# full-matrix perturbation.  A carrier makes the containment explicit:
#
#   * ``LowRankCarrier``  — today's path, dense-shaped ``P Qᵀ`` factors;
#   * ``RowLocalCarrier`` — an affected-row index set plus the compact
#     row block: ``ΔA = scatter(rows, B) Vᵀ`` touches only ``r`` of
#     ``n`` rows.  Row support is preserved by exactly the §4 closure
#     the compiler proves per view (see ``repro.core.delta
#     .row_support_preserved``): left-multiplication into a chain,
#     adds of preserving terms, and scalar scales; anything else —
#     transposes, Woodbury inverses, right-factor deltas — widens the
#     carrier to ``LowRankCarrier`` via :meth:`factors`.
#   * ``NoOpCarrier``     — a tolerance-compared empty that legally
#     skips firing altogether (the delta-deduplication gate).
#
# Carriers are host-side numpy values (like the stacking helpers above):
# ranks and row counts stay static Python ints so triggers bucket and
# jit-cache exactly as before.  The dense path is bit-identical — a
# ``LowRankCarrier`` is *literally* the old ``(P, Q)`` pair.


class DeltaCarrier:
    """One concrete factored update ``ΔA`` to an engine input."""

    kind: str = "abstract"

    @property
    def rank(self) -> int:
        raise NotImplementedError

    @property
    def nm(self) -> Tuple[int, int]:
        """The (n, m) shape of the carried delta."""
        raise NotImplementedError

    def factors(self) -> Tuple["np.ndarray", "np.ndarray"]:
        """Widen to dense-shaped ``(P, Q)`` float32 factors (the oracle
        representation every carrier must agree with exactly)."""
        raise NotImplementedError

    def affected_fraction(self) -> float:
        """Fraction of rows the delta can touch (1.0 unless contained)."""
        return 1.0

    def norm_bound(self) -> float:
        """Upper bound on ``‖ΔA‖_F`` (``‖P‖_F · ‖Q‖_F``)."""
        raise NotImplementedError

    def is_noop(self, tol: float = 0.0) -> bool:
        """Whether applying this delta is guaranteed to move no view by
        more than ``tol`` (in delta Frobenius norm)."""
        return self.norm_bound() <= tol

    def negate(self) -> "DeltaCarrier":
        """The downdate ``-ΔA``: applying a carrier then its negation is
        the identity up to float cancellation (the F-IVM delete path —
        a deletion is an insertion with negative weight).  Subclasses
        override to preserve their compact representation."""
        import numpy as np
        P, Q = self.factors()
        return LowRankCarrier(np.negative(P), Q)


def _as_f32_factor(a, name: str) -> "np.ndarray":
    import numpy as np
    a = np.asarray(a, dtype=np.float32)
    if a.ndim == 1:
        a = a[:, None]
    if a.ndim != 2:
        raise ex.ShapeError(f"{name} must be 2-D, got shape {a.shape}")
    return a


@dataclass(frozen=True)
class LowRankCarrier(DeltaCarrier):
    """Dense-shaped factored delta ``ΔA = P Qᵀ`` — the classic carrier."""

    P: "np.ndarray"   # (n, k)
    Q: "np.ndarray"   # (m, k)

    kind = "low_rank"

    @property
    def rank(self) -> int:
        return int(self.P.shape[1])

    @property
    def nm(self) -> Tuple[int, int]:
        return int(self.P.shape[0]), int(self.Q.shape[0])

    def factors(self) -> Tuple["np.ndarray", "np.ndarray"]:
        return self.P, self.Q

    def norm_bound(self) -> float:
        import numpy as np
        return float(np.linalg.norm(self.P)) * float(np.linalg.norm(self.Q))

    def negate(self) -> "LowRankCarrier":
        import numpy as np
        return LowRankCarrier(np.negative(self.P), self.Q)


@dataclass(frozen=True)
class RowLocalCarrier(DeltaCarrier):
    """Row-contained factored delta: ``ΔA = scatter_n(rows, block) @ Vᵀ``.

    ``rows`` is the sorted, duplicate-free affected-row index set
    (``r`` entries), ``block`` the compact ``(r, k)`` left factor whose
    i-th row lands on row ``rows[i]``, and ``V`` the ordinary dense
    ``(m, k)`` right factor.  Only ``r/n`` of the left factor is ever
    stored or swept — the §3 "local change" contained as data.
    """

    rows: "np.ndarray"    # (r,) int32, sorted unique, all < n
    block: "np.ndarray"   # (r, k) float32
    V: "np.ndarray"       # (m, k) float32
    n: int                # full row dimension of the carried delta

    kind = "row_local"

    def __post_init__(self):
        if self.rows.ndim != 1 or self.block.ndim != 2 or self.V.ndim != 2:
            raise ex.ShapeError(
                f"row-local carrier dims: rows {self.rows.shape}, "
                f"block {self.block.shape}, V {self.V.shape}")
        if self.block.shape[0] != self.rows.shape[0]:
            raise ex.ShapeError(
                f"block rows {self.block.shape[0]} != affected rows "
                f"{self.rows.shape[0]}")
        if self.block.shape[1] != self.V.shape[1]:
            raise ex.ShapeError(
                f"carrier rank mismatch: block {self.block.shape} vs "
                f"V {self.V.shape}")

    @property
    def rank(self) -> int:
        return int(self.block.shape[1])

    @property
    def rows_touched(self) -> int:
        return int(self.rows.shape[0])

    @property
    def nm(self) -> Tuple[int, int]:
        return int(self.n), int(self.V.shape[0])

    def affected_fraction(self) -> float:
        return self.rows_touched / max(int(self.n), 1)

    def factors(self) -> Tuple["np.ndarray", "np.ndarray"]:
        """Widen: scatter the compact block into a dense-shaped P."""
        import numpy as np
        P = np.zeros((int(self.n), self.rank), dtype=np.float32)
        P[self.rows] = self.block
        return P, self.V

    def norm_bound(self) -> float:
        import numpy as np
        return (float(np.linalg.norm(self.block))
                * float(np.linalg.norm(self.V)))

    def scale(self, factor: float) -> "RowLocalCarrier":
        """Scalar scale preserves row support exactly (§4 closure)."""
        return RowLocalCarrier(self.rows, self.block * float(factor),
                               self.V, self.n)

    def matmul_right(self, W: "np.ndarray") -> "RowLocalCarrier":
        """``ΔA @ W`` preserves row support: only V changes (§4 closure
        — right-multiplication acts on columns, never rows)."""
        import numpy as np
        W = np.asarray(W, dtype=np.float32)
        return RowLocalCarrier(self.rows, self.block, W.T @ self.V, self.n)

    def negate(self) -> "RowLocalCarrier":
        """Negation preserves row support — a delete carrier is exactly
        as contained as the insert it cancels."""
        import numpy as np
        return RowLocalCarrier(self.rows, np.negative(self.block),
                               self.V, self.n)


@dataclass(frozen=True)
class NoOpCarrier(DeltaCarrier):
    """A delta known (to tolerance) to change nothing — skips firing."""

    n: int
    m: int

    kind = "noop"

    @property
    def rank(self) -> int:
        return 0

    @property
    def nm(self) -> Tuple[int, int]:
        return int(self.n), int(self.m)

    def affected_fraction(self) -> float:
        return 0.0

    def factors(self) -> Tuple["np.ndarray", "np.ndarray"]:
        import numpy as np
        return (np.zeros((int(self.n), 1), np.float32),
                np.zeros((int(self.m), 1), np.float32))

    def norm_bound(self) -> float:
        return 0.0

    def is_noop(self, tol: float = 0.0) -> bool:
        return True

    def negate(self) -> "NoOpCarrier":
        return self


def row_delta_carrier(rows, V, n: int, *, weight: float = 1.0
                      ) -> RowLocalCarrier:
    """The canonical F-IVM row tuple-update carrier: ``ΔA`` adds
    ``weight · V[:, j]ᵀ`` to row ``rows[j]`` of an ``(n, m)`` input.

    ``weight=+1`` is an insert (the row was zero), ``weight=-1`` the
    matching delete/downdate — the negative-weight form the learning-
    over-changing-data workloads (arXiv 1703.07484) maintain their
    covariance ring under.  ``rows`` may be a scalar slot or a
    duplicate-free index array; ``V`` is ``(m,)`` for one row or
    ``(m, r)`` column-per-row for several.
    """
    import numpy as np
    rows = np.atleast_1d(np.asarray(rows, dtype=np.int32))
    V = np.asarray(V, dtype=np.float32)
    if V.ndim == 1:
        V = V[:, None]
    if V.shape[1] != rows.size:
        raise ex.ShapeError(f"row_delta_carrier: {rows.size} rows but "
                            f"{V.shape[1]} value columns")
    block = np.eye(rows.size, dtype=np.float32) * np.float32(weight)
    order = np.argsort(rows)
    return RowLocalCarrier(rows[order], block[order], V, n)


def as_carrier(u, v=None) -> DeltaCarrier:
    """Normalize an update to a carrier.

    Accepts a :class:`DeltaCarrier` (returned as-is, ``v`` must then be
    ``None``) or a raw factor pair — the compatibility path every
    existing call site rides for free."""
    if isinstance(u, DeltaCarrier):
        if v is not None:
            raise ValueError("carrier updates take no separate v factor")
        return u
    if v is None:
        raise ValueError("raw factor updates need both u and v")
    return LowRankCarrier(_as_f32_factor(u, "u"), _as_f32_factor(v, "v"))


def detect_row_local(u, v, *, max_fraction: float = 0.5,
                     noop_tol: float = 0.0) -> DeltaCarrier:
    """Classify raw ``(u, v)`` factors into the tightest carrier.

    Scans ``u`` for its nonzero row support (O(n·k), cheap next to any
    sweep): empty support (or a delta under ``noop_tol``) is a
    :class:`NoOpCarrier`; support ≤ ``max_fraction`` of the rows is a
    :class:`RowLocalCarrier`; anything wider stays low-rank.  Exact —
    zero rows of ``u`` contribute nothing to ``u vᵀ``.
    """
    import numpy as np
    u = _as_f32_factor(u, "u")
    v = _as_f32_factor(v, "v")
    mask = np.any(u != 0.0, axis=1)
    rows = np.flatnonzero(mask).astype(np.int32)
    n = u.shape[0]
    if rows.size == 0:
        return NoOpCarrier(n, v.shape[0])
    c: DeltaCarrier
    if rows.size <= max_fraction * n:
        c = RowLocalCarrier(rows, u[rows], v, n)
    else:
        c = LowRankCarrier(u, v)
    if noop_tol > 0.0 and c.is_noop(noop_tol):
        return NoOpCarrier(n, v.shape[0])
    return c


def stack_carriers(carriers: Sequence[DeltaCarrier]) -> DeltaCarrier:
    """Stack a batch of carriers for one input into a single carrier.

    Row-local closure under addition: the union of the row supports.
    All-row-local batches stay row-local (rows = sorted union, compact
    blocks re-scattered into union coordinates, ranks concatenated);
    any dense-shaped member widens the whole stack to
    :class:`LowRankCarrier`; no-ops contribute nothing.  This is the §6
    batched-trigger stacking restated on carriers — the stacked rank is
    still ``Σ k_t`` and the dense widening reproduces
    :func:`stack_update_arrays` bit-for-bit.
    """
    import numpy as np
    live = [c for c in carriers if c.kind != "noop"]
    if not live:
        if not carriers:
            raise ValueError("empty carrier batch")
        n, m = carriers[0].nm
        return NoOpCarrier(n, m)
    if all(c.kind == "row_local" for c in live):
        n = live[0].n
        if any(c.n != n for c in live):
            raise ex.ShapeError("row-local carriers disagree on n")
        rows = np.unique(np.concatenate([c.rows for c in live]))
        rows = rows.astype(np.int32)
        pos = {int(r): i for i, r in enumerate(rows)}
        total_k = sum(c.rank for c in live)
        block = np.zeros((rows.size, total_k), np.float32)
        V = np.concatenate([c.V for c in live], axis=1)
        off = 0
        for c in live:
            idx = np.fromiter((pos[int(r)] for r in c.rows),
                              dtype=np.int64, count=c.rows.size)
            block[idx, off:off + c.rank] = c.block
            off += c.rank
        return RowLocalCarrier(rows, block, V, n)
    P, Q = stack_update_arrays([c.factors() for c in live])
    return LowRankCarrier(P, Q)

