"""LINVIEW core: incremental view maintenance for linear-algebra programs.

Public API:

    from repro.core import (
        Program, dim, var, matmul, add, transpose, inverse,
        compile_program, IncrementalEngine, ReevalEngine,
    )
"""

from .expr import (Dim, Expr, ShapeError, Var, add, const, identity, inverse,
                   matmul, scale, sub, transpose, var, zero)
from .program import Program, Statement, dim
from .factored import (DeltaCarrier, DeltaRep, DenseDelta, HStack,
                       LowRank, LowRankCarrier, NoOpCarrier,
                       RowLocalCarrier, as_carrier, detect_row_local,
                       pad_factors_to_rank, recompress_factors,
                       row_delta_carrier, stack_carriers,
                       stack_update_arrays)
from .delta import DeltaEnv, derive, derive_delta, IncrementalInverseError
from .compiler import (Assign, CompiledProgram, DeltaView, Trigger,
                       ViewUpdate, batch_bucket, compile_batched_trigger,
                       compile_delta_trigger, compile_program,
                       delta_view_name, extract_inverse_views)
from .codegen import build_evaluator, build_trigger_fn, evaluate
from .runtime import EngineStats, IncrementalEngine, ReevalEngine, max_abs_diff
from .cost import (Cost, batch_crossover_rank, batched_apply_cost,
                   batched_strategy, cholesky_factor_cost,
                   cholesky_update_cost, expr_cost, lowrank_cost,
                   recompress_cost, solver_crossover_rank,
                   triangular_solve_cost)
from .sherman_morrison import (sherman_morrison, sherman_morrison_delta,
                               woodbury, woodbury_delta)
from . import iterative

__all__ = [
    "Dim", "Expr", "ShapeError", "Var", "add", "const", "identity",
    "inverse", "matmul", "scale", "sub", "transpose", "var", "zero",
    "Program", "Statement", "dim",
    "DeltaRep", "DenseDelta", "HStack", "LowRank",
    "DeltaCarrier", "LowRankCarrier", "RowLocalCarrier", "NoOpCarrier",
    "as_carrier", "detect_row_local", "row_delta_carrier", "stack_carriers",
    "pad_factors_to_rank", "recompress_factors", "stack_update_arrays",
    "DeltaEnv", "derive", "derive_delta", "IncrementalInverseError",
    "Assign", "CompiledProgram", "DeltaView", "Trigger", "ViewUpdate",
    "batch_bucket", "compile_batched_trigger", "compile_delta_trigger",
    "compile_program", "delta_view_name", "extract_inverse_views",
    "build_evaluator", "build_trigger_fn", "evaluate",
    "EngineStats", "IncrementalEngine", "ReevalEngine", "max_abs_diff",
    "Cost", "batch_crossover_rank", "batched_apply_cost", "batched_strategy",
    "cholesky_factor_cost", "cholesky_update_cost", "expr_cost",
    "lowrank_cost", "recompress_cost", "solver_crossover_rank",
    "triangular_solve_cost",
    "sherman_morrison", "sherman_morrison_delta", "woodbury",
    "woodbury_delta", "iterative",
]
