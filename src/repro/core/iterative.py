"""Iterative models (paper §3.2, Table 1) as program generators.

Each generator emits a straight-line :class:`Program` whose statements
follow one of the three recurrences — linear, exponential, skip-s — for

  * matrix powers            P_k = A^k
  * sums of matrix powers    S_k = I + A + … + A^{k-1}
  * the general form         T_{i+1} = A·T_i + B

The emitted program is then fed to the LINVIEW compiler; the incremental /
re-evaluation / hybrid strategies of Table 2 correspond to how the program
is executed, not to different programs.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from . import expr as ex
from .program import Program, dim


def _check_pow2(x: int, what: str):
    if x < 1 or (x & (x - 1)) != 0:
        raise ValueError(f"{what} must be a power of two, got {x}")


def matrix_powers(k: int, n: int, model: str = "exp", s: int = 4,
                  name: Optional[str] = None) -> Program:
    """P_k = A^k per Table 1. Views are named ``P{i}``; output is ``P{k}``."""
    p = Program(name=name or f"powers_{model}_k{k}")
    N = dim("n")
    A = p.input("A", (N, N))
    p.bind_dims(n=n)

    views: Dict[int, ex.Expr] = {1: A}
    if model == "linear":
        for i in range(2, k + 1):
            views[i] = p.let(f"P{i}", ex.matmul(A, views[i - 1]))
    elif model == "exp":
        _check_pow2(k, "k")
        i = 2
        while i <= k:
            half = views[i // 2]
            views[i] = p.let(f"P{i}", ex.matmul(half, half))
            i *= 2
    elif model == "skip":
        _check_pow2(s, "s")
        if k % s != 0:
            raise ValueError(f"k={k} must be a multiple of s={s}")
        i = 2
        while i <= s:
            half = views[i // 2]
            views[i] = p.let(f"P{i}", ex.matmul(half, half))
            i *= 2
        Ps = views[s]
        for i in range(2 * s, k + 1, s):
            views[i] = p.let(f"P{i}", ex.matmul(Ps, views[i - s]))
    else:
        raise ValueError(f"unknown model {model!r}")
    p.outputs = [f"P{k}"] if k > 1 else []
    return p


def sums_of_powers(k: int, n: int, model: str = "exp", s: int = 4,
                   name: Optional[str] = None) -> Program:
    """S_k = I + A + … + A^{k-1} per Table 1.  Output view ``S{k}``."""
    p = Program(name=name or f"sums_{model}_k{k}")
    N = dim("n")
    A = p.input("A", (N, N))
    p.bind_dims(n=n)
    I = ex.identity(N)

    S: Dict[int, ex.Expr] = {}
    P: Dict[int, ex.Expr] = {1: A}
    if model == "linear":
        S[1] = p.let("S1", ex.add(I))  # S_1 = I  (Add of single identity)
        for i in range(2, k + 1):
            S[i] = p.let(f"S{i}", ex.add(ex.matmul(A, S[i - 1]), I))
    elif model == "exp":
        _check_pow2(k, "k")
        S[1] = p.let("S1", ex.add(I))
        i = 2
        while i <= k:
            if i < k:  # P_k itself is not needed for S_k
                P[i] = p.let(f"P{i}", ex.matmul(P[i // 2], P[i // 2]))
            half_p = P[i // 2]
            S[i] = p.let(f"S{i}", ex.add(ex.matmul(half_p, S[i // 2]), S[i // 2]))
            i *= 2
    elif model == "skip":
        _check_pow2(s, "s")
        if k % s != 0:
            raise ValueError(f"k={k} must be a multiple of s={s}")
        S[1] = p.let("S1", ex.add(I))
        i = 2
        while i <= s:
            P[i] = p.let(f"P{i}", ex.matmul(P[i // 2], P[i // 2]))
            S[i] = p.let(f"S{i}", ex.add(ex.matmul(P[i // 2], S[i // 2]), S[i // 2]))
            i *= 2
        for i in range(2 * s, k + 1, s):
            S[i] = p.let(f"S{i}", ex.add(ex.matmul(P[s], S[i - s]), S[s]))
    else:
        raise ValueError(f"unknown model {model!r}")
    p.outputs = [f"S{k}"]
    return p


def append_general_iteration(prog: Program, A: ex.Expr, B: Optional[ex.Expr],
                             T0: ex.Expr, k: int, model: str = "exp",
                             s: int = 4, prefix: str = "") -> str:
    """Append Table-1 statements for T_{i+1} = A·T_i (+ B) to ``prog``.

    ``A`` may be an input *or a previously-defined view* (PageRank and
    gradient descent derive their transition matrix as a view).  Returns
    the name of the output view ``T{k}``.
    """
    N = A.shape[0]
    with_b = B is not None

    def step(x: ex.Expr) -> ex.Expr:
        ax = ex.matmul(A, x)
        return ex.add(ax, B) if with_b else ax

    T: Dict[int, ex.Expr] = {}
    Pw: Dict[int, ex.Expr] = {1: A}
    S: Dict[int, ex.Expr] = {1: ex.identity(N)}

    def emit_doubling(i: int):
        h = i // 2
        Pw[i] = prog.let(f"{prefix}P{i}", ex.matmul(Pw[h], Pw[h]))
        if with_b:
            S[i] = prog.let(f"{prefix}S{i}",
                            ex.add(ex.matmul(Pw[h], S[h]), S[h]))
            T[i] = prog.let(f"{prefix}T{i}", ex.add(ex.matmul(Pw[h], T[h]),
                                                    ex.matmul(S[h], B)))
        else:
            T[i] = prog.let(f"{prefix}T{i}", ex.matmul(Pw[h], T[h]))

    if model == "linear":
        T[1] = prog.let(f"{prefix}T1", step(T0))
        for i in range(2, k + 1):
            T[i] = prog.let(f"{prefix}T{i}", step(T[i - 1]))
    elif model == "exp":
        _check_pow2(k, "k")
        T[1] = prog.let(f"{prefix}T1", step(T0))
        i = 2
        while i <= k:
            emit_doubling(i)
            i *= 2
    elif model == "skip":
        _check_pow2(s, "s")
        if k % s != 0:
            raise ValueError(f"k={k} must be a multiple of s={s}")
        T[1] = prog.let(f"{prefix}T1", step(T0))
        i = 2
        while i <= s:
            emit_doubling(i)
            i *= 2
        for i in range(2 * s, k + 1, s):
            if with_b:
                T[i] = prog.let(f"{prefix}T{i}",
                                ex.add(ex.matmul(Pw[s], T[i - s]),
                                       ex.matmul(S[s], B)))
            else:
                T[i] = prog.let(f"{prefix}T{i}", ex.matmul(Pw[s], T[i - s]))
    else:
        raise ValueError(f"unknown model {model!r}")
    return f"{prefix}T{k}"


def general_form(k: int, n: int, p_dim: int, model: str = "exp", s: int = 4,
                 with_b: bool = True, name: Optional[str] = None) -> Program:
    """T_i per Table 1 for T_{i+1} = A·T_i + B.  Output ``T{k}``.

    ``T0`` (n×p) and ``B`` (n×p) are inputs; ``A`` (n×n) is the dynamic
    matrix.  ``with_b=False`` gives the degenerate T_{i+1} = A·T_i used in
    the paper's Fig. 3g study.
    """
    prog = Program(name=name or f"general_{model}_k{k}")
    N, P_ = dim("n"), dim("p")
    A = prog.input("A", (N, N))
    T0 = prog.input("T0", (N, P_))
    B = prog.input("B", (N, P_)) if with_b else None
    prog.bind_dims(n=n, p=p_dim)
    out = append_general_iteration(prog, A, B, T0, k, model, s)
    prog.outputs = [out]
    return prog
