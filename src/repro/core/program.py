"""Linear-algebra programs (paper §3).

A :class:`Program` is an ordered list of statements ``target := expr`` over
input matrices and previously-defined views, with symbolic dimensions bound
to concrete sizes at compile/run time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from . import expr as ex
from .expr import Dim, Expr, Shape, Var


@dataclass(frozen=True)
class Statement:
    target: Var
    expr: Expr

    def __repr__(self) -> str:
        return f"{self.target.name} := {self.expr!r}"


@dataclass
class Program:
    """A sequence of statements over declared inputs.

    ``outputs`` names the result views (default: last statement's target).
    """

    name: str = "program"
    inputs: Dict[str, Var] = field(default_factory=dict)
    statements: List[Statement] = field(default_factory=list)
    outputs: List[str] = field(default_factory=list)
    dims: Dict[str, int] = field(default_factory=dict)

    # -- construction -------------------------------------------------------
    def input(self, name: str, shape: Shape) -> Var:
        if name in self.inputs:
            raise ValueError(f"duplicate input {name}")
        v = ex.var(name, shape)
        self.inputs[name] = v
        return v

    def let(self, name: str, e: Expr) -> Var:
        if name in self.inputs or any(s.target.name == name for s in self.statements):
            raise ValueError(f"duplicate definition {name}")
        v = ex.var(name, e.shape)
        self.statements.append(Statement(v, e))
        return v

    def bind_dims(self, **dims: int) -> "Program":
        self.dims.update(dims)
        return self

    # -- queries -------------------------------------------------------------
    def view_names(self) -> List[str]:
        return [s.target.name for s in self.statements]

    def statement_for(self, name: str) -> Statement:
        for s in self.statements:
            if s.target.name == name:
                return s
        raise KeyError(name)

    def output_names(self) -> List[str]:
        if self.outputs:
            return list(self.outputs)
        return [self.statements[-1].target.name]

    def __repr__(self) -> str:
        lines = [f"program {self.name}:"]
        lines += [f"  in  {v.name}: {v.shape}" for v in self.inputs.values()]
        lines += [f"  {s!r}" for s in self.statements]
        return "\n".join(lines)


def dim(name: str) -> Dim:
    return Dim(name)
