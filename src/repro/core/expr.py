"""Symbolic linear-algebra expression IR for LINVIEW.

The delta calculus (paper §4.1) operates on a small symbolic IR rather than
on traced JAX values: derivation, common-factor extraction and CSE all
happen *before* staging to XLA, mirroring the paper's compiler/runtime
split (Fig. 2).

Nodes are immutable and hash-consed so that structural equality is pointer
equality; this makes common-subexpression detection during trigger
compilation cheap.

Shapes are symbolic pairs ``(rows, cols)`` where each element is either an
``int`` or a ``Dim`` (a named symbolic dimension).  Vectors are ``(n, 1)``
matrices; scalars are ``(1, 1)``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union


# ---------------------------------------------------------------------------
# symbolic dimensions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Dim:
    """A named symbolic dimension (e.g. ``n``, ``m``, ``p``)."""

    name: str

    def __repr__(self) -> str:  # pragma: no cover - repr cosmetics
        return self.name


DimLike = Union[int, Dim]
Shape = Tuple[DimLike, DimLike]


def dims_equal(a: DimLike, b: DimLike) -> bool:
    return a == b


def shape_mul(a: Shape, b: Shape) -> Shape:
    """Shape of a matrix product; raises on symbolic mismatch."""
    if not dims_equal(a[1], b[0]):
        raise ShapeError(f"matmul mismatch: {a} @ {b}")
    return (a[0], b[1])


class ShapeError(ValueError):
    pass


# ---------------------------------------------------------------------------
# expression nodes
# ---------------------------------------------------------------------------

_INTERN: Dict[Tuple[Any, ...], "Expr"] = {}
_COUNTER = itertools.count()


def _intern(key: Tuple[Any, ...], build) -> "Expr":
    node = _INTERN.get(key)
    if node is None:
        node = build()
        _INTERN[key] = node
    return node


class Expr:
    """Base class. Subclasses are hash-consed; use the module constructors."""

    shape: Shape
    children: Tuple["Expr", ...] = ()

    # --- operator sugar ----------------------------------------------------
    def __matmul__(self, other: "Expr") -> "Expr":
        return matmul(self, other)

    def __mul__(self, other):  # scalar * expr handled in scale()
        return scale(other, self)

    __rmul__ = __mul__

    def __add__(self, other: "Expr") -> "Expr":
        return add(self, other)

    def __sub__(self, other: "Expr") -> "Expr":
        return sub(self, other)

    def __neg__(self) -> "Expr":
        return scale(-1.0, self)

    @property
    def T(self) -> "Expr":
        return transpose(self)

    def inv(self) -> "Expr":
        return inverse(self)

    # --- utilities ---------------------------------------------------------
    def free_vars(self) -> frozenset:
        out = set()
        stack = [self]
        seen = set()
        while stack:
            e = stack.pop()
            if id(e) in seen:
                continue
            seen.add(id(e))
            if isinstance(e, Var):
                out.add(e.name)
            stack.extend(e.children)
        return frozenset(out)

    def contains(self, name: str) -> bool:
        return name in self.free_vars()

    def is_zero(self) -> bool:
        return isinstance(self, Zero)

    def size_nodes(self) -> int:
        seen = set()
        stack = [self]
        while stack:
            e = stack.pop()
            if id(e) in seen:
                continue
            seen.add(id(e))
            stack.extend(e.children)
        return len(seen)


@dataclass(frozen=True, eq=False)
class Var(Expr):
    """A named matrix variable (input matrix or materialized view)."""

    name: str
    shape: Shape

    def __repr__(self) -> str:
        return self.name


@dataclass(frozen=True, eq=False)
class Zero(Expr):
    """The zero matrix of a given shape (delta of an unaffected expr)."""

    shape: Shape

    def __repr__(self) -> str:
        return "0"


@dataclass(frozen=True, eq=False)
class Identity(Expr):
    """The identity matrix I_n."""

    shape: Shape

    def __repr__(self) -> str:
        return "I"


@dataclass(frozen=True, eq=False)
class Const(Expr):
    """A scalar literal, usable as a (1,1) expression or a scale factor."""

    value: float
    shape: Shape = (1, 1)

    def __repr__(self) -> str:
        return repr(self.value)


@dataclass(frozen=True, eq=False)
class MatMul(Expr):
    lhs: Expr
    rhs: Expr
    shape: Shape = field(init=False)
    children: Tuple[Expr, ...] = field(init=False)

    def __post_init__(self):
        object.__setattr__(self, "shape", shape_mul(self.lhs.shape, self.rhs.shape))
        object.__setattr__(self, "children", (self.lhs, self.rhs))

    def __repr__(self) -> str:
        return f"({self.lhs!r} {self.rhs!r})"


@dataclass(frozen=True, eq=False)
class Add(Expr):
    terms: Tuple[Expr, ...]
    shape: Shape = field(init=False)
    children: Tuple[Expr, ...] = field(init=False)

    def __post_init__(self):
        object.__setattr__(self, "shape", self.terms[0].shape)
        object.__setattr__(self, "children", tuple(self.terms))

    def __repr__(self) -> str:
        return "(" + " + ".join(map(repr, self.terms)) + ")"


@dataclass(frozen=True, eq=False)
class Scale(Expr):
    """scalar * matrix.  ``factor`` is an Expr of shape (1,1)."""

    factor: Expr
    operand: Expr
    shape: Shape = field(init=False)
    children: Tuple[Expr, ...] = field(init=False)

    def __post_init__(self):
        object.__setattr__(self, "shape", self.operand.shape)
        object.__setattr__(self, "children", (self.factor, self.operand))

    def __repr__(self) -> str:
        return f"({self.factor!r} * {self.operand!r})"


@dataclass(frozen=True, eq=False)
class Transpose(Expr):
    operand: Expr
    shape: Shape = field(init=False)
    children: Tuple[Expr, ...] = field(init=False)

    def __post_init__(self):
        s = self.operand.shape
        object.__setattr__(self, "shape", (s[1], s[0]))
        object.__setattr__(self, "children", (self.operand,))

    def __repr__(self) -> str:
        return f"{self.operand!r}^T"


@dataclass(frozen=True, eq=False)
class Inverse(Expr):
    operand: Expr
    shape: Shape = field(init=False)
    children: Tuple[Expr, ...] = field(init=False)

    def __post_init__(self):
        s = self.operand.shape
        if not dims_equal(s[0], s[1]):
            raise ShapeError(f"inverse of non-square {s}")
        object.__setattr__(self, "shape", s)
        object.__setattr__(self, "children", (self.operand,))

    def __repr__(self) -> str:
        return f"{self.operand!r}^-1"


# ---------------------------------------------------------------------------
# smart constructors (perform local simplification + hash-consing)
# ---------------------------------------------------------------------------


def var(name: str, shape: Shape) -> Var:
    return _intern(("var", name, shape), lambda: Var(name, shape))


def zero(shape: Shape) -> Zero:
    return _intern(("zero", shape), lambda: Zero(shape))


def identity(n: DimLike) -> Identity:
    return _intern(("identity", n), lambda: Identity((n, n)))


def const(value: float) -> Const:
    return _intern(("const", float(value)), lambda: Const(float(value)))


def matmul(a: Expr, b: Expr) -> Expr:
    if a.is_zero() or b.is_zero():
        return zero(shape_mul(a.shape, b.shape))
    if isinstance(a, Identity):
        return b
    if isinstance(b, Identity):
        return a
    if isinstance(a, Const):
        return scale(a, b)
    if isinstance(b, Const):
        return scale(b, a)
    return _intern(("matmul", id_of(a), id_of(b)), lambda: MatMul(a, b))


def add(*terms: Expr) -> Expr:
    flat = []
    for t in terms:
        if isinstance(t, Add):
            flat.extend(t.terms)
        elif not t.is_zero():
            flat.append(t)
    if not flat:
        return zero(terms[0].shape)
    for t in flat[1:]:
        if t.shape != flat[0].shape:
            raise ShapeError(f"add mismatch: {[x.shape for x in flat]}")
    if len(flat) == 1:
        return flat[0]
    return _intern(("add", tuple(id_of(t) for t in flat)), lambda: Add(tuple(flat)))


def sub(a: Expr, b: Expr) -> Expr:
    return add(a, scale(-1.0, b))


def scale(factor, operand: Expr) -> Expr:
    if not isinstance(factor, Expr):
        factor = const(factor)
    if isinstance(factor, Const):
        if factor.value == 0.0:
            return zero(operand.shape)
        if factor.value == 1.0:
            return operand
        if isinstance(operand, Scale) and isinstance(operand.factor, Const):
            return scale(factor.value * operand.factor.value, operand.operand)
    if operand.is_zero():
        return operand
    return _intern(("scale", id_of(factor), id_of(operand)), lambda: Scale(factor, operand))


def transpose(e: Expr) -> Expr:
    if e.is_zero():
        return zero((e.shape[1], e.shape[0]))
    if isinstance(e, Identity):
        return e
    if isinstance(e, Transpose):
        return e.operand
    if isinstance(e, MatMul):  # (AB)^T = B^T A^T
        return matmul(transpose(e.rhs), transpose(e.lhs))
    if isinstance(e, Add):
        return add(*[transpose(t) for t in e.terms])
    if isinstance(e, Scale):
        return scale(e.factor, transpose(e.operand))
    return _intern(("transpose", id_of(e)), lambda: Transpose(e))


def inverse(e: Expr) -> Expr:
    if isinstance(e, Identity):
        return e
    if isinstance(e, Inverse):
        return e.operand
    return _intern(("inverse", id_of(e)), lambda: Inverse(e))


def id_of(e: Expr) -> int:
    """Identity key used for hash-consing (nodes are interned ⇒ id is stable)."""
    return id(e)


# ---------------------------------------------------------------------------
# substitution & traversal
# ---------------------------------------------------------------------------


def substitute(e: Expr, env: Dict[str, Expr]) -> Expr:
    """Replace Var nodes by expressions from ``env`` (capture-free)."""
    cache: Dict[int, Expr] = {}

    def go(x: Expr) -> Expr:
        hit = cache.get(id(x))
        if hit is not None:
            return hit
        if isinstance(x, Var):
            out = env.get(x.name, x)
        elif isinstance(x, MatMul):
            out = matmul(go(x.lhs), go(x.rhs))
        elif isinstance(x, Add):
            out = add(*[go(t) for t in x.terms])
        elif isinstance(x, Scale):
            out = scale(go(x.factor), go(x.operand))
        elif isinstance(x, Transpose):
            out = transpose(go(x.operand))
        elif isinstance(x, Inverse):
            out = inverse(go(x.operand))
        else:
            out = x
        cache[id(x)] = out
        return out

    return go(e)


def postorder(e: Expr) -> Iterable[Expr]:
    seen = set()
    out = []

    def go(x: Expr):
        if id(x) in seen:
            return
        seen.add(id(x))
        for c in x.children:
            go(c)
        out.append(x)

    go(e)
    return out


def monomials(e: Expr) -> Tuple[Expr, ...]:
    """Flatten an Add tree into its summand monomials."""
    if isinstance(e, Add):
        out = []
        for t in e.terms:
            out.extend(monomials(t))
        return tuple(out)
    if e.is_zero():
        return ()
    return (e,)


def concrete_shape(e: Expr, binding: Dict[str, int]) -> Tuple[int, int]:
    """Resolve symbolic dims against a {dim-name: int} binding."""

    def res(d: DimLike) -> int:
        if isinstance(d, Dim):
            return binding[d.name]
        return int(d)

    return (res(e.shape[0]), res(e.shape[1]))
