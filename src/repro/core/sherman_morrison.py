"""Numeric Sherman–Morrison / Woodbury primitives (paper §4.1).

These are the runtime counterparts of the symbolic rules in
``factored.lowrank_inverse_woodbury`` — used directly by apps that maintain
inverses (OLS) and by tests as oracles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def sherman_morrison(w: Array, u: Array, v: Array) -> Array:
    """New inverse of ``E + u vᵀ`` given ``w = E⁻¹`` — O(n²), no inversion.

    ``u``, ``v`` are (n,1) column vectors (or (n,) — reshaped).
    """
    u = u.reshape(-1, 1)
    v = v.reshape(-1, 1)
    wu = w @ u                       # n×1
    vtw = v.T @ w                    # 1×n
    denom = 1.0 + (vtw @ u)[0, 0]
    return w - (wu @ vtw) / denom


def sherman_morrison_delta(w: Array, u: Array, v: Array) -> Tuple[Array, Array]:
    """Factored delta of the inverse: Δ(E⁻¹) = p qᵀ (paper §4.1)."""
    u = u.reshape(-1, 1)
    v = v.reshape(-1, 1)
    wu = w @ u
    wtv = w.T @ v
    denom = 1.0 + (v.T @ wu)[0, 0]
    return -wu / denom, wtv


def woodbury(w: Array, p: Array, q: Array) -> Array:
    """New inverse of ``E + P Qᵀ`` for rank-k P,Q given ``w = E⁻¹``.

    (E + PQᵀ)⁻¹ = W − W P (I_k + Qᵀ W P)⁻¹ Qᵀ W — only a k×k inversion.
    """
    wp = w @ p                                       # n×k
    cap = jnp.eye(p.shape[1], dtype=w.dtype) + q.T @ wp   # k×k
    return w - wp @ jnp.linalg.solve(cap, q.T @ w)


def woodbury_delta(w: Array, p: Array, q: Array) -> Tuple[Array, Array]:
    """Factored delta (L, R) with Δ(E⁻¹) = L Rᵀ, rank k."""
    wp = w @ p
    cap = jnp.eye(p.shape[1], dtype=w.dtype) + q.T @ wp
    l = -wp @ jnp.linalg.inv(cap)
    r = w.T @ q
    return l, r
