"""LINVIEW runtime: materialized-view store + incremental engine.

The engine owns the compiled program, the jitted re-evaluator, and one
jitted trigger per dynamic input.  ``apply_update`` fires a trigger;
``apply_updates`` coalesces a whole update stream into one batched trigger
firing (stacked factors, §6 batching); ``reevaluate`` is the paper's
baseline strategy for comparison/validation.

With ``mesh=`` the engine routes every trigger firing — per-update and
batched — through the row-sharded apply (:mod:`repro.dist.ivm_shard`):
views are placed row-sharded at initialize time and each firing is the
§6 distributed trigger, numerically identical to the single-device path.

With ``plan=`` (:mod:`repro.plan`) every firing executes a cost-based
**maintenance plan**: per view, factored delta propagation while it
wins, in-firing re-evaluation past the §7 crossover, a rank/staleness
hybrid in between, and lazy (recompute-on-read) refresh for
unmaterialized intermediates.  Compiled triggers are shared across
engine instances through the plan trigger cache.  Engines with
``flush_policy="cost"`` and no explicit plan still get the per-view
re-evaluation fallback: a firing whose stacked rank puts some view past
its crossover re-evaluates that view instead of sweeping it.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .codegen import (_get_apply_fn, build_evaluator,
                      build_planned_trigger_fn, build_rowlocal_inplace_fn,
                      build_rowlocal_trigger_fn, build_trigger_fn, evaluate,
                      trigger_flops)
from .compiler import (CompiledProgram, Trigger, batch_bucket,
                       compile_batched_trigger, compile_delta_trigger,
                       compile_program)
from .factored import (DeltaCarrier, LowRankCarrier, RowLocalCarrier,
                       as_carrier, pad_factors_to_rank, recompress_factors,
                       stack_carriers, stack_update_arrays)
from .program import Program

Array = jax.Array


@dataclass
class EngineStats:
    """Engine counters.

    ``trigger_seconds`` only accumulates for *blocked* firings (an async
    dispatch has no meaningful wall time), so per-update timings divide by
    ``updates_timed`` — counting them against ``updates_applied`` silently
    under-reports whenever any caller passes ``block=False``.
    """

    updates_applied: int = 0      # logical updates (a T-batch counts T)
    triggers_fired: int = 0       # trigger firings (a T-batch counts 1)
    updates_timed: int = 0        # logical updates included in trigger_seconds
    trigger_seconds: float = 0.0
    batches_applied: int = 0
    recompressions: int = 0
    reevals: int = 0
    reeval_seconds: float = 0.0
    plan_reevals: int = 0         # views re-evaluated inside planned firings
    lazy_skips: int = 0           # unmaterialized views left stale by firings
    replans: int = 0              # adaptive plan hot-swaps
    # FLOPs behind the timed seconds above — the observed wall-clock
    # rates (trigger_seconds/sweep_flops_timed vs
    # reeval_seconds/reeval_flops_timed) are what
    # AdaptivePlanner.refit_from_stats turns into an online cost_scale.
    sweep_flops_timed: float = 0.0
    reeval_flops_timed: float = 0.0
    # deferred-cascade (depth >= 2) maintenance counters
    folds: int = 0                # window folds (all tiers folded = 1)
    fold_sweeps: int = 0          # views folded via one stacked sweep
    fold_reevals: int = 0         # views folded via re-evaluation
    fold_aborts: int = 0          # folds rolled back (guard/chaos), then redone
    reads: int = 0                # output() calls — the read-rate signal that
                                  # online depth selection divides firings by
    # sparsity-aware carrier counters (repro.core.factored.DeltaCarrier)
    noop_skips: int = 0           # no-op carriers dropped before any firing
    rowlocal_firings: int = 0     # firings that swept only touched row slabs
    widened_carriers: int = 0     # row-local carriers that fell back dense

    def per_update_seconds(self) -> float:
        return self.trigger_seconds / max(self.updates_timed, 1)


class IncrementalEngine:
    """Maintains all program views under factored updates to the inputs."""

    def __init__(self, program: Program,
                 update_ranks: Optional[Dict[str, int]] = None,
                 *, force_rep: Optional[str] = None,
                 sequential_sm: bool = False,
                 apply_backend: str = "xla",
                 jit: bool = True,
                 donate: bool = False,
                 max_batch_rank: Optional[int] = None,
                 recompress_tol: float = 1e-6,
                 rowlocal_fraction: float = 0.25,
                 rowlocal_apply: str = "auto",
                 flush_size: int = 16,
                 flush_age: float = 0.1,
                 flush_policy: str = "fixed",
                 mesh=None,
                 mesh_axis: Optional[str] = None,
                 plan=None,
                 trigger_cache=None,
                 guard=None,
                 chaos=None,
                 order=None,
                 fold_window: int = 8,
                 max_fold_rank: Optional[int] = 64):
        """``flush_policy`` picks how :meth:`enqueue_update` decides to
        flush: ``"fixed"`` trips on the ``flush_size``/``flush_age``
        thresholds; ``"cost"`` asks the §4/§7 cost model instead — the
        queue flushes at the first stacked rank where
        :func:`repro.core.cost.batched_strategy` stops answering
        ``"stacked"`` for some maintained view (``flush_age`` remains as
        the latency bound), and the flushed firing re-evaluates any view
        whose crossover the stacked rank did pass (the per-view
        fallback; flushing early merely *bounds* how far past the
        crossover a view can get).  ``mesh`` routes every trigger firing
        through the row-sharded distributed apply
        (``repro.dist.ivm_shard``); ``mesh_axis`` names the row axis
        (default: the mesh's first).

        ``plan`` attaches a :class:`repro.plan.MaintenancePlan` (or a
        :class:`~repro.plan.WorkloadDescriptor` to plan here, or an
        :class:`~repro.plan.AdaptivePlanner` for online re-planning);
        planned engines share compiled triggers through
        ``trigger_cache`` (default: the process-global
        :func:`repro.plan.global_trigger_cache`), so a second engine
        with an identical plan key never re-jits.

        ``guard`` attaches the :mod:`repro.guard` failure-containment
        layer (a :class:`~repro.guard.GuardConfig`, or ``True`` for the
        defaults): update validation + quarantine at every admission
        point, transactional firings (snapshot → validate outputs →
        atomic rollback), and an optional drift sentinel.  ``chaos``
        (a :class:`~repro.guard.ChaosConfig` or shared
        :class:`~repro.guard.ChaosMonkey`) injects deterministic
        faults — update poisoning and in-trigger raises — so the guard's
        recovery paths are exercised, not trusted.

        ``order`` turns on higher-order (deferred-cascade) maintenance:
        an int applies the depth to every view, a ``{view: depth}`` dict
        assigns per view.  Views with effective depth ``o >= 2`` are not
        swept per firing; their window of updates accumulates in factored
        form and is **folded** — one stacked sweep (or re-evaluation,
        whichever the §7 crossover prefers) from the window-start base —
        every ``fold_window**(o-1)`` firings or at the next read, which is
        the operational form of DBToaster's Δᵏ hierarchy in LINVIEW's
        continuous setting (the first-order coefficient views are already
        materialized; what the hierarchy buys is fold amortization).
        Depth assignments are resolved so a producer view is never
        staler than its consumers.  ``max_fold_rank`` caps the stacked
        window rank via QR/SVD re-compression.  When a maintenance
        ``plan`` carries per-view ``order`` fields (depth-priced by
        ``plan_program``), the plan's depths are authoritative.

        ``rowlocal_fraction`` is the affected-fraction crossover for
        row-local carriers (:mod:`repro.core.factored`): a
        :class:`~repro.core.factored.RowLocalCarrier` touching at most
        this fraction of its input's rows fires the row-slab trigger
        variant (sweeps only the touched rows of every view the
        compiler proved row-local); above it the carrier widens to the
        dense factored path, which stays the bit-exact oracle.

        ``rowlocal_apply`` picks how a contained row-slab firing
        executes: ``"jit"`` always stages the row-slab XLA program;
        ``"inplace"`` mutates the touched rows of each view directly on
        mutable host storage
        (:func:`~repro.core.codegen.build_rowlocal_inplace_fn`) when
        the trigger's whole factor chain is compact — on CPU, where XLA
        ignores buffer donation, this removes the per-firing full-view
        rewrite entirely; ``"auto"`` (default) is ``"inplace"`` on the
        CPU backend and ``"jit"`` elsewhere.  Guarded/chaos engines and
        triggers with any widened view always use the staged path (the
        transaction needs copy-on-write rollback).
        """
        if rowlocal_apply not in ("auto", "jit", "inplace"):
            raise ValueError(f"unknown rowlocal_apply {rowlocal_apply!r}")
        if flush_policy not in ("fixed", "cost"):
            raise ValueError(f"unknown flush_policy {flush_policy!r}")
        if isinstance(order, dict):
            requested_orders = {k: int(v) for k, v in order.items()}
            compile_order = max([1, *requested_orders.values()])
        elif order is not None:
            compile_order = max(1, int(order))
            requested_orders = None  # all views, filled after compile
        else:
            compile_order, requested_orders = 1, {}
        self.compiled: CompiledProgram = compile_program(
            program, update_ranks, force_rep=force_rep,
            sequential_sm=sequential_sm, order=compile_order)
        self.program = self.compiled.program
        self.binding = dict(self.program.dims)
        if requested_orders is None:
            requested_orders = {st.target.name: compile_order
                                for st in self.program.statements}
        else:
            unknown = set(requested_orders) - {
                st.target.name for st in self.program.statements}
            if unknown:
                raise KeyError(f"order assigns unknown views: {sorted(unknown)}")
        self.fold_window = max(2, int(fold_window))
        self.max_fold_rank = max_fold_rank
        self._delta_fns: Dict[Tuple, Callable] = {}
        self._view_orders: Dict[str, int] = \
            self._resolve_view_orders(requested_orders)
        self._deferred: frozenset = frozenset(
            n for n, o in self._view_orders.items() if o >= 2)
        self._tiers: Tuple[int, ...] = tuple(
            sorted({o for o in self._view_orders.values() if o >= 2}))
        self._tier_factors: Dict[int, Dict[str, List]] = \
            {o: {} for o in self._tiers}
        self._tier_firings: Dict[int, int] = {o: 0 for o in self._tiers}
        self._tier_base: Dict[int, Dict[str, Array]] = \
            {o: {} for o in self._tiers}
        self._jit = jit
        self._apply_backend = apply_backend
        self._donate = donate
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._evaluator = build_evaluator(self.program, self.binding, jit=jit)
        # planned execution state (repro.plan)
        self.plan = None
        self.planner = None
        self._cache_ns: Optional[Tuple] = None
        self._trigger_cache = trigger_cache
        self._accum_rank: Dict[str, int] = {}   # hybrid staleness counters
        self._stale: set = set()                # lazy views awaiting refresh
        self._view_costs: Dict[str, List[Tuple[str, Tuple[int, int], float]]] = {}
        if plan is not None and trigger_cache is None:
            from repro.plan import global_trigger_cache
            self._trigger_cache = global_trigger_cache()
        if plan is not None:
            self._attach_plan(plan)
        self._trigger_fns: Dict[str, Callable] = {
            name: self._cached_build(("base", name, trig.rank),
                                     lambda trig=trig: self._build_trigger(trig))
            for name, trig in self.compiled.triggers.items()
        }
        # batched triggers, keyed by (input, bucket rank); compiled lazily
        # so only the buckets a workload actually hits pay compile time.
        self._batched_triggers: Dict[Tuple[str, int], Callable] = {}
        self._bucket_trigger_ir: Dict[Tuple[str, int], Trigger] = {}
        self._planned_fns: Dict[Tuple, Callable] = {}
        # row-slab trigger variants, keyed (input, rank bucket, row bucket)
        self._rowlocal_fns: Dict[Tuple, Callable] = {}
        self.rowlocal_fraction = float(rowlocal_fraction)
        self.rowlocal_apply = rowlocal_apply
        # in-place compact appliers, keyed by input (None = chain not
        # compact); built lazily on first contained firing
        self._rowlocal_inplace_fns: Dict[str, Optional[Callable]] = {}
        # batching policy: cap the stacked rank (QR/SVD re-compression past
        # it) and the queue flush thresholds (size in stacked rank,
        # staleness in seconds).
        self.max_batch_rank = max_batch_rank
        self.recompress_tol = recompress_tol
        self.flush_size = flush_size
        self.flush_age = flush_age
        self.flush_policy = flush_policy
        self._cost_flush_rank: Dict[str, int] = {}
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        self._pending_since: Dict[str, float] = {}
        self.views: Dict[str, Array] = {}
        self.stats = EngineStats()
        # failure containment (repro.guard): imported lazily so unguarded
        # engines never pay the import and the core↔guard layering stays
        # one-directional at module load.
        self.chaos = None
        self.guard = None
        if chaos is not None:
            from repro.guard import as_monkey
            self.chaos = as_monkey(chaos)
        if guard is not None:
            from repro.guard import EngineGuard, GuardConfig
            if guard is True:
                guard = GuardConfig()
            if donate and guard.transactional:
                raise ValueError(
                    "guard+donate are incompatible: transactional firings "
                    "keep the pre-firing view buffers alive for rollback, "
                    "and donation would let XLA overwrite them")
            self.guard = EngineGuard(guard, self)
        # whether guarded firings take the fused in-program path (trigger
        # + finite-check + select-commit in one dispatch) — admission can
        # then defer its own finite screen into that same program
        self._guard_fast_path = (
            self.guard is not None and self.guard.fused_path_ok
            and self.plan is None and self.flush_policy != "cost"
            and not self._deferred)

    # -- higher-order (deferred-cascade) maintenance ---------------------------
    def _resolve_view_orders(self, requested: Dict[str, int]
                             ) -> Dict[str, int]:
        """Effective per-view depth: a producer may never be staler than
        its consumers, so each view's requested depth is clamped to the
        minimum effective depth of the views that read it (inputs are
        always first-order)."""
        names = {st.target.name for st in self.program.statements}
        consumers: Dict[str, List[str]] = {}
        for st in self.program.statements:
            for vname in st.expr.free_vars():
                if vname in names and vname != st.target.name:
                    consumers.setdefault(vname, []).append(st.target.name)
        eff: Dict[str, int] = {}
        for st in reversed(self.program.statements):
            name = st.target.name
            o = max(1, int(requested.get(name, 1)))
            for c in consumers.get(name, ()):
                o = min(o, eff[c])
            eff[name] = o
        return eff

    def _window(self, o: int) -> int:
        return max(1, self.fold_window ** (o - 1))

    def _cascade_pending(self) -> bool:
        return any(fs for o in self._tiers
                   for fs in self._tier_factors[o].values())

    def _cascade_rebase_all(self) -> None:
        self._pending_input = {}
        for o in self._tiers:
            self._tier_factors[o] = {}
            self._tier_firings[o] = 0
            self._tier_base[o] = dict(self.views)

    def _cascade_snapshot(self):
        """Cascade state for transactional rollback (window factors,
        window-start bases, firing counters) — pointer copies only."""
        if not self._tiers:
            return None
        return ({o: {k: list(v) for k, v in self._tier_factors[o].items()}
                 for o in self._tiers},
                {o: dict(self._tier_base[o]) for o in self._tiers},
                dict(self._tier_firings))

    def _cascade_restore(self, snap) -> None:
        if snap is None:
            return
        factors, base, firings = snap
        self._tier_factors = {o: {k: list(v) for k, v in factors[o].items()}
                              for o in factors}
        self._tier_base = {o: dict(base[o]) for o in base}
        self._tier_firings = dict(firings)

    def _cascade_accumulate(self, input_name: str, pairs,
                            defer_input: bool = False) -> None:
        """Append one admitted firing's (pre-padding) factors to every
        tier's window, re-compressing at the rank cap, then fold any tier
        whose window is due.  ``pairs`` is the firing's update list (a
        whole batch still ticks each window once).  With ``defer_input``
        the factors are also banked — exactly, outside any rank cap —
        for :meth:`_apply_pending_inputs` to replay onto the input at
        the next fold."""
        norm = []
        for u, v in pairs:
            u = np.asarray(u, dtype=np.float32)
            v = np.asarray(v, dtype=np.float32)
            if u.ndim == 1:
                u = u[:, None]
            if v.ndim == 1:
                v = v[:, None]
            norm.append((u, v))
        if defer_input:
            self._pending_input.setdefault(input_name, []).extend(norm)
        for o in self._tiers:
            fs = self._tier_factors[o].setdefault(input_name, [])
            fs.extend(norm)
            self._tier_firings[o] += 1
            if self.max_fold_rank is not None:
                rank = sum(a.shape[1] for a, _ in fs)
                if rank > self.max_fold_rank:
                    P, Q = stack_update_arrays(fs)
                    P, Q = recompress_factors(P, Q,
                                              max_rank=self.max_fold_rank,
                                              tol=self.recompress_tol)
                    self._tier_factors[o][input_name] = \
                        [(np.asarray(P), np.asarray(Q))]
                    self.stats.recompressions += 1
        self._maybe_fold()

    def _inputs_deferrable(self, input_name: str) -> bool:
        """True when nothing this trigger maintains needs to be current
        between folds: every maintained target is a deferred (depth >= 2)
        view and no guard/chaos/plan layer expects a per-firing
        transaction or partition decision.  The firing then banks its
        raw factors — no stacking, no padding, no device dispatch — and
        the input apply itself becomes part of the fold."""
        if not self._tiers or self.guard is not None \
                or self.chaos is not None or self.plan is not None \
                or self.planner is not None or self.mesh is not None:
            return False
        targets = {up.view for up in
                   self.compiled.triggers[input_name].updates}
        return (targets - {input_name}) <= self._deferred

    def _apply_pending_inputs(self) -> Dict[str, Tuple]:
        """Materialize deferred input state: one stacked GEMM per input
        applies everything banked since the last fold.  The banked
        factors are exact (never rank-capped), so the input is bitwise
        a function of the update stream alone — replay engines folding
        on the same cadence reproduce it identically.  Returns the
        stacked factors per input (``(P, Q, n_pairs)``) so the fold's
        sweep can reuse them instead of re-stacking the same window."""
        stacked: Dict[str, Tuple] = {}
        for input_name, pairs in self._pending_input.items():
            if not pairs:
                continue
            P, Q = stack_update_arrays(pairs)
            apply_fn = _get_apply_fn(self._apply_backend)
            self.views[input_name] = apply_fn(
                self.views[input_name], jnp.asarray(P), jnp.asarray(Q))
            stacked[input_name] = (P, Q, len(pairs))
            pairs.clear()
        return stacked

    def _maybe_fold(self) -> None:
        due = [o for o in self._tiers
               if self._tier_firings[o] >= self._window(o)]
        if due:
            self._fold(max(due))

    def _fold(self, upto: int) -> None:
        """Fold the pending windows of every tier <= ``upto``, lowest
        first (a tier's fold reads its ancestors' *current* values, and
        lower tiers are never staler than higher ones).

        Guarded engines run the fold transactionally: snapshot → (chaos)
        → fold → finite-check, with rollback + an exact re-evaluation
        fallback on failure — a fold is a firing as far as containment
        is concerned."""
        tiers = [o for o in self._tiers if o <= upto]
        if not tiers:
            return
        # deferred-input engines bank the raw input factors per firing;
        # the fold is where the input state materializes (one stacked
        # GEMM — the same FLOPs as the per-firing applies it replaces)
        self._fold_prestacked = self._apply_pending_inputs()
        guarded = self.guard is not None and self.guard.config.transactional
        if guarded or self.chaos is not None:
            from repro.guard.txn import (FiringAborted, check_finite,
                                         restore_snapshot, take_snapshot)
            snap = take_snapshot(self) if guarded else None
            try:
                if self.chaos is not None:
                    self.chaos.maybe_raise_in_trigger()
                folded: set = set()
                for o in tiers:
                    folded |= self._fold_tier(o)
                if guarded and folded:
                    reason = check_finite(self.views, folded)
                    if reason is not None:
                        raise FiringAborted(reason, "<fold>", "validate")
            except Exception:
                if snap is None:
                    raise  # unguarded chaos: propagate like any kernel error
                restore_snapshot(self, snap)
                self.stats.fold_aborts += 1
                self.guard.stats.rollbacks += 1
                # exact, chaos-free fallback: re-evaluate the deferred
                # views from their (current) ancestors
                for o in tiers:
                    self._fold_tier(o, force_reeval=True)
        else:
            for o in tiers:
                self._fold_tier(o)
        self._fold_prestacked = {}
        self.stats.folds += 1

    def _fold_tier(self, o: int, force_reeval: bool = False) -> set:
        """Fold one tier's window and rebase it on the resulting store.
        Returns the set of view names the fold wrote."""
        targets = {n for n, oo in self._view_orders.items() if oo == o}
        factors = self._tier_factors.get(o, {})
        touched = [n for n, fs in factors.items() if fs]
        folded: set = set()
        if targets and touched:
            affected: set = set()
            for input_name in touched:
                affected |= {up.view for up in
                             self.compiled.triggers[input_name].updates}
            affected &= targets
            if affected:
                if force_reeval or len(touched) > 1:
                    # multi-input windows interleave updates to different
                    # inputs; re-evaluation from current ancestors is the
                    # always-exact fold for any mix
                    folded = self._fold_reeval(affected)
                else:
                    folded = self._fold_sweep(o, touched[0], affected)
        self._tier_factors[o] = {}
        self._tier_firings[o] = 0
        self._tier_base[o] = dict(self.views)
        self._stale -= targets
        return folded

    def _fold_reeval(self, affected: set) -> set:
        # one fused jitted re-evaluation from the (current) inputs
        # instead of an eager per-statement walk: at fold time the walk
        # pays ~2x the evaluator's cost in per-op dispatch alone, and
        # the fold IS the amortized price the depth-2 plan is built on.
        # Non-affected targets the evaluator recomputes are simply not
        # written back; replay/oracle engines fold through this same
        # path, so determinism comparisons stay bit-identical.
        computed = self._evaluator({k: self.views[k]
                                    for k in self.program.inputs})
        for name in affected:
            self.views[name] = computed[name]
        self.stats.fold_reevals += len(affected)
        return set(affected)

    def _fold_sweep(self, o: int, input_name: str, affected: set) -> set:
        """Single-input window fold: stack the window's factors and sweep
        each affected view ONCE from the tier's window-start base (the
        trigger's pre-update contract makes this exact), falling back to
        re-evaluation per view past its §7 crossover at the window rank."""
        from .cost import batched_strategy
        fs = self._tier_factors[o][input_name]
        pre = getattr(self, "_fold_prestacked", {}).get(input_name)
        if pre is not None and pre[2] == len(fs):
            # this tier's window is exactly the pending-input set the
            # fold just applied (both are "every update since time X"
            # append-only logs, so equal length ⇒ equal content): reuse
            # its stacked factors instead of re-concatenating the window
            P, Q = pre[0], pre[1]
        else:
            P, Q = stack_update_arrays(fs)
        r = int(P.shape[1])
        costs = {name: (shape, re) for name, shape, re
                 in self._factored_view_costs(input_name)}
        sweep: set = set()
        reeval: set = set()
        for name in affected:
            info = costs.get(name)
            if info is None:
                reeval.add(name)  # dense-rep views: no factored sweep
                continue
            shape, re_flops = info
            if batched_strategy(shape, r, r, re_flops) == "stacked":
                sweep.add(name)
            else:
                reeval.add(name)
        if sweep:
            bucket = batch_bucket(r)
            Pb, Qb = pad_factors_to_rank(P, Q, bucket)
            trig_targets = {up.view for up in
                            self.compiled.triggers[input_name].updates}
            maintained = {st.target.name for st in self.program.statements}
            lazy = frozenset((maintained & trig_targets) - sweep)
            fn = self._planned_trigger_fn(input_name, bucket,
                                          frozenset(), lazy)
            base = dict(self._tier_base[o])
            out = fn(base, np.asarray(Pb), np.asarray(Qb))
            for name in sweep:
                self.views[name] = out[name]
            self.stats.fold_sweeps += len(sweep)
        if reeval:
            self._fold_reeval(reeval)
        return sweep | reeval

    def _build_trigger(self, trig) -> Callable:
        """Single-device jitted trigger, or the row-sharded distributed
        one when the engine was given a mesh."""
        if self.mesh is not None:
            from repro.dist.ivm_shard import build_distributed_trigger
            return build_distributed_trigger(trig, self.program, self.mesh,
                                             jit=self._jit,
                                             axis=self.mesh_axis)
        return build_trigger_fn(trig, self.program, self.binding,
                                jit=self._jit,
                                apply_backend=self._apply_backend,
                                donate=self._donate)

    # -- maintenance plans (repro.plan) ---------------------------------------
    def _attach_plan(self, plan) -> None:
        from repro.plan import AdaptivePlanner, WorkloadDescriptor
        if isinstance(plan, WorkloadDescriptor):
            from repro.plan import plan_for_engine
            plan = plan_for_engine(self, plan)
        if isinstance(plan, AdaptivePlanner):
            self.planner = plan
            plan = plan.bind(self.compiled, self.binding,
                             mesh=self.mesh, mesh_axis=self.mesh_axis)
        self.set_plan(plan)

    def set_plan(self, plan) -> None:
        """Hot-swap the maintenance plan.

        Pending queues, hybrid staleness counters and lazy-view
        staleness all survive the swap — a re-plan changes how future
        firings refresh views, never the values they produce — so a
        serving engine can adopt a re-plan mid-stream without dropping
        its staleness contract.  Raises if the plan was priced for a
        different (program, dims) fingerprint.
        """
        from repro.plan import global_trigger_cache, program_fingerprint
        fp = program_fingerprint(self.program, self.binding)
        if plan.fingerprint != fp:
            raise ValueError(
                f"plan fingerprint {plan.fingerprint} does not match this "
                f"engine's program ({fp}); plans are not portable across "
                f"program structures or dimension bindings")
        if self._trigger_cache is None:
            self._trigger_cache = global_trigger_cache()
        self.plan = plan
        # a plan with per-view depth assignments is authoritative for the
        # deferred cascade: adopt (and re-resolve) its orders, settling
        # any pending windows under the old depths first
        plan_orders = {name: int(getattr(vp, "order", 1) or 1)
                       for name, vp in plan.views.items()}
        if any(o > 1 for o in plan_orders.values()):
            if any(not vp.materialize for vp in plan.views.values()):
                raise ValueError(
                    "a plan assigning depth >= 2 must materialize every "
                    "view: deferred folds sweep from window-start base "
                    "snapshots, which lazy (recompute-on-read) views "
                    "would leave inconsistent")
            self._adopt_orders(plan_orders)
        elif getattr(self, "_deferred", frozenset()):
            self._adopt_orders({})  # re-plan back down to first order
        # planned firings leave the guard's fused fast path (their
        # per-view partitioning runs under the snapshot/rollback path);
        # getattr: set_plan also runs mid-__init__, before the guard
        # (and flush policy) fields exist
        guard = getattr(self, "guard", None)
        self._guard_fast_path = (
            guard is not None and guard.fused_path_ok
            and self.plan is None
            and getattr(self, "flush_policy", None) != "cost"
            and not getattr(self, "_deferred", frozenset()))
        if self.planner is not None and self.planner.plan is not plan:
            # keep the attached adaptive planner's baseline in sync so
            # its next drift check does not silently revert a hot-swap
            self.planner.adopt(plan)

    def _adopt_orders(self, requested: Dict[str, int]) -> None:
        """Hot-swap the per-view depth assignment (adaptive re-plans).

        Pending windows are folded under the OLD depths first so no
        accumulated update is lost, then the cascade state and the
        trigger-cache namespace (which carries the order signature) are
        rebuilt."""
        eff = self._resolve_view_orders(requested)
        if getattr(self, "_view_orders", None) == eff:
            return
        if getattr(self, "_tiers", ()) and getattr(self, "views", None) \
                and self._cascade_pending():
            self._fold(self._tiers[-1])
        self._view_orders = eff
        self._deferred = frozenset(n for n, o in eff.items() if o >= 2)
        self._tiers = tuple(sorted({o for o in eff.values() if o >= 2}))
        self._pending_input = {}
        self._fold_prestacked = {}
        self._tier_factors = {o: {} for o in self._tiers}
        self._tier_firings = {o: 0 for o in self._tiers}
        self._tier_base = {o: dict(getattr(self, "views", None) or {})
                           for o in self._tiers}
        self._cache_ns = None  # namespace embeds the order signature

    def _cache_key(self, tail: Tuple) -> Tuple:
        if self._cache_ns is None:
            from repro.plan import mesh_cache_key, program_fingerprint
            # the namespace includes the compile-time delta depth and the
            # per-view deferral signature: a depth-2 engine must never
            # reuse (or poison) a first-order engine's compiled fns in a
            # shared TriggerCache
            order_sig = tuple(sorted(
                (n, o) for n, o in self._view_orders.items() if o > 1))
            self._cache_ns = (
                program_fingerprint(self.program, self.binding),
                self._apply_backend, self._jit, self._donate,
                self.compiled.force_rep, self.compiled.sequential_sm,
                mesh_cache_key(self.mesh, self.mesh_axis),
                self.compiled.order, order_sig)
        return self._cache_ns + tail

    def _cached_build(self, tail: Tuple, builder: Callable) -> Callable:
        """Build a trigger fn through the shared cache (identical plan
        keys across engine instances reuse the jitted callable — no
        re-trace, no re-compile)."""
        if self._trigger_cache is None:
            return builder()
        return self._trigger_cache.get_or_build(self._cache_key(tail),
                                                builder)

    def _bucket_trigger(self, input_name: str, bucket: int) -> Trigger:
        """The trigger IR for (input, stacked-rank bucket)."""
        base = self.compiled.triggers[input_name]
        if bucket == base.rank:
            return base
        key = (input_name, bucket)
        trig = self._bucket_trigger_ir.get(key)
        if trig is None:
            trig = compile_batched_trigger(self.compiled, input_name, bucket)
            self._bucket_trigger_ir[key] = trig
        return trig

    def _factored_view_costs(self, input_name: str
                             ) -> List[Tuple[str, Tuple[int, int], float]]:
        """(view, shape, reeval FLOPs) per factored-maintained view of
        one trigger; cached per input (used on every cost-policy
        firing)."""
        cached = self._view_costs.get(input_name)
        if cached is None:
            from .cost import expr_cost, shape_of
            trig = self.compiled.triggers[input_name]
            by_name = {s.target.name: s for s in self.program.statements}
            cached = []
            for up in trig.updates:
                st = by_name.get(up.view)
                if up.kind != "lowrank" or st is None:
                    continue
                cached.append((up.view, shape_of(st.target, self.binding),
                               expr_cost(st.expr, self.binding).flops))
            self._view_costs[input_name] = cached
        return cached

    def _plan_decision(self, input_name: str, rank: int
                       ) -> Tuple[frozenset, frozenset]:
        """(views to re-evaluate, views to lazily skip) for a firing of
        ``input_name`` at stacked rank ``rank``."""
        if self.plan is not None:
            reeval, lazy = self.plan.decide(rank, self._accum_rank)
        elif self.flush_policy == "cost":
            # planless cost-policy engines still get the per-view §7
            # fallback: re-evaluate any view the stacked rank pushed
            # past its crossover instead of sweeping it
            from .cost import batched_strategy
            reeval = frozenset(
                name for name, shape, re in
                self._factored_view_costs(input_name)
                if batched_strategy(shape, rank, rank, re) == "reeval")
            lazy = frozenset()
        elif not self._deferred:
            return frozenset(), frozenset()
        else:
            reeval, lazy = frozenset(), frozenset()
        if self._deferred:
            # deferred (depth >= 2) views are never swept per firing:
            # they skip like lazy views and are refreshed by window
            # folds instead of on-read recomputation
            reeval = reeval - self._deferred
            lazy = lazy | self._deferred
        targets = {up.view for up in self.compiled.triggers[input_name].updates}
        # keep the partition scoped to this trigger's targets, EXCEPT
        # that a lazy view left stale by an earlier firing (possibly of
        # a different input's trigger) must stay visible so the planned
        # codegen pulls it into the recompute closure when a view
        # re-evaluated here reads it — otherwise the in-firing reeval
        # would silently consume the stale value
        return reeval & targets, (lazy & targets) | (self._stale & lazy)

    def _planned_trigger_fn(self, input_name: str, bucket: int,
                            reeval: frozenset, lazy: frozenset) -> Callable:
        key = (input_name, bucket, tuple(sorted(reeval)),
               tuple(sorted(lazy)))
        fn = self._planned_fns.get(key)
        if fn is None:
            fn = self._cached_build(
                ("planned",) + key,
                lambda: self._build_planned_trigger(input_name, bucket,
                                                    reeval, lazy))
            self._planned_fns[key] = fn
        return fn

    def _build_planned_trigger(self, input_name: str, bucket: int,
                               reeval: frozenset, lazy: frozenset
                               ) -> Callable:
        trig = self._bucket_trigger(input_name, bucket)
        if self.mesh is not None:
            from repro.dist.ivm_shard import build_distributed_planned_trigger
            return build_distributed_planned_trigger(
                trig, self.program, self.mesh, reeval_views=reeval,
                lazy_views=lazy, jit=self._jit, axis=self.mesh_axis)
        return build_planned_trigger_fn(
            trig, self.program, self.binding, reeval_views=reeval,
            lazy_views=lazy, jit=self._jit,
            apply_backend=self._apply_backend, donate=self._donate)

    def _fire(self, input_name: str, bucket: int, P: Array, Q: Array,
              screened: bool = False) -> None:
        """One trigger firing, transactional when the engine is guarded:
        snapshot → (chaos) → execute → validate outputs → commit, with
        an atomic rollback on any failure (:mod:`repro.guard.txn`).

        ``screened=True`` promises the factors already passed the host
        NaN/Inf screen (batch admission), so the fused fast path can
        drop its redundant in-program input screen — one fewer full
        pass over ``(P, Q)`` on device."""
        if self.guard is not None:
            return self.guard.fire(self, input_name, bucket, P, Q,
                                   screened=screened)
        if self.chaos is not None:
            # unguarded chaos: the injected fault propagates, exactly as
            # a real kernel error would without the guard layer
            self.chaos.maybe_raise_in_trigger()
        return self._fire_inner(input_name, bucket, P, Q)

    def _fire_inner(self, input_name: str, bucket: int, P: Array,
                    Q: Array) -> None:
        """One (possibly planned) trigger firing at stacked rank
        ``bucket``: partition views per the plan, execute, and keep the
        hybrid/lazy bookkeeping current."""
        reeval, lazy = self._plan_decision(input_name, bucket)
        # numpy factors go straight into the jitted trigger: its C++
        # argument path converts (and canonicalizes) them far cheaper
        # than an explicit host-side jnp.asarray/device_put round
        if not self._jit:  # unjitted bodies still need real jax arrays
            P, Q = jnp.asarray(P), jnp.asarray(Q)
        elif isinstance(P, (list, tuple)) or isinstance(Q, (list, tuple)):
            P, Q = np.asarray(P), np.asarray(Q)  # jit rejects raw lists
        if not reeval and not lazy:
            fn = self._batched_trigger_fn(input_name, bucket)
            self.views = fn(self.views, P, Q)
            if self.plan is not None:
                for up in self.compiled.triggers[input_name].updates:
                    self._accum_rank[up.view] = \
                        self._accum_rank.get(up.view, 0) + bucket
            return
        fn = self._planned_trigger_fn(input_name, bucket, reeval, lazy)
        self.views = fn(self.views, P, Q)
        recomputed = set(fn.recomputes)
        # count only plan-DIRECTED re-evaluations; recomputed also holds
        # lazy views pulled into the recompute closure for exactness
        self.stats.plan_reevals += len(reeval)
        self.stats.lazy_skips += len(fn.skipped)
        self._stale |= set(fn.skipped)
        self._stale -= recomputed
        for name in fn.incr_views:
            self._accum_rank[name] = self._accum_rank.get(name, 0) + bucket
        for name in recomputed:
            self._accum_rank[name] = 0

    def refresh(self, block: bool = False) -> Dict[str, Array]:
        """Recompute lazily-materialized views left stale by planned
        firings (program order, so stale ancestors refresh first).  On a
        deferred-cascade engine this is a read point: any pending window
        is folded first, so every deferred view is exact on return."""
        if self._tiers and self._cascade_pending():
            self._fold(self._tiers[-1])
        if not self._stale:
            return self.views
        for st in self.program.statements:
            if st.target.name in self._stale:
                self.views[st.target.name] = evaluate(st.expr, self.views,
                                                      self.binding)
        if block:
            jax.block_until_ready(self.views)
        self._stale.clear()
        return self.views

    # -- lifecycle -----------------------------------------------------------
    def initialize(self, inputs: Dict[str, Array]) -> Dict[str, Array]:
        """Full evaluation of the program; materializes every view (placed
        row-sharded when the engine runs on a mesh)."""
        missing = set(self.program.inputs) - set(inputs)
        if missing:
            raise KeyError(f"missing inputs: {sorted(missing)}")
        computed = self._evaluator(dict(inputs))
        self.views = {**{k: jnp.asarray(v) for k, v in inputs.items()},
                      **computed}
        if self.mesh is not None:
            from repro.dist.ivm_shard import shard_views
            self.views = shard_views(self.views, self.mesh,
                                     axis=self.mesh_axis)
        self._stale.clear()
        self._accum_rank.clear()
        self._cascade_rebase_all()
        return dict(computed)

    # -- incremental path ------------------------------------------------------
    def apply_update(self, input_name: str, u: Array,
                     v: Optional[Array] = None,
                     block: bool = False) -> Dict[str, Array]:
        """Fire the trigger for ``input_name += u @ v.T`` (executing the
        engine's maintenance plan, when one is attached).

        ``u`` may be a :class:`~repro.core.factored.DeltaCarrier`
        instead of a raw left factor (``v`` then stays ``None``): a
        no-op carrier skips the firing entirely, a row-local carrier
        under the engine's ``rowlocal_fraction`` fires the row-slab
        trigger variant, and everything else widens to this dense path
        — which remains bit-identical to what it was before carriers
        existed.

        On a guarded engine the update is validated first (rejects go
        to quarantine, views untouched) and the firing is transactional
        (a chaos fault or non-finite output rolls back and returns the
        pre-firing views)."""
        if isinstance(u, DeltaCarrier) or v is None:
            return self._apply_carrier(input_name, as_carrier(u, v),
                                       block=block)
        rank = self.compiled.triggers[input_name].rank
        if self._tiers and self._inputs_deferrable(input_name):
            # deferred-input fast path: bank the factors and return —
            # the fold materializes the input along with the views
            self._cascade_accumulate(input_name, [(u, v)],
                                     defer_input=True)
            self.stats.updates_applied += 1
            self.stats.triggers_fired += 1
            if block:
                jax.block_until_ready(self.views)
            return self.views
        if self.chaos is not None:
            u, v = self.chaos.poison_update(u, v)
        if self.guard is not None:
            admitted = self.guard.admit(input_name, u, v,
                                        defer_finite=self._guard_fast_path)
            if admitted is None:
                return self.views
            u, v = admitted
        t0 = time.perf_counter()
        if self.guard is not None or self.chaos is not None:
            from repro.guard.txn import FiringAborted
            try:
                self._fire(input_name, rank, u, v)
            except FiringAborted as e:
                self.guard.on_abort(input_name, u, v, e.reason)
                return self.views
        elif self.plan is None and self.flush_policy != "cost" \
                and not self._deferred:
            fn = self._trigger_fns[input_name]
            # np factors feed the jit directly — see _fire_inner
            if not self._jit:
                u, v = jnp.asarray(u), jnp.asarray(v)
            elif isinstance(u, (list, tuple)) or isinstance(v, (list, tuple)):
                u, v = np.asarray(u), np.asarray(v)
            self.views = fn(self.views, u, v)
        else:
            self._fire(input_name, rank, u, v)
        if self._tiers:
            self._cascade_accumulate(input_name, [(u, v)])
        if block:
            jax.block_until_ready(self.views)
            self.stats.trigger_seconds += time.perf_counter() - t0
            self.stats.updates_timed += 1
            self.stats.sweep_flops_timed += self._sweep_flops(input_name, rank)
        self.stats.updates_applied += 1
        self.stats.triggers_fired += 1
        self._observe_firing(input_name, rank, 1)
        if self.guard is not None:
            self.guard.after_firing(self)
        return self.views

    # -- sparsity-aware carrier path (repro.core.factored.DeltaCarrier) --------
    def _rowlocal_ok(self, input_name: str, carrier: DeltaCarrier) -> bool:
        """Whether a row-local carrier may fire the row-slab trigger.

        Requires: a single-device, non-deferred engine (sharded and
        depth>=2 engines widen — the dense path is their oracle), an
        affected fraction under the ``rowlocal_fraction`` crossover, at
        least one maintained view the compiler proved row-local (else
        slab sweeping buys nothing), and an empty plan partition (a
        firing the plan wants to re-evaluate or skip must go through
        the planned dense codegen).  When *every* maintained view is
        row-local the plan/§7 decision is priced at the containment-
        scaled rank ``ceil(rank · frac)`` — a row-slab sweep touches
        ``r·m`` elements where the dense sweep the crossover was solved
        for touches ``n·m``, so a high-rank contained burst must not be
        kicked to re-evaluation at the full-rank price (the same
        ``K*/frac`` scaling the planner applies; docs/sparse_deltas.md).
        Triggers with any widened view keep the full-rank price: those
        views really do pay the dense sweep."""
        if self.mesh is not None or self._tiers:
            return False
        frac = carrier.affected_fraction()
        if frac > self.rowlocal_fraction:
            return False
        trig = self.compiled.triggers[input_name]
        kinds = [trig.carriers.get(up.view) for up in trig.updates
                 if up.kind == "lowrank" and up.view != input_name]
        if not any(kd == "row_local" for kd in kinds):
            # only the input's own (trivially row-local) self-update is
            # contained — every maintained view widens, so the slab
            # trigger buys nothing over the dense sweep
            return False
        rank = max(carrier.rank, 1)
        if all(kd == "row_local" for kd in kinds):
            rank = max(1, int(np.ceil(rank * frac)))
        reeval, lazy = self._plan_decision(input_name, rank)
        return not reeval and not lazy

    def _rowlocal_trigger_fn(self, input_name: str, rank_bucket: int,
                             row_bucket: int) -> Callable:
        """The jitted row-slab trigger for (input, rank bucket, row
        bucket), compiled on first use and shared through the trigger
        cache like every other variant."""
        key = (input_name, rank_bucket, row_bucket)
        fn = self._rowlocal_fns.get(key)
        if fn is None:
            trig = self._bucket_trigger(input_name, rank_bucket)
            fn = self._cached_build(
                ("rowlocal", input_name, rank_bucket, row_bucket),
                lambda: build_rowlocal_trigger_fn(
                    trig, self.program, self.binding,
                    row_bucket=row_bucket, jit=self._jit,
                    apply_backend=self._apply_backend,
                    donate=self._donate))
            self._rowlocal_fns[key] = fn
        return fn

    def _apply_carrier(self, input_name: str, carrier: DeltaCarrier,
                       block: bool = False) -> Dict[str, Array]:
        """Dispatch one carrier: no-op → skip, contained row-local →
        row-slab firing, anything else → widen to the dense factored
        path (``carrier.factors()`` is exact, so widening never changes
        the result — only the traffic)."""
        if input_name not in self.compiled.triggers:
            raise KeyError(f"no trigger for input {input_name!r}; have "
                           f"{sorted(self.compiled.triggers)}")
        if carrier.kind == "noop":
            # legally skip the firing: a no-op moves no view, so there
            # is nothing for chaos to poison or the guard to validate
            self.stats.noop_skips += 1
            self.stats.updates_applied += 1
            if block:
                jax.block_until_ready(self.views)
            return self.views
        if carrier.kind == "row_local":
            if self._rowlocal_ok(input_name, carrier):
                return self._apply_rowlocal(input_name, carrier,
                                            block=block)
            self.stats.widened_carriers += 1
        P, Q = carrier.factors()
        return self.apply_update(input_name, P, Q, block=block)

    def _apply_rowlocal(self, input_name: str, carrier: RowLocalCarrier,
                        block: bool = False, t_count: int = 1,
                        poisoned: bool = False) -> Dict[str, Array]:
        """Fire the row-slab trigger for one (possibly stacked)
        row-local carrier: chaos poisoning and guard admission run on
        the *compact* ``(block, V)`` factors (same call sequence as the
        dense path — one poison gate per logical update stream entry is
        preserved by the batch path poisoning members before stacking),
        then the rank is padded to its power-of-two bucket and the row
        set to a power-of-two row bucket (out-of-bounds sentinel ``n``,
        zero block rows — exact, see
        :func:`~repro.core.codegen.build_rowlocal_trigger_fn`)."""
        rows = np.asarray(carrier.rows, dtype=np.int32)
        B = np.asarray(carrier.block, dtype=np.float32)
        V = np.asarray(carrier.V, dtype=np.float32)
        if self.chaos is not None and not poisoned:
            B, V = self.chaos.poison_update(B, V)
            B = np.asarray(B, dtype=np.float32)
            V = np.asarray(V, dtype=np.float32)
        if self.guard is not None:
            admitted = self.guard.admit_carrier(input_name, rows, B, V,
                                                count=t_count)
            if admitted is None:
                return self.views
            B, V = admitted
        t0 = time.perf_counter()
        rows0, B0, V0 = rows, B, V  # pre-padding (what an abort keeps)
        rank = B.shape[1]
        n_in = int(carrier.nm[0])
        if (self.guard is None and self.chaos is None
                and (self.rowlocal_apply == "inplace"
                     or (self.rowlocal_apply == "auto"
                         and jax.default_backend() == "cpu"))):
            infn = self._rowlocal_inplace_fn(input_name)
            if infn is not None:
                # unguarded compact chain: mutate the touched rows in
                # place — no padding, no staged program, no copy floor
                self.views = infn(self.views, rows, B, V)
                return self._rowlocal_epilogue(input_name, carrier, rank,
                                               int(rows.shape[0]), t0,
                                               block, t_count)
        base = self.compiled.triggers[input_name].rank
        rank_bucket = rank if rank == base else batch_bucket(rank)
        if rank_bucket != rank:
            B = np.concatenate(
                [B, np.zeros((B.shape[0], rank_bucket - rank),
                             np.float32)], axis=1)
            V = np.concatenate(
                [V, np.zeros((V.shape[0], rank_bucket - rank),
                             np.float32)], axis=1)
        r = int(rows.shape[0])
        row_bucket = max(8, 1 << (r - 1).bit_length())
        if row_bucket > r:
            rows = np.concatenate(
                [rows, np.full(row_bucket - r, n_in, np.int32)])
            B = np.concatenate(
                [B, np.zeros((row_bucket - r, rank_bucket), np.float32)],
                axis=0)
        fn = self._rowlocal_trigger_fn(input_name, rank_bucket, row_bucket)
        if self.guard is not None or self.chaos is not None:
            from repro.guard.txn import FiringAborted
            try:
                if self.guard is not None:
                    self.guard.fire_rowlocal(self, input_name, fn,
                                             rows, B, V)
                else:
                    self.chaos.maybe_raise_in_trigger()
                    self.views = fn(self.views, rows, B, V)
            except FiringAborted as e:
                P0 = np.zeros((n_in, B0.shape[1]), np.float32)
                P0[rows0] = B0
                self.guard.on_abort(input_name, P0, V0, e.reason)
                return self.views
        else:
            self.views = fn(self.views, rows, B, V)
        return self._rowlocal_epilogue(input_name, carrier, rank_bucket, r,
                                       t0, block, t_count)

    def _rowlocal_inplace_fn(self, input_name: str) -> Optional[Callable]:
        """The in-place compact applier for ``input_name``'s trigger
        (``None`` when its factor chain is not compact), built once."""
        if input_name not in self._rowlocal_inplace_fns:
            self._rowlocal_inplace_fns[input_name] = \
                build_rowlocal_inplace_fn(
                    self.compiled.triggers[input_name], self.program,
                    self.binding)
        return self._rowlocal_inplace_fns[input_name]

    def _rowlocal_epilogue(self, input_name: str, carrier: RowLocalCarrier,
                           rank: int, r: int, t0: float, block: bool,
                           t_count: int) -> Dict[str, Array]:
        """Shared accounting tail of a row-slab firing (staged or
        in-place): plan staleness, timed-sweep stats, firing counters,
        and the planner's observed affected fraction."""
        if self.plan is not None:
            for up in self.compiled.triggers[input_name].updates:
                self._accum_rank[up.view] = \
                    self._accum_rank.get(up.view, 0) + rank
        if block:
            jax.block_until_ready(self.views)
            self.stats.trigger_seconds += time.perf_counter() - t0
            self.stats.updates_timed += t_count
            self.stats.sweep_flops_timed += \
                self._rowlocal_sweep_flops(input_name, rank, r)
        self.stats.updates_applied += t_count
        self.stats.triggers_fired += 1
        self.stats.rowlocal_firings += 1
        if t_count > 1:
            self.stats.batches_applied += 1
        self._observe_firing(input_name, carrier.rank, t_count,
                             affected_fraction=carrier.affected_fraction())
        if self.guard is not None:
            self.guard.after_firing(self)
        return self.views

    def _rowlocal_sweep_flops(self, input_name: str, rank: int,
                              r: int) -> float:
        """FLOPs of one row-slab sweep: row-local views pay
        ``2·rank·r·m``, widened views the full ``2·rank·n·m``."""
        trig = self.compiled.triggers[input_name]
        total = 0.0
        for name, (n, m), _ in self._factored_view_costs(input_name):
            rows_eff = r if trig.carriers.get(name) == "row_local" else n
            total += 2.0 * rank * rows_eff * m
        return total

    def _apply_carrier_batch(self, input_name: str, updates,
                             block: bool = False) -> Dict[str, Array]:
        """Batched carrier path: drop no-ops, stack the rest
        (:func:`~repro.core.factored.stack_carriers` — union row
        support while everything stays row-local), and fire once.  A
        stack that widens — any dense member, or a union past the
        crossover — expands to factor pairs and rides the ordinary
        batched path, whose per-update poisoning/admission semantics it
        then inherits verbatim."""
        carriers = [x if isinstance(x, DeltaCarrier)
                    else as_carrier(x[0], x[1]) for x in updates]
        live = [c for c in carriers if c.kind != "noop"]
        skipped = len(carriers) - len(live)
        self.stats.noop_skips += skipped
        self.stats.updates_applied += skipped
        if not live:
            if block:
                jax.block_until_ready(self.views)
            return self.views
        probe = stack_carriers(live)
        if not (probe.kind == "row_local"
                and self._rowlocal_ok(input_name, probe)):
            self.stats.widened_carriers += \
                sum(1 for c in live if c.kind == "row_local")
            return self.apply_updates(input_name,
                                      [c.factors() for c in live],
                                      block=block)
        # row-local fast path: poison each member compactly (one chaos
        # gate per logical update — the same draw count as the dense
        # batched path), restack, optionally re-compress the compact
        # factors (QR touches only the r affected rows, so the row
        # support is preserved exactly), then one row-slab firing
        if self.chaos is not None:
            repl = []
            for c in live:
                Bp, Vp = self.chaos.poison_update(c.block, c.V)
                repl.append(RowLocalCarrier(
                    c.rows, np.asarray(Bp, np.float32),
                    np.asarray(Vp, np.float32), c.n))
            live = repl
            probe = stack_carriers(live)
        stacked = probe
        if (self.max_batch_rank is not None
                and stacked.rank > self.max_batch_rank):
            B2, V2 = recompress_factors(stacked.block, stacked.V,
                                        max_rank=self.max_batch_rank,
                                        tol=self.recompress_tol)
            stacked = RowLocalCarrier(stacked.rows,
                                      np.asarray(B2, np.float32),
                                      np.asarray(V2, np.float32),
                                      stacked.n)
            self.stats.recompressions += 1
        return self._apply_rowlocal(input_name, stacked, block=block,
                                    t_count=len(live), poisoned=True)

    # -- batched incremental path ---------------------------------------------
    def apply_updates(self, input_name: str,
                      updates: Sequence[Tuple[Array, Array]],
                      block: bool = False) -> Dict[str, Array]:
        """Apply a whole update stream ``[(u_1, v_1) … (u_T, v_T)]`` to one
        input in a single batched trigger firing (§6 batching).

        The factors are stacked into ``P = [u_1 … u_T]``, ``Q = [v_1 … v_T]``
        (one rank-ΣkT update), optionally re-compressed when the stacked
        rank exceeds ``max_batch_rank``, then zero-padded up to the next
        power-of-two bucket so the per-bucket jit cache stays warm across
        ragged batch sizes.  Every maintained view is swept ONCE per batch
        instead of once per update — the whole point of the pipeline.
        """
        if input_name not in self.compiled.triggers:
            raise KeyError(f"no trigger for input {input_name!r}; have "
                           f"{sorted(self.compiled.triggers)}")
        updates = list(updates)
        if any(isinstance(x, DeltaCarrier) for x in updates):
            return self._apply_carrier_batch(input_name, updates,
                                             block=block)
        if self.chaos is not None:
            updates = [self.chaos.poison_update(u, v) for u, v in updates]
        if not updates:
            return self.views
        if self._tiers and self._inputs_deferrable(input_name):
            # deferred-input fast path: every maintained target of this
            # trigger is folded from the window anyway, so the firing
            # banks its raw factors and does no stacking, padding, or
            # device dispatch at all; flush()/output() (and any due
            # fold) first materialize the pending input state
            self._cascade_accumulate(input_name, updates, defer_input=True)
            self.stats.updates_applied += len(updates)
            self.stats.triggers_fired += 1
            self.stats.batches_applied += 1
            if block:
                jax.block_until_ready(self.views)
            return self.views
        t0 = time.perf_counter()  # before admission+stacking: host-side
        # concat (and any device sync from jax-array factors) is part of
        # the batch cost — the guard's fast path fuses admission INTO
        # the concat the trigger needs anyway
        P = Q = None
        if self.guard is not None:
            stacked = self.guard.admit_batch_stacked(input_name, updates)
            if stacked is not None:
                P, Q = stacked
            else:
                # careful walk: one poisoned update quarantines alone
                # and the healthy remainder still batches
                updates = self.guard.admit_batch(input_name, updates)
                if not updates:
                    return self.views
        t_count = len(updates)
        if P is None:
            P, Q = stack_update_arrays(updates)
        stacked_rank = P.shape[1]
        if self.max_batch_rank is not None and P.shape[1] > self.max_batch_rank:
            P, Q = recompress_factors(P, Q, max_rank=self.max_batch_rank,
                                      tol=self.recompress_tol)
            self.stats.recompressions += 1
        P0, Q0 = P, Q  # pre-padding factors (what a rollback quarantines)
        bucket = batch_bucket(P.shape[1])
        P, Q = pad_factors_to_rank(P, Q, bucket)
        if self.guard is not None or self.chaos is not None:
            from repro.guard.txn import FiringAborted
            try:
                # batch admission already host-screened the factors
                self._fire(input_name, bucket, P, Q, screened=True)
            except FiringAborted as e:
                self.guard.on_abort(input_name, P0, Q0, e.reason)
                return self.views
        else:
            self._fire(input_name, bucket, P, Q)
        if self._tiers:
            self._cascade_accumulate(input_name, [(P0, Q0)])
        if block:
            jax.block_until_ready(self.views)
            self.stats.trigger_seconds += time.perf_counter() - t0
            self.stats.updates_timed += t_count
            self.stats.sweep_flops_timed += self._sweep_flops(input_name,
                                                              bucket)
        self.stats.updates_applied += t_count
        self.stats.triggers_fired += 1
        self.stats.batches_applied += 1
        self._observe_firing(input_name, stacked_rank, t_count)
        if self.guard is not None:
            self.guard.after_firing(self)
        return self.views

    def _sweep_flops(self, input_name: str, rank: int) -> float:
        """FLOPs of one factored sweep over this trigger's maintained
        views at stacked rank ``rank`` — the denominator behind
        ``stats.trigger_seconds`` that online cost_scale refitting
        (:meth:`repro.plan.AdaptivePlanner.refit_from_stats`) divides by."""
        return sum(2.0 * rank * n * m for _, (n, m), _
                   in self._factored_view_costs(input_name))

    def _observe_firing(self, input_name: str, stacked_rank: int,
                        t_count: int,
                        affected_fraction: Optional[float] = None) -> None:
        """Report one firing to the attached adaptive planner (both the
        per-update and the batched path), adopting a re-plan if due.
        Row-local firings also report their affected fraction, which
        the adaptive planner folds into the observed workload; a custom
        planner whose ``observe`` predates the kwarg still works."""
        if self.planner is None:
            return
        if affected_fraction is not None:
            try:
                self.planner.observe(input_name, stacked_rank, t_count,
                                     affected_fraction=affected_fraction)
            except TypeError:
                self.planner.observe(input_name, stacked_rank, t_count)
        else:
            self.planner.observe(input_name, stacked_rank, t_count)
        if hasattr(self.planner, "refit_from_stats"):
            self.planner.refit_from_stats(self.stats)
        new_plan = self.planner.maybe_replan()
        if new_plan is not None:
            self.set_plan(new_plan)
            self.stats.replans += 1

    def _batched_trigger_fn(self, input_name: str, bucket: int) -> Callable:
        """The jitted trigger for (input, bucket), compiled on first use."""
        key = (input_name, bucket)
        fn = self._batched_triggers.get(key)
        if fn is None:
            base = self.compiled.triggers[input_name]
            if bucket == base.rank:
                fn = self._trigger_fns[input_name]
            else:
                fn = self._cached_build(
                    ("batched", input_name, bucket),
                    lambda: self._build_trigger(
                        self._bucket_trigger(input_name, bucket)))
            self._batched_triggers[key] = fn
        return fn

    # -- update queue (serving-path coalescing) --------------------------------
    def enqueue_update(self, input_name: str, u: Array, v: Array
                       ) -> Optional[Dict[str, Array]]:
        """Queue ``input_name += u @ v.T`` for the next coalesced flush.

        Flushes automatically per the engine's ``flush_policy`` —
        ``"fixed"``: pending stacked rank reaches ``flush_size``;
        ``"cost"``: the cost model's crossover (:meth:`cost_flush_rank`);
        both: the oldest queued update is older than ``flush_age``
        seconds.  Returns the refreshed views on flush, else ``None``
        (views are stale until the next :meth:`flush`).
        """
        if input_name not in self.compiled.triggers:
            raise KeyError(f"no trigger for input {input_name!r}; have "
                           f"{sorted(self.compiled.triggers)}")
        u = np.asarray(u, dtype=np.float32)
        v = np.asarray(v, dtype=np.float32)
        if self.chaos is not None:
            u, v = self.chaos.poison_update(u, v)
        if self.guard is not None:
            admitted = self.guard.admit(input_name, u, v)
            if admitted is None:
                return None
            u, v = admitted
        q = self._pending.setdefault(input_name, [])
        if not q:
            self._pending_since[input_name] = time.perf_counter()
        q.append((u, v))
        return self.maybe_flush(input_name)

    def pending_rank(self, input_name: str) -> int:
        return sum(u.shape[1] if u.ndim == 2 else 1
                   for u, _ in self._pending.get(input_name, ()))

    def pending_age(self, input_name: str) -> float:
        if not self._pending.get(input_name):
            return 0.0
        return time.perf_counter() - self._pending_since[input_name]

    def maybe_flush(self, input_name: str) -> Optional[Dict[str, Array]]:
        """Flush one input's queue if the active policy says so.

        ``"fixed"``: the stacked-rank/staleness thresholds.  ``"cost"``:
        the cost model — flush at the first stacked rank where some
        maintained view's :func:`~repro.core.cost.batched_strategy` stops
        answering ``"stacked"`` (the §7 crossover); staleness still
        bounds latency.  Flushing at the crossover does NOT by itself
        re-evaluate the losing view — it bounds the stacked rank; the
        flushed firing then makes the per-view choice (:meth:`_fire`),
        re-evaluating exactly the views the rank pushed past their
        crossover and sweeping the rest.
        """
        if self.pending_age(input_name) >= self.flush_age:
            return self.flush(input_name)
        threshold = (self.cost_flush_rank(input_name)
                     if self.flush_policy == "cost" else self.flush_size)
        if self.pending_rank(input_name) >= threshold:
            return self.flush(input_name)
        return None

    def _lowrank_view_costs(self, input_name: str
                            ) -> List[Tuple[Tuple[int, int], float]]:
        """(view shape, per-view reeval FLOPs) for every maintained view
        the trigger updates in factored form (the input itself has no
        re-evaluation expression and is excluded)."""
        return [(shape, reeval) for _, shape, reeval
                in self._factored_view_costs(input_name)]

    def cost_flush_rank(self, input_name: str) -> int:
        """The stacked rank at which the ``"cost"`` policy flushes: the
        first K where ``batched_strategy(shape, K, K, reeval)`` stops
        answering ``"stacked"`` for some maintained view, i.e. one past
        the smallest §7 crossover (first integer K with
        reeval_flops < 2·K·n·m).  Computed once per input and cached;
        triggers with no factored views fall back to ``flush_size``.
        The firing this flush triggers re-evaluates any view actually
        past its own crossover (per-view fallback) rather than sweeping
        it at the losing rank.
        """
        cached = self._cost_flush_rank.get(input_name)
        if cached is None:
            firsts = [int(reeval / (2.0 * n * m)) + 1
                      for (n, m), reeval
                      in self._lowrank_view_costs(input_name)]
            cached = min(firsts) if firsts else self.flush_size
            self._cost_flush_rank[input_name] = cached
        return cached

    def flush(self, input_name: Optional[str] = None,
              block: bool = False) -> Dict[str, Array]:
        """Apply all pending updates (for one input, or every input).

        The exactness point before a read: also recomputes any lazily
        maintained views that planned firings left stale, so every view
        in :attr:`views` is current when this returns."""
        names = [input_name] if input_name is not None else \
            [n for n, q in self._pending.items() if q]
        for name in names:
            q = self._pending.get(name)
            if q:
                # apply before popping: if the trigger raises, the queue
                # survives for a retry instead of silently vanishing
                self.apply_updates(name, q, block=block)
            self._pending.pop(name, None)
            self._pending_since.pop(name, None)
        if self._stale or (self._tiers and self._cascade_pending()):
            self.refresh(block=block)
        return self.views

    # -- baseline path ---------------------------------------------------------
    def reevaluate(self, block: bool = False) -> Dict[str, Array]:
        """The paper's re-evaluation strategy: recompute from the current
        inputs (which the triggers have been keeping up to date)."""
        self._apply_pending_inputs()  # deferred-input engines: make current
        inputs = {k: self.views[k] for k in self.program.inputs}
        t0 = time.perf_counter()
        computed = self._evaluator(inputs)
        if block:
            jax.block_until_ready(computed)
            self.stats.reeval_seconds += time.perf_counter() - t0
            self.stats.reeval_flops_timed += self.reeval_flops()
        self.views.update(computed)
        self._stale.clear()
        self._accum_rank.clear()
        self._cascade_rebase_all()  # windows are void: every view is current
        self.stats.reevals += 1
        return dict(computed)

    # -- introspection -----------------------------------------------------------
    def output(self, name: Optional[str] = None) -> Array:
        self.stats.reads += 1
        if self.planner is not None and \
                hasattr(self.planner, "observe_read"):
            self.planner.observe_read()
        if self._stale or (self._tiers and self._cascade_pending()):
            self.refresh()
        name = name or self.program.output_names()[0]
        return self.views[name]

    def trigger_flops(self, input_name: str) -> float:
        return trigger_flops(self.compiled.triggers[input_name], self.program,
                             self.binding)

    # -- materialized Δᵈ views (symbolic hierarchy) ----------------------------
    def delta_trigger_fn(self, input_name: str, depth: int,
                         rank: Optional[int] = None) -> Callable:
        """Jitted trigger maintaining the ``__d{depth}__V`` views.

        The shared-cache key carries the depth explicitly (plus the
        engine namespace's order signature) — the latent collision this
        fixes: the old tails ``("base", input, rank)`` would have let a
        depth-2 trigger silently reuse a first-order compiled fn."""
        if rank is None:
            rank = self.compiled.triggers[input_name].rank
        bucket = batch_bucket(rank)
        if depth == 1:
            return self._batched_trigger_fn(input_name, bucket)
        key = (input_name, depth, bucket)
        fn = self._delta_fns.get(key)
        if fn is None:
            fn = self._cached_build(
                ("delta", input_name, depth, bucket),
                lambda: self._build_trigger(compile_delta_trigger(
                    self.compiled, input_name, depth, bucket)))
            self._delta_fns[key] = fn
        return fn

    def materialize_delta_views(self, input_name: str, depth: int,
                                rank: Optional[int] = None
                                ) -> Tuple[str, ...]:
        """Zero-initialize the ΔᵈV auxiliary views the depth-``depth``
        trigger for ``input_name`` maintains; returns their names."""
        from .cost import shape_of
        if rank is None:
            rank = self.compiled.triggers[input_name].rank
        trig = compile_delta_trigger(self.compiled, input_name, depth,
                                     batch_bucket(rank))
        by_name = {st.target.name: st.target
                   for st in self.program.statements}
        names = []
        for up in trig.updates:
            base = up.view.split("__", 2)[-1]
            n, m = shape_of(by_name[base], self.binding)
            self.views.setdefault(up.view,
                                  jnp.zeros((n, m), dtype=jnp.float32))
            names.append(up.view)
        return tuple(names)

    def reeval_flops(self) -> float:
        from .cost import expr_cost
        seen: Dict[int, bool] = {}
        from .cost import _expr_cost_shared
        return sum(_expr_cost_shared(s.expr, self.binding, seen).flops
                   for s in self.program.statements)


class ReevalEngine:
    """Pure re-evaluation baseline: applies the update to the input, then
    recomputes every view from scratch (paper's REEVAL strategy)."""

    def __init__(self, program: Program, jit: bool = True):
        self.program = program
        self.binding = dict(program.dims)
        self._evaluator = build_evaluator(program, self.binding, jit=jit)
        self.views: Dict[str, Array] = {}

    def initialize(self, inputs: Dict[str, Array]) -> Dict[str, Array]:
        computed = self._evaluator(dict(inputs))
        self.views = {**{k: jnp.asarray(v) for k, v in inputs.items()},
                      **computed}
        return dict(computed)

    def apply_update(self, input_name: str, u: Array, v: Array,
                     block: bool = False) -> Dict[str, Array]:
        self.views[input_name] = self.views[input_name] + u @ v.T
        inputs = {k: self.views[k] for k in self.program.inputs}
        computed = self._evaluator(inputs)
        if block:
            jax.block_until_ready(computed)
        self.views.update(computed)
        return self.views

    def output(self, name: Optional[str] = None) -> Array:
        name = name or self.program.output_names()[0]
        return self.views[name]


def max_abs_diff(a: Dict[str, Array], b: Dict[str, Array],
                 keys: Optional[Tuple[str, ...]] = None) -> float:
    keys = keys or tuple(set(a) & set(b))
    worst = 0.0
    for k in keys:
        worst = max(worst, float(jnp.max(jnp.abs(a[k] - b[k]))))
    return worst
