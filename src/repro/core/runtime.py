"""LINVIEW runtime: materialized-view store + incremental engine.

The engine owns the compiled program, the jitted re-evaluator, and one
jitted trigger per dynamic input.  ``apply_update`` fires a trigger;
``reevaluate`` is the paper's baseline strategy for comparison/validation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .codegen import build_evaluator, build_trigger_fn, trigger_flops
from .compiler import CompiledProgram, compile_program
from .program import Program

Array = jax.Array


@dataclass
class EngineStats:
    updates_applied: int = 0
    trigger_seconds: float = 0.0
    reevals: int = 0
    reeval_seconds: float = 0.0


class IncrementalEngine:
    """Maintains all program views under factored updates to the inputs."""

    def __init__(self, program: Program,
                 update_ranks: Optional[Dict[str, int]] = None,
                 *, force_rep: Optional[str] = None,
                 sequential_sm: bool = False,
                 apply_backend: str = "xla",
                 jit: bool = True,
                 donate: bool = False):
        self.compiled: CompiledProgram = compile_program(
            program, update_ranks, force_rep=force_rep,
            sequential_sm=sequential_sm)
        self.program = self.compiled.program
        self.binding = dict(self.program.dims)
        self._evaluator = build_evaluator(self.program, self.binding, jit=jit)
        self._trigger_fns: Dict[str, Callable] = {
            name: build_trigger_fn(trig, self.program, self.binding, jit=jit,
                                   apply_backend=apply_backend, donate=donate)
            for name, trig in self.compiled.triggers.items()
        }
        self.views: Dict[str, Array] = {}
        self.stats = EngineStats()

    # -- lifecycle -----------------------------------------------------------
    def initialize(self, inputs: Dict[str, Array]) -> Dict[str, Array]:
        """Full evaluation of the program; materializes every view."""
        missing = set(self.program.inputs) - set(inputs)
        if missing:
            raise KeyError(f"missing inputs: {sorted(missing)}")
        computed = self._evaluator(dict(inputs))
        self.views = {**{k: jnp.asarray(v) for k, v in inputs.items()},
                      **computed}
        return dict(computed)

    # -- incremental path ------------------------------------------------------
    def apply_update(self, input_name: str, u: Array, v: Array,
                     block: bool = False) -> Dict[str, Array]:
        """Fire the trigger for ``input_name += u @ v.T``."""
        fn = self._trigger_fns[input_name]
        t0 = time.perf_counter()
        self.views = fn(self.views, jnp.asarray(u), jnp.asarray(v))
        if block:
            jax.block_until_ready(self.views)
            self.stats.trigger_seconds += time.perf_counter() - t0
        self.stats.updates_applied += 1
        return self.views

    # -- baseline path ---------------------------------------------------------
    def reevaluate(self, block: bool = False) -> Dict[str, Array]:
        """The paper's re-evaluation strategy: recompute from the current
        inputs (which the triggers have been keeping up to date)."""
        inputs = {k: self.views[k] for k in self.program.inputs}
        t0 = time.perf_counter()
        computed = self._evaluator(inputs)
        if block:
            jax.block_until_ready(computed)
            self.stats.reeval_seconds += time.perf_counter() - t0
        self.views.update(computed)
        self.stats.reevals += 1
        return dict(computed)

    # -- introspection -----------------------------------------------------------
    def output(self, name: Optional[str] = None) -> Array:
        name = name or self.program.output_names()[0]
        return self.views[name]

    def trigger_flops(self, input_name: str) -> float:
        return trigger_flops(self.compiled.triggers[input_name], self.program,
                             self.binding)

    def reeval_flops(self) -> float:
        from .cost import expr_cost
        seen: Dict[int, bool] = {}
        from .cost import _expr_cost_shared
        return sum(_expr_cost_shared(s.expr, self.binding, seen).flops
                   for s in self.program.statements)


class ReevalEngine:
    """Pure re-evaluation baseline: applies the update to the input, then
    recomputes every view from scratch (paper's REEVAL strategy)."""

    def __init__(self, program: Program, jit: bool = True):
        self.program = program
        self.binding = dict(program.dims)
        self._evaluator = build_evaluator(program, self.binding, jit=jit)
        self.views: Dict[str, Array] = {}

    def initialize(self, inputs: Dict[str, Array]) -> Dict[str, Array]:
        computed = self._evaluator(dict(inputs))
        self.views = {**{k: jnp.asarray(v) for k, v in inputs.items()},
                      **computed}
        return dict(computed)

    def apply_update(self, input_name: str, u: Array, v: Array,
                     block: bool = False) -> Dict[str, Array]:
        self.views[input_name] = self.views[input_name] + u @ v.T
        inputs = {k: self.views[k] for k in self.program.inputs}
        computed = self._evaluator(inputs)
        if block:
            jax.block_until_ready(computed)
        self.views.update(computed)
        return self.views

    def output(self, name: Optional[str] = None) -> Array:
        name = name or self.program.output_names()[0]
        return self.views[name]


def max_abs_diff(a: Dict[str, Array], b: Dict[str, Array],
                 keys: Optional[Tuple[str, ...]] = None) -> float:
    keys = keys or tuple(set(a) & set(b))
    worst = 0.0
    for k in keys:
        worst = max(worst, float(jnp.max(jnp.abs(a[k] - b[k]))))
    return worst
