"""Cost model (paper §3, Table 2).

Counts FLOPs and bytes for symbolic expressions under a concrete dimension
binding.  Hash-consing makes the count CSE-aware: a shared subexpression is
priced once, the way the generated code evaluates it.

The paper states asymptotics with a matmul exponent γ (O(n^γ), §3); the
γ-form strings live only in the human-readable ``TABLE2`` report dict.
All decision-making FLOP counts fix γ = 3 — the classical 2·a·b·c — since
that is what BLAS/XLA executes (the paper makes the same practical
assumption).  See docs/cost_model.md for the function-by-function map to
the paper's cost expressions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from . import expr as ex
from .expr import Expr
from .factored import ColSlice, DenseDelta, HStack, LowRank


@dataclass(frozen=True)
class Cost:
    flops: float
    bytes_rw: float  # bytes read+written, 4 B/elt (f32 runtime)

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.flops + other.flops, self.bytes_rw + other.bytes_rw)

    @staticmethod
    def zero() -> "Cost":
        return Cost(0.0, 0.0)


ELT = 4.0  # bytes per element


def _dim(d, binding: Dict[str, int]) -> int:
    if isinstance(d, ex.Dim):
        return binding[d.name]
    return int(d)


def shape_of(e: Expr, binding: Dict[str, int]) -> Tuple[int, int]:
    return (_dim(e.shape[0], binding), _dim(e.shape[1], binding))


def expr_cost(e: Expr, binding: Dict[str, int]) -> Cost:
    """CSE-aware cost of evaluating ``e`` once."""
    seen: Dict[int, Cost] = {}

    def go(x: Expr) -> Cost:
        if id(x) in seen:
            return Cost.zero()  # shared node: already priced
        sub = Cost.zero()
        for c in x.children:
            sub = sub + go(c)
        mine = _node_cost(x, binding)
        seen[id(x)] = mine
        return sub + mine

    return go(e)


def _node_cost(x: Expr, binding) -> Cost:
    if isinstance(x, ex.MatMul):
        a, b = shape_of(x.lhs, binding)
        b2, c = shape_of(x.rhs, binding)
        assert b == b2, (x, b, b2)
        return Cost(2.0 * a * b * c, ELT * (a * b + b * c + a * c))
    if isinstance(x, ex.Add):
        n, m = shape_of(x, binding)
        t = len(x.terms)
        return Cost((t - 1) * n * m, ELT * t * n * m)
    if isinstance(x, ex.Scale):
        n, m = shape_of(x, binding)
        return Cost(n * m, ELT * 2 * n * m)
    if isinstance(x, ex.Transpose):
        n, m = shape_of(x, binding)
        return Cost(0.0, ELT * 2 * n * m)
    if isinstance(x, ex.Inverse):
        n, _ = shape_of(x, binding)
        if n == 1:
            return Cost(1.0, ELT * 2)
        return Cost((2.0 / 3.0) * n ** 3 + 2.0 * n ** 2, ELT * 2 * n * n)
    if isinstance(x, HStack):
        n, m = shape_of(x, binding)
        return Cost(0.0, ELT * 2 * n * m)
    if isinstance(x, ColSlice):
        n, _ = shape_of(x, binding)
        return Cost(0.0, ELT * 2 * n)
    # leaves
    return Cost.zero()


def expr_cost_kinds(e: Expr, binding: Dict[str, int]) -> Dict[str, float]:
    """CSE-aware FLOPs of ``e`` bucketed by op kind: ``"matmul"``,
    ``"inverse"``, ``"other"``.

    Wall-clock per FLOP differs wildly between kinds — a BLAS3 matmul
    streams at machine peak while an n×n factorization (``Inverse``) and
    elementwise traffic run far below it — so a planner comparing
    trigger FLOPs against re-evaluation FLOPs needs per-kind scales, not
    one global fudge factor (see
    :attr:`repro.plan.WorkloadDescriptor.op_cost_scales`).
    """
    kinds = {"matmul": 0.0, "inverse": 0.0, "other": 0.0}
    seen: Dict[int, bool] = {}
    stack = [e]
    while stack:
        x = stack.pop()
        if id(x) in seen:
            continue
        seen[id(x)] = True
        stack.extend(x.children)
        flops = _node_cost(x, binding).flops
        if isinstance(x, ex.MatMul):
            kinds["matmul"] += flops
        elif isinstance(x, ex.Inverse):
            kinds["inverse"] += flops
        else:
            kinds["other"] += flops
    return kinds


def lowrank_cost(d: LowRank, binding: Dict[str, int]) -> Cost:
    """Cost of evaluating every factor block of a factored delta."""
    total = Cost.zero()
    seen: Dict[int, bool] = {}
    for blk in list(d.left) + list(d.right):
        # share the CSE cache across blocks
        total = total + _expr_cost_shared(blk, binding, seen)
    return total


def _expr_cost_shared(e: Expr, binding, seen: Dict[int, bool]) -> Cost:
    total = Cost.zero()
    stack = [e]
    order = []
    while stack:
        x = stack.pop()
        if id(x) in seen:
            continue
        seen[id(x)] = True
        order.append(x)
        stack.extend(x.children)
    for x in order:
        total = total + _node_cost(x, binding)
    return total


def apply_update_cost(view_shape: Tuple[int, int], rank: int) -> Cost:
    """Cost of ``M += U Vᵀ`` (the rank-k GER): 2·k·n·m FLOPs, M touched twice."""
    n, m = view_shape
    return Cost(2.0 * rank * n * m, ELT * (2 * n * m + rank * (n + m)))


def dense_delta_cost(d: DenseDelta, binding: Dict[str, int]) -> Cost:
    return expr_cost(d.value, binding)


# ---------------------------------------------------------------------------
# batched-trigger cost model (§6 batching + §4.2 avalanche containment)
# ---------------------------------------------------------------------------


def batched_apply_cost(view_shape: Tuple[int, int], rank: int,
                       batch: int) -> Cost:
    """Cost of applying a T-batch of rank-k updates in ONE pass over M.

    FLOPs match T sequential GERs (2·T·k·n·m) but M crosses memory once,
    not T times — the batched kernel's roofline win.  Compare against
    ``apply_update_cost`` called T times to see the T× byte saving.
    """
    n, m = view_shape
    return Cost(2.0 * batch * rank * n * m,
                ELT * (2 * n * m + batch * rank * (n + m)))


def recompress_cost(n: int, m: int, stacked_rank: int) -> Cost:
    """Thin-QR both stacked factors + SVD of the (K × K) core.

    O((n + m)·K² + K³) — independent of the maintained views, so it pays
    whenever it shaves enough rank off every subsequent view sweep.
    """
    K = stacked_rank
    flops = 2.0 * (n + m) * K * K + 22.0 * K ** 3  # QR×2 + SVD + recombine
    return Cost(flops, ELT * (2 * (n + m) * K + 4 * K * K))


def batched_strategy(view_shape: Tuple[int, int], stacked_rank: int,
                     compressed_rank: int, reeval_flops: float) -> str:
    """Pick how to refresh one view under a stacked rank-K batch delta.

    Returns one of:
      * ``"stacked"``     — fire the rank-K batched trigger as-is;
      * ``"recompress"``  — QR/SVD the factors down to ``compressed_rank``
                            first (wins once K outgrows the numerical
                            rank: compaction is view-size independent);
      * ``"reeval"``      — recompute the view from scratch (wins past the
                            crossover rank, the paper's §7 regime where
                            INCR loses to REEVAL).
    """
    n, m = view_shape
    stacked = batched_apply_cost(view_shape, stacked_rank, 1).flops
    comp = (recompress_cost(n, m, stacked_rank).flops
            + batched_apply_cost(view_shape, compressed_rank, 1).flops)
    best, best_cost = "stacked", stacked
    if comp < best_cost:
        best, best_cost = "recompress", comp
    if reeval_flops < best_cost:
        best = "reeval"
    return best


def batch_crossover_rank(view_shape: Tuple[int, int],
                         reeval_flops: float) -> int:
    """Stacked rank beyond which re-evaluating the view beats the trigger.

    Solves ``2·K·n·m ≥ reeval_flops`` for K — the §7 crossover where the
    incremental strategy stops winning and the engine should fall back.
    """
    n, m = view_shape
    return max(1, int(reeval_flops / (2.0 * n * m)))


# ---------------------------------------------------------------------------
# row-local (sparsity-aware) carrier costs
# ---------------------------------------------------------------------------


def rowlocal_apply_cost(view_shape: Tuple[int, int], rank: int,
                        rows: int) -> Cost:
    """Cost of the row-slab GER: ``M[rows] += B Vᵀ`` touching ``rows``
    of the n rows.  FLOPs and M-traffic both scale with the affected
    row count — the §3 "local change" priced as data instead of
    structure.  The right factor still crosses memory whole."""
    n, m = view_shape
    r = min(int(rows), n)
    return Cost(2.0 * rank * r * m, ELT * (2 * r * m + rank * (r + m)))


def rowlocal_crossover_fraction(view_shape: Tuple[int, int], rank: int,
                                efficiency: float = 0.5) -> float:
    """Affected fraction below which the row-slab sweep beats the dense
    rank-k sweep.

    The slab path's gather/scatter runs at a discount (``efficiency``,
    wall-clock per byte relative to the dense kernel's streaming reads
    — slab DMA is strided and the index plan costs host time), so the
    crossover solves ``traffic_slab(r) = efficiency · traffic_dense``
    for ``r/n`` rather than the trivial ``r < n``.  Engines default
    their ``rowlocal_fraction`` below this (0.25) — the model is used
    by the planner to decide *strategy*, the engine bound to decide
    *kernel*.
    """
    n, m = view_shape
    k = max(1, int(rank))
    dense = 2.0 * n * m + k * (n + m)
    r_star = (efficiency * dense - k * m) / (2.0 * m + k)
    return min(1.0, max(0.0, r_star / max(n, 1)))


# ---------------------------------------------------------------------------
# normal-equation solver costs (repro.fivm: models over the maintained ring)
# ---------------------------------------------------------------------------


def cholesky_factor_cost(n: int) -> Cost:
    """Factoring ``A = L Lᵀ`` from scratch: n³/3 FLOPs over an (n, n)
    SPD matrix (the re-solve path of a ridge/OLS model whose gram view
    the ring maintains)."""
    return Cost(float(n) ** 3 / 3.0, ELT * 2.0 * n * n)


def cholesky_update_cost(n: int, rank: int) -> Cost:
    """Rank-``rank`` Cholesky update/downdate: ``rank`` rank-1 passes at
    ~2n² FLOPs each (Givens sweep over the triangle) — the incremental
    re-solve path, priced against :func:`cholesky_factor_cost` exactly
    like the §7 trigger-vs-reeval crossover."""
    return Cost(2.0 * max(1, int(rank)) * float(n) * n,
                ELT * (max(1, int(rank)) + 1.0) * n * n)


def triangular_solve_cost(n: int, p: int) -> Cost:
    """Two triangular solves ``L Lᵀ B = C`` for an (n, p) right-hand
    side (paid identically by both re-solve strategies, so it cancels
    out of the crossover but belongs in absolute refresh pricing)."""
    return Cost(2.0 * float(n) * n * max(1, int(p)),
                ELT * (n * n + 2.0 * n * max(1, int(p))))


def solver_crossover_rank(n: int) -> int:
    """Accumulated factor-update rank past which re-factoring beats
    rank-1 update/downdate sweeps: solves ``2·K·n² ≥ n³/3`` for K —
    the §7 crossover restated for the solver's triangular factor."""
    return max(1, int(n / 6))


# ---------------------------------------------------------------------------
# asymptotic (Table 2) reports — used for docs/EXPERIMENTS, not decisions
# ---------------------------------------------------------------------------

TABLE2 = {
    # (family, strategy, model) -> human-readable complexity
    ("powers", "reeval", "linear"): "n^γ·k",
    ("powers", "reeval", "exp"): "n^γ·log k",
    ("powers", "reeval", "skip"): "n^γ·(log s + k/s)",
    ("powers", "incr", "linear"): "n²·k²",
    ("powers", "incr", "exp"): "n²·k",
    ("powers", "incr", "skip"): "n²·k²/s",
    ("general", "reeval", "linear"): "p·n²·k",
    ("general", "reeval", "exp"): "(n^γ + p·n²)·log k",
    ("general", "incr", "linear"): "(n² + p·n)·k²",
    ("general", "incr", "exp"): "(n² + p·n)·k",
    ("general", "hybrid", "linear"): "p·n²·k",
    ("general", "hybrid", "exp"): "p·n²·log k + n²·k",
    ("general", "hybrid", "skip"): "p·n²·(log s + k/s) + n²·s",
}
