"""LINVIEW compiler (paper Alg. 1 + §6 optimizer).

``compile_program`` turns a :class:`Program` into one :class:`Trigger` per
dynamic input.  Each trigger is a straight-line list of factor-block
assignments followed by ``+=`` view updates — exactly the paper's trigger
shape (Example 4.6), with three optimizer passes:

1. **auxiliary-view extraction** — nested ``E⁻¹`` nodes are materialized as
   views so the Woodbury/Sherman–Morrison rule can reference their old
   value (§6 "the optimizer might define a number of auxiliary views");
2. **common-factor extraction** — inside the delta derivation
   (:func:`repro.core.factored.combine_blocks`);
3. **representation choice** — per statement, the factored (incremental)
   and single-matrix (hybrid, §5.3) delta representations are priced with
   the cost model and the cheaper one is materialized.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Sequence, Tuple

from . import expr as ex
from .cost import Cost, dense_delta_cost, expr_cost, lowrank_cost, shape_of
from .delta import (DeltaEnv, IncrementalInverseError, derive, derive_delta,
                    row_support_preserved)
from .expr import Expr, Var
from .factored import DeltaRep, DenseDelta, HStack, LowRank, _hstack
from .program import Program, Statement


@dataclass(frozen=True)
class Assign:
    """``name := expr`` inside a trigger body."""

    name: str
    expr: Expr


@dataclass(frozen=True)
class ViewUpdate:
    """``view += delta`` — factored (U·Vᵀ) or dense."""

    view: str
    kind: Literal["lowrank", "dense"]
    u: Optional[str] = None   # factored: U name
    v: Optional[str] = None   # factored: V name
    d: Optional[str] = None   # dense: delta name


@dataclass
class Trigger:
    """ON UPDATE <input> BY (U, V): <assigns>; <updates>."""

    input_name: str
    rank: int
    u_var: Var
    v_var: Var
    assigns: List[Assign] = field(default_factory=list)
    updates: List[ViewUpdate] = field(default_factory=list)
    cost: Cost = Cost.zero()
    reps: Dict[str, str] = field(default_factory=dict)  # view -> chosen rep
    # view -> carrier kind a row-local input update propagates to it:
    # "row_local" (delta's row support provably ⊆ the update's affected
    # rows — §4 closure, see repro.core.delta.row_support_preserved),
    # "low_rank" (factored but support widens), "dense" (hybrid rep).
    # The input's own += is always row-local.
    carriers: Dict[str, str] = field(default_factory=dict)

    def __repr__(self) -> str:
        lines = [f"ON UPDATE {self.input_name} BY ({self.u_var.name}, "
                 f"{self.v_var.name}):  # rank {self.rank}"]
        lines += [f"  {a.name} := {a.expr!r}" for a in self.assigns]
        for up in self.updates:
            if up.kind == "lowrank":
                lines.append(f"  {up.view} += {up.u} {up.v}^T")
            else:
                lines.append(f"  {up.view} += {up.d}")
        return "\n".join(lines)


def delta_view_name(view: str, depth: int) -> str:
    """Canonical name of the materialized ΔᵈV auxiliary view."""
    return f"__d{depth}__{view}"


@dataclass(frozen=True)
class DeltaView:
    """A materialized k-th order delta view ΔᵈV (auxiliary view, §6 /
    DBToaster's recursive delta hierarchy).

    ``rank`` is the factored rank of the Δᵈ representation at the compile
    update rank (0 for a dense rep); ``flops`` prices one evaluation of the
    rep's blocks — the trigger cost of maintaining the view.
    """

    name: str          # "__d{depth}__{view}"
    view: str          # the base view this is a delta of
    input_name: str
    depth: int
    kind: Literal["lowrank", "dense"]
    rank: int
    flops: float


@dataclass
class CompiledProgram:
    program: Program
    triggers: Dict[str, Trigger]
    # statements after the auxiliary-view pass (what the runtime evaluates)
    statements: List[Statement] = field(default_factory=list)
    # compile options, retained so batched triggers (compiled lazily per
    # batch-size bucket) share the same derivation choices
    force_rep: Optional[str] = None
    sequential_sm: bool = False
    # maximum delta depth derived at compile time (1 = classic first order)
    order: int = 1
    # (input, depth) -> {view -> DeltaView}: the ΔᵈV materialization
    # candidates registered when order >= 2 (absent views have Δᵈ ≡ 0)
    delta_views: Dict[Tuple[str, int], Dict[str, DeltaView]] = \
        field(default_factory=dict)
    # (input, depth) -> views whose Δᵈ derivation is unsupported (the
    # Woodbury capacitance inverse has no materialized view at depth >= 2)
    delta_unsupported: Dict[Tuple[str, int], Tuple[str, ...]] = \
        field(default_factory=dict)


# ---------------------------------------------------------------------------
# pass 1: auxiliary views for nested inverses
# ---------------------------------------------------------------------------


def extract_inverse_views(program: Program) -> Program:
    """Materialize every ``Inverse`` node as its own view.

    A statement ``W := E⁻¹`` already materializes the inverse; a nested
    inverse inside a larger expression is hoisted into ``__auxK := E⁻¹``
    and substituted, preserving program semantics.
    """
    counter = itertools.count()
    out = Program(name=program.name, inputs=dict(program.inputs),
                  outputs=list(program.outputs), dims=dict(program.dims))
    known: Dict[int, Var] = {}

    def hoist(e: Expr) -> Expr:
        if isinstance(e, ex.Inverse):
            inner = hoist(e.operand)
            node = ex.inverse(inner)
            if id(node) in known:
                return known[id(node)]
            aux = out.let(f"__aux{next(counter)}", node)
            known[id(node)] = aux
            return aux
        if isinstance(e, ex.MatMul):
            return ex.matmul(hoist(e.lhs), hoist(e.rhs))
        if isinstance(e, ex.Add):
            return ex.add(*[hoist(t) for t in e.terms])
        if isinstance(e, ex.Scale):
            return ex.scale(hoist(e.factor), hoist(e.operand))
        if isinstance(e, ex.Transpose):
            return ex.transpose(hoist(e.operand))
        return e

    for st in program.statements:
        if isinstance(st.expr, ex.Inverse):
            # top-level inverse: keep, but register as a known inverse view
            inner = hoist(st.expr.operand)
            node = ex.inverse(inner)
            v = out.let(st.target.name, node)
            known[id(node)] = v
        else:
            out.let(st.target.name, hoist(st.expr))
    return out


# ---------------------------------------------------------------------------
# pass 2+3: delta derivation + representation choice  (Alg. 1)
# ---------------------------------------------------------------------------


def compile_program(
    program: Program,
    update_ranks: Optional[Dict[str, int]] = None,
    *,
    force_rep: Optional[str] = None,      # "lowrank" | "dense" | None=cost-based
    sequential_sm: bool = False,          # paper-faithful SM chain vs Woodbury
    order: int = 1,                       # max delta depth to derive (>= 1)
) -> CompiledProgram:
    """Alg. 1: one trigger per dynamic input matrix.

    ``order >= 2`` additionally derives the ΔᵈV hierarchy per input for
    depths 2..order and registers each non-zero ΔᵈV as a first-class
    materialization candidate (:class:`DeltaView`); the depth-d trigger
    itself is compiled on demand by :func:`compile_delta_trigger`.  Views
    whose Δᵈ cannot be derived (the inverse error path) are recorded in
    ``delta_unsupported`` instead of failing the whole program.
    """
    if order < 1:
        raise ValueError(f"order must be >= 1, got {order}")
    program = extract_inverse_views(program)
    update_ranks = update_ranks or {name: 1 for name in program.inputs}
    binding = dict(program.dims)

    # views map for the inverse rule: expr-id -> var, for materialized views
    views: Dict[int, Expr] = {}
    for st in program.statements:
        views[id(st.expr)] = st.target

    triggers: Dict[str, Trigger] = {}
    for input_name, rank in update_ranks.items():
        if input_name not in program.inputs:
            raise KeyError(f"{input_name} is not an input of {program.name}")
        triggers[input_name] = _compile_trigger(
            program, input_name, rank, views, binding,
            force_rep=force_rep, sequential_sm=sequential_sm)
    compiled = CompiledProgram(program=program, triggers=triggers,
                               statements=list(program.statements),
                               force_rep=force_rep, sequential_sm=sequential_sm,
                               order=order)
    if order >= 2:
        for input_name, rank in update_ranks.items():
            _register_delta_views(compiled, input_name, rank, binding)
    return compiled


def _raw_delta_reps(program: Program, input_name: str, rank: int,
                    *, sequential_sm: bool):
    """Per-statement *raw* first-order reps with view deltas inlined.

    Unlike :func:`_compile_trigger`, downstream statements see the full
    factor expressions of upstream deltas (not renamed ``dU_V`` vars), so
    the result can be differentiated again by :func:`derive_delta`.
    """
    views: Dict[int, Expr] = {id(st.expr): st.target
                              for st in program.statements}
    x = program.inputs[input_name]
    u = ex.var(f"dU_{input_name}", (x.shape[0], rank))
    v = ex.var(f"dV_{input_name}", (x.shape[1], rank))
    env = DeltaEnv(views=views, sequential_sm=sequential_sm)
    env.deltas[input_name] = LowRank.outer(u, v)
    reps: Dict[str, DeltaRep] = {}
    for st in program.statements:
        d = derive(st.expr, env)
        if not d.is_zero():
            env.deltas[st.target.name] = d
        reps[st.target.name] = d
    return env, reps, u, v


def _register_delta_views(compiled: CompiledProgram, input_name: str,
                          rank: int, binding: Dict[str, int]) -> None:
    program = compiled.program
    env, reps, _, _ = _raw_delta_reps(
        program, input_name, rank, sequential_sm=compiled.sequential_sm)
    current: Dict[str, DeltaRep] = dict(reps)
    for depth in range(2, compiled.order + 1):
        registry: Dict[str, DeltaView] = {}
        unsupported: List[str] = []
        nxt: Dict[str, DeltaRep] = {}
        for st in program.statements:
            name = st.target.name
            d = current.get(name)
            if d is None or d.is_zero():
                continue
            try:
                dd = derive_delta(d, env)
            except IncrementalInverseError:
                unsupported.append(name)
                continue
            nxt[name] = dd
            if dd.is_zero():
                continue  # Δᵈ ≡ 0: hierarchy exhausted for this view
            if isinstance(dd, DenseDelta):
                kind, k = "dense", 0
                flops = expr_cost(dd.value, binding).flops
            else:
                kind, k = "lowrank", dd.rank
                flops = lowrank_cost(dd, binding).flops
            registry[name] = DeltaView(
                name=delta_view_name(name, depth), view=name,
                input_name=input_name, depth=depth, kind=kind,
                rank=k, flops=flops)
        compiled.delta_views[(input_name, depth)] = registry
        if unsupported:
            compiled.delta_unsupported[(input_name, depth)] = tuple(unsupported)
        current = nxt


def compile_delta_trigger(compiled: CompiledProgram, input_name: str,
                          depth: int, rank: Optional[int] = None) -> Trigger:
    """Compile the trigger maintaining the ΔᵈV views for one input.

    The trigger reads the *pre-update* base views plus the update factors
    (same ``dU_*``/``dV_*`` signature as the base trigger — every level of
    the diagonal hierarchy is driven by the same update) and writes the
    ``__d{depth}__V`` auxiliary views.  Raises
    :class:`IncrementalInverseError` if any view's Δᵈ is unsupported at
    this depth — the inverse error path is a hard error here because a
    partial hierarchy cannot be folded.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    program = compiled.program
    if input_name not in program.inputs:
        raise KeyError(f"{input_name} is not an input of {program.name}")
    if rank is None:
        rank = compiled.triggers[input_name].rank
    if depth == 1:
        return compile_batched_trigger(compiled, input_name, rank)
    env, reps, u, v = _raw_delta_reps(
        program, input_name, rank, sequential_sm=compiled.sequential_sm)
    binding = dict(program.dims)

    trig = Trigger(input_name=input_name, rank=rank, u_var=u, v_var=v)
    total = Cost.zero()
    for st in program.statements:
        name = st.target.name
        d = reps.get(name)
        if d is None or d.is_zero():
            continue
        try:
            for _ in range(depth - 1):
                d = derive_delta(d, env)
                if d.is_zero():
                    break
        except IncrementalInverseError as err:
            raise IncrementalInverseError(
                f"Δ^{depth} of view {name!r} is unsupported: {err}") from err
        if d.is_zero():
            continue
        dview = delta_view_name(name, depth)
        rep = _choose_rep(d, st, binding, compiled.force_rep)
        if rep == "dense" or isinstance(d, DenseDelta):
            dname = f"dD_{dview}"
            dexpr = d.value if isinstance(d, DenseDelta) else d.to_expr()
            trig.assigns.append(Assign(dname, dexpr))
            trig.updates.append(ViewUpdate(view=dview, kind="dense", d=dname))
            total = total + expr_cost(dexpr, binding)
            trig.reps[dview] = "dense"
        else:
            uname, vname = f"dU_{dview}", f"dV_{dview}"
            trig.assigns.append(Assign(uname, _hstack(d.left)))
            trig.assigns.append(Assign(vname, _hstack(d.right)))
            trig.updates.append(ViewUpdate(view=dview, kind="lowrank",
                                           u=uname, v=vname))
            total = total + lowrank_cost(d, binding)
            trig.reps[dview] = "lowrank"
    trig.cost = total
    return trig


# ---------------------------------------------------------------------------
# batched triggers (§6 batching, one trigger firing per T-update batch)
# ---------------------------------------------------------------------------


def batch_bucket(rank: int) -> int:
    """Static batch-size bucket: the next power of two ≥ rank.

    Stacked batch factors are zero-padded up to the bucket rank, so one
    jitted trigger per bucket serves every batch size in (bucket/2, bucket]
    and the jit cache stays warm across ragged batches.
    """
    if rank < 1:
        raise ValueError(f"rank must be ≥ 1, got {rank}")
    return 1 << (rank - 1).bit_length()


def compile_batched_trigger(compiled: CompiledProgram, input_name: str,
                            rank: int) -> Trigger:
    """Compile the trigger for a *stacked* batch of updates to one input.

    A batch of T rank-k updates {(U_t, V_t)} is the single factored update
    ``P Qᵀ`` with P = [U_1 … U_T], Q = [V_1 … V_T] (rank k·T), so the
    derivation is identical to the per-update trigger at the stacked rank —
    the entire batch flows through each maintained view in ONE pass.
    Representation choice re-runs per rank: wide batches flip skinny views
    to the dense/hybrid path exactly as §5.3 prescribes.
    """
    program = compiled.program  # already aux-extracted by compile_program
    if input_name not in program.inputs:
        raise KeyError(f"{input_name} is not an input of {program.name}")
    views: Dict[int, Expr] = {id(st.expr): st.target
                              for st in program.statements}
    return _compile_trigger(
        program, input_name, rank, views, dict(program.dims),
        force_rep=compiled.force_rep, sequential_sm=compiled.sequential_sm)


def _compile_trigger(program: Program, input_name: str, rank: int,
                     views: Dict[int, Expr], binding: Dict[str, int],
                     *, force_rep: Optional[str],
                     sequential_sm: bool) -> Trigger:
    x = program.inputs[input_name]
    u = ex.var(f"dU_{input_name}", (x.shape[0], rank))
    v = ex.var(f"dV_{input_name}", (x.shape[1], rank))

    env = DeltaEnv(views=views, sequential_sm=sequential_sm)
    env.deltas[input_name] = LowRank.outer(u, v)

    trig = Trigger(input_name=input_name, rank=rank, u_var=u, v_var=v)
    trig.updates.append(ViewUpdate(view=input_name, kind="lowrank",
                                   u=u.name, v=v.name))
    # carrier-kind propagation: which maintained views a row-local input
    # update reaches without leaving its affected rows.  The input's own
    # += trivially stays row-local; a view's does iff its left factor
    # expression is row-support-preserving over the already-preserving
    # factor vars (containment composes down the delta chain).
    trig.carriers[input_name] = "row_local"
    preserving = {u.name}
    total = Cost.zero()

    for st in program.statements:
        d = derive(st.expr, env)
        if isinstance(d, LowRank) and d.is_zero():
            continue
        rep = _choose_rep(d, st, binding, force_rep)
        if rep == "dense":
            dname = f"dD_{st.target.name}"
            dexpr = d.value if isinstance(d, DenseDelta) else d.to_expr()
            trig.assigns.append(Assign(dname, dexpr))
            trig.updates.append(ViewUpdate(view=st.target.name, kind="dense",
                                           d=dname))
            env.deltas[st.target.name] = DenseDelta(
                ex.var(dname, st.target.shape))
            total = total + expr_cost(dexpr, binding)
            trig.carriers[st.target.name] = "dense"
        else:
            lr = d if isinstance(d, LowRank) else _refactor_dense(d)
            uname = f"dU_{st.target.name}"
            vname = f"dV_{st.target.name}"
            uexpr = _hstack(lr.left)
            vexpr = _hstack(lr.right)
            trig.assigns.append(Assign(uname, uexpr))
            trig.assigns.append(Assign(vname, vexpr))
            trig.updates.append(ViewUpdate(view=st.target.name,
                                           kind="lowrank", u=uname, v=vname))
            k = lr.rank
            env.deltas[st.target.name] = LowRank.outer(
                ex.var(uname, (st.target.shape[0], k)),
                ex.var(vname, (st.target.shape[1], k)))
            total = total + lowrank_cost(lr, binding)
            if row_support_preserved(uexpr, preserving):
                trig.carriers[st.target.name] = "row_local"
                preserving.add(uname)
            else:
                trig.carriers[st.target.name] = "low_rank"
        trig.reps[st.target.name] = rep
    trig.cost = total
    return trig


def _refactor_dense(d: DenseDelta) -> LowRank:
    raise NotImplementedError(
        "a dense delta cannot be re-factored without value inspection "
        "(paper §4.3); once a statement goes hybrid, downstream statements "
        "must either stay dense or be cost-priced as dense")


def _choose_rep(d: DeltaRep, st: Statement, binding: Dict[str, int],
                force_rep: Optional[str]) -> str:
    """Representation choice (§5.3 hybrid evaluation).

    The factored form wins when rank ≪ min(n, m); when the view itself is
    skinny (p comparable to the rank, e.g. p = 1 in the paper's extreme),
    a single dense delta is cheaper.  We price both and pick.
    """
    if isinstance(d, DenseDelta):
        return "dense"
    if force_rep is not None:
        return force_rep
    n, m = shape_of(st.target, binding)
    if d.rank >= min(n, m):
        return "dense"
    fact = lowrank_cost(d, binding).flops
    dense = expr_cost(d.to_expr(), binding).flops
    # materializing U,V then applying U Vᵀ touches the view once more than
    # the dense path; fold the apply cost into the comparison.
    fact += 2.0 * d.rank * n * m
    dense += 2.0 * n * m
    return "lowrank" if fact <= dense else "dense"
