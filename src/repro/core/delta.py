"""Delta derivation (paper §4.1) over the symbolic IR.

``derive(E, env)`` computes the total delta of ``E`` under *simultaneous*
factored updates of the variables named in ``env``.  The product rule

    Δ(E1·E2) = ΔE1·E2 + E1·ΔE2 + ΔE1·ΔE2

is exact for simultaneous multi-variable updates when ``ΔEi`` is the total
delta of ``Ei`` — the paper's sequential multi-update rule (Example 4.5)
expands to the same expression, so a single recursive pass suffices.

All variables in the produced expressions denote *pre-update* values, which
matches trigger semantics: every factor block is evaluated first, the
``+=`` updates are applied last (Alg. 1 / Example 4.6).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from . import expr as ex
from .expr import Expr
from .factored import (DeltaRep, DenseDelta, LowRank, lowrank_add,
                       lowrank_inverse_woodbury, lowrank_matmul)


@dataclass
class DeltaEnv:
    """Maps var name → its delta representation.

    ``views`` maps an expression (by interned id) to the Var materializing
    it — the inverse rule needs the *old value* of ``E⁻¹`` and may only be
    applied when that inverse is materialized as a view (the compiler's
    auxiliary-view pass guarantees this).
    """

    deltas: Dict[str, DeltaRep] = field(default_factory=dict)
    views: Dict[int, Expr] = field(default_factory=dict)
    sequential_sm: bool = False  # paper-faithful rank-1 SM chain vs Woodbury

    def delta_of(self, name: str) -> Optional[DeltaRep]:
        return self.deltas.get(name)

    def view_for(self, e: Expr) -> Optional[Expr]:
        return self.views.get(id(e))


def is_static(e: Expr, env: DeltaEnv) -> bool:
    """True if no variable of ``e`` has a registered delta."""
    return not any(v in env.deltas for v in e.free_vars())


def derive(e: Expr, env: DeltaEnv) -> DeltaRep:
    """Total delta of ``e`` under the updates in ``env``."""
    d = _derive(e, env, {})
    return d


def _derive(e: Expr, env: DeltaEnv, cache: Dict[int, DeltaRep]) -> DeltaRep:
    hit = cache.get(id(e))
    if hit is not None:
        return hit
    out = _derive_impl(e, env, cache)
    cache[id(e)] = out
    return out


def _derive_impl(e: Expr, env: DeltaEnv, cache) -> DeltaRep:
    if isinstance(e, ex.Var):
        d = env.delta_of(e.name)
        return d if d is not None else LowRank.zero()

    if isinstance(e, (ex.Zero, ex.Identity, ex.Const)):
        return LowRank.zero()

    if isinstance(e, ex.Add):
        parts = [_derive(t, env, cache) for t in e.terms]
        if any(isinstance(p, DenseDelta) for p in parts):
            vals = [_as_dense(p, t.shape) for p, t in zip(parts, e.terms)]
            return DenseDelta(ex.add(*vals))
        return lowrank_add(*parts)

    if isinstance(e, ex.Scale):
        if not is_static(e.factor, env):
            # scalar factor with its own delta: treat as (1×1) product rule
            return _derive_scalar_product(e, env, cache)
        d = _derive(e.operand, env, cache)
        return d.scale(e.factor) if not d.is_zero() else d

    if isinstance(e, ex.Transpose):
        d = _derive(e.operand, env, cache)
        return d.transpose() if not d.is_zero() else d

    if isinstance(e, ex.MatMul):
        d1 = _derive(e.lhs, env, cache)
        d2 = _derive(e.rhs, env, cache)
        if d1.is_zero() and d2.is_zero():
            return LowRank.zero()
        if isinstance(d1, DenseDelta) or isinstance(d2, DenseDelta):
            return _dense_matmul_rule(e, d1, d2)
        return lowrank_matmul(d1, e.lhs, d2, e.rhs)

    if isinstance(e, ex.Inverse):
        d = _derive(e.operand, env, cache)
        if d.is_zero():
            return LowRank.zero()
        view = env.view_for(e)
        if view is None:
            raise IncrementalInverseError(
                f"inverse {e!r} is affected by updates but not materialized "
                f"as a view; run the auxiliary-view pass first")
        if isinstance(d, DenseDelta):
            # no factored structure to exploit: Δ(E⁻¹) = (E+ΔE)⁻¹ − E⁻¹
            new_op = ex.add(e.operand, d.value)
            return DenseDelta(ex.sub(ex.inverse(new_op), view))
        return lowrank_inverse_woodbury(view, d, sequential=env.sequential_sm)

    raise TypeError(f"no delta rule for {type(e).__name__}")


class IncrementalInverseError(RuntimeError):
    pass


def _as_dense(d: DeltaRep, shape) -> Expr:
    if isinstance(d, DenseDelta):
        return d.value
    if d.is_zero():
        return ex.zero(shape)
    return d.to_expr()


def _dense_matmul_rule(e: ex.MatMul, d1: DeltaRep, d2: DeltaRep) -> DenseDelta:
    """Hybrid product rule: keep the result as one matrix, but evaluate any
    factored operand in its cheap (skinny-first) association."""
    terms = []
    if not d1.is_zero():
        if isinstance(d1, LowRank):
            # (P1 Q1ᵀ) E2  →  P1 (E2ᵀ Q1)ᵀ — still O(k·n²)
            terms.extend(ex.matmul(l, ex.transpose(ex.matmul(ex.transpose(e.rhs), r)))
                         for l, r in zip(d1.left, d1.right))
        else:
            terms.append(ex.matmul(d1.value, e.rhs))
    if not d2.is_zero():
        if isinstance(d2, LowRank):
            terms.extend(ex.matmul(ex.matmul(e.lhs, l), ex.transpose(r))
                         for l, r in zip(d2.left, d2.right))
        else:
            terms.append(ex.matmul(e.lhs, d2.value))
    if not d1.is_zero() and not d2.is_zero():
        a = _as_dense(d1, e.lhs.shape)
        b = _as_dense(d2, e.rhs.shape)
        terms.append(ex.matmul(a, b))
    return DenseDelta(ex.add(*terms))


def _derive_scalar_product(e: ex.Scale, env: DeltaEnv, cache) -> DeltaRep:
    """Δ(λ·E) when the scalar λ itself changes: product rule on (1×1)·E.

    λ is (1,1) so Δλ is rank ≤ 1; the result stays factored if ΔE does.
    """
    dl = _derive(e.factor, env, cache)
    dE = _derive(e.operand, env, cache)
    lam = e.factor
    terms = []
    # Δλ · E  — dense rank equal to rank(E); represent dense
    if not dl.is_zero():
        dl_expr = _as_dense(dl, (1, 1))
        terms.append(ex.scale(dl_expr, e.operand))
        if not dE.is_zero():
            terms.append(ex.scale(dl_expr, _as_dense(dE, e.operand.shape)))
    if not dE.is_zero():
        terms.append(ex.scale(lam, _as_dense(dE, e.operand.shape)))
    if not terms:
        return LowRank.zero()
    return DenseDelta(ex.add(*terms))
