"""Delta derivation (paper §4.1) over the symbolic IR.

``derive(E, env)`` computes the total delta of ``E`` under *simultaneous*
factored updates of the variables named in ``env``.  The product rule

    Δ(E1·E2) = ΔE1·E2 + E1·ΔE2 + ΔE1·ΔE2

is exact for simultaneous multi-variable updates when ``ΔEi`` is the total
delta of ``Ei`` — the paper's sequential multi-update rule (Example 4.5)
expands to the same expression, so a single recursive pass suffices.

All variables in the produced expressions denote *pre-update* values, which
matches trigger semantics: every factor block is evaluated first, the
``+=`` updates are applied last (Alg. 1 / Example 4.6).

``derive(E, env, order=k)`` with ``k ≥ 2`` produces the k-th order delta
(delta-of-delta, DBToaster arXiv 1207.0137): Δ applied recursively to
the Δᵏ⁻¹ representation.  For a polynomial program of degree d the
hierarchy terminates — ``Δ^(d+1) E ≡ 0`` — and each level's blocks read
*less* of the base views than the last (Δ² of a quadratic reads none),
which is exactly why materializing the hierarchy makes triggers
asymptotically cheaper.  The inverse (Woodbury) rule does not extend
past first order without materializing the capacitance inverse, so
deriving through it raises :class:`IncrementalInverseError` — the
compiler records such views as unsupported at depth ≥ 2.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from . import expr as ex
from .expr import Expr
from .factored import (ColSlice, DeltaRep, DenseDelta, HStack, LowRank,
                       lowrank_add, lowrank_inverse_woodbury, lowrank_matmul)


@dataclass
class DeltaEnv:
    """Maps var name → its delta representation.

    ``views`` maps an expression (by interned id) to the Var materializing
    it — the inverse rule needs the *old value* of ``E⁻¹`` and may only be
    applied when that inverse is materialized as a view (the compiler's
    auxiliary-view pass guarantees this).
    """

    deltas: Dict[str, DeltaRep] = field(default_factory=dict)
    views: Dict[int, Expr] = field(default_factory=dict)
    sequential_sm: bool = False  # paper-faithful rank-1 SM chain vs Woodbury

    def delta_of(self, name: str) -> Optional[DeltaRep]:
        return self.deltas.get(name)

    def view_for(self, e: Expr) -> Optional[Expr]:
        return self.views.get(id(e))


def is_static(e: Expr, env: DeltaEnv) -> bool:
    """True if no variable of ``e`` has a registered delta."""
    return not any(v in env.deltas for v in e.free_vars())


def derive(e: Expr, env: DeltaEnv, order: int = 1,
           steps: Optional[list] = None) -> DeltaRep:
    """Total delta of ``e`` under the updates in ``env``.

    ``order`` selects the delta depth.  ``order <= 1`` (including the
    degenerate ``order=0``) is the classic first-order total delta and is
    bit-identical to the pre-existing behavior.  ``order=k`` applies Δ
    recursively ``k`` times; by default every level differentiates w.r.t.
    the *same* update symbols (the diagonal Δᵏ E(A; d, …, d), which is what
    a materialized ΔᵏV view maintains).  ``steps`` optionally supplies a
    distinct :class:`DeltaEnv` per extra level for mixed-update algebra
    tests: ``len(steps) == order - 1``.
    """
    if order < 0:
        raise ValueError(f"delta order must be >= 0, got {order}")
    d = _derive(e, env, {})
    if order <= 1:
        return d
    envs = list(steps) if steps is not None else [env] * (order - 1)
    if len(envs) != order - 1:
        raise ValueError(
            f"steps must supply {order - 1} environments, got {len(envs)}")
    for env_j in envs:
        if d.is_zero():
            return LowRank.zero()
        d = derive_delta(d, env_j)
    return d


def derive_delta(d: DeltaRep, env: DeltaEnv) -> DeltaRep:
    """Δ of a delta *representation* — one level of delta-of-delta.

    A factored rep Σᵢ lᵢ·rᵢᵀ is differentiated blockwise with the product
    rule Δ(l·rᵀ) = Δl·rᵀ + l·Δrᵀ + Δl·Δrᵀ; a dense rep falls back to the
    expression-level rules.  The update symbols themselves (``dU_*`` /
    ``dV_*`` vars) carry no registered delta, so they are constants at the
    next level — exactly DBToaster's Δ-hierarchy semantics.
    """
    if isinstance(d, DenseDelta):
        return _derive(d.value, env, {})
    if d.is_zero():
        return LowRank.zero()
    cache: Dict[int, DeltaRep] = {}
    parts = []
    for l, r in zip(d.left, d.right):
        dl = _derive(l, env, cache)
        dr = _derive(r, env, cache)
        if dl.is_zero() and dr.is_zero():
            continue
        rt = ex.transpose(r)
        drt = dr if dr.is_zero() else dr.transpose()
        if isinstance(dl, DenseDelta) or isinstance(drt, DenseDelta):
            parts.append(_dense_matmul_rule_on(l, rt, dl, drt))
        else:
            parts.append(lowrank_matmul(dl, l, drt, rt))
    if not parts:
        return LowRank.zero()
    if any(isinstance(p, DenseDelta) for p in parts):
        shape = d.shape
        return DenseDelta(ex.add(*[_as_dense(p, shape) for p in parts]))
    return lowrank_add(*parts)


def _derive(e: Expr, env: DeltaEnv, cache: Dict[int, DeltaRep]) -> DeltaRep:
    hit = cache.get(id(e))
    if hit is not None:
        return hit
    out = _derive_impl(e, env, cache)
    cache[id(e)] = out
    return out


def _derive_impl(e: Expr, env: DeltaEnv, cache) -> DeltaRep:
    if isinstance(e, ex.Var):
        d = env.delta_of(e.name)
        return d if d is not None else LowRank.zero()

    if isinstance(e, (ex.Zero, ex.Identity, ex.Const)):
        return LowRank.zero()

    if isinstance(e, ex.Add):
        parts = [_derive(t, env, cache) for t in e.terms]
        if any(isinstance(p, DenseDelta) for p in parts):
            vals = [_as_dense(p, t.shape) for p, t in zip(parts, e.terms)]
            return DenseDelta(ex.add(*vals))
        return lowrank_add(*parts)

    if isinstance(e, ex.Scale):
        if not is_static(e.factor, env):
            # scalar factor with its own delta: treat as (1×1) product rule
            return _derive_scalar_product(e, env, cache)
        d = _derive(e.operand, env, cache)
        return d.scale(e.factor) if not d.is_zero() else d

    if isinstance(e, ex.Transpose):
        d = _derive(e.operand, env, cache)
        return d.transpose() if not d.is_zero() else d

    if isinstance(e, ex.MatMul):
        d1 = _derive(e.lhs, env, cache)
        d2 = _derive(e.rhs, env, cache)
        if d1.is_zero() and d2.is_zero():
            return LowRank.zero()
        if isinstance(d1, DenseDelta) or isinstance(d2, DenseDelta):
            return _dense_matmul_rule(e, d1, d2)
        return lowrank_matmul(d1, e.lhs, d2, e.rhs)

    if isinstance(e, ex.Inverse):
        d = _derive(e.operand, env, cache)
        if d.is_zero():
            return LowRank.zero()
        view = env.view_for(e)
        if view is None:
            raise IncrementalInverseError(
                f"inverse {e!r} is affected by updates but not materialized "
                f"as a view; run the auxiliary-view pass first")
        if isinstance(d, DenseDelta):
            # no factored structure to exploit: Δ(E⁻¹) = (E+ΔE)⁻¹ − E⁻¹
            new_op = ex.add(e.operand, d.value)
            return DenseDelta(ex.sub(ex.inverse(new_op), view))
        return lowrank_inverse_woodbury(view, d, sequential=env.sequential_sm)

    if isinstance(e, (HStack, ColSlice)):
        # these nodes exist only inside Woodbury / Sherman–Morrison
        # first-order reps; meeting one here means Δ is being applied
        # *through* an inverse rule, which does not extend past first
        # order without materializing the capacitance inverse
        if is_static(e, env):
            return LowRank.zero()
        raise IncrementalInverseError(
            f"Δ through a Woodbury/SM block operand "
            f"({type(e).__name__}) is unsupported: the inverse rule "
            f"does not extend past first order")

    raise TypeError(f"no delta rule for {type(e).__name__}")


class IncrementalInverseError(RuntimeError):
    pass


def _as_dense(d: DeltaRep, shape) -> Expr:
    if isinstance(d, DenseDelta):
        return d.value
    if d.is_zero():
        return ex.zero(shape)
    return d.to_expr()


def _dense_matmul_rule(e: ex.MatMul, d1: DeltaRep, d2: DeltaRep) -> DenseDelta:
    return _dense_matmul_rule_on(e.lhs, e.rhs, d1, d2)


def _dense_matmul_rule_on(lhs: Expr, rhs: Expr,
                          d1: DeltaRep, d2: DeltaRep) -> DenseDelta:
    """Hybrid product rule: keep the result as one matrix, but evaluate any
    factored operand in its cheap (skinny-first) association."""
    terms = []
    if not d1.is_zero():
        if isinstance(d1, LowRank):
            # (P1 Q1ᵀ) E2  →  P1 (E2ᵀ Q1)ᵀ — still O(k·n²)
            terms.extend(ex.matmul(l, ex.transpose(ex.matmul(ex.transpose(rhs), r)))
                         for l, r in zip(d1.left, d1.right))
        else:
            terms.append(ex.matmul(d1.value, rhs))
    if not d2.is_zero():
        if isinstance(d2, LowRank):
            terms.extend(ex.matmul(ex.matmul(lhs, l), ex.transpose(r))
                         for l, r in zip(d2.left, d2.right))
        else:
            terms.append(ex.matmul(lhs, d2.value))
    if not d1.is_zero() and not d2.is_zero():
        a = _as_dense(d1, lhs.shape)
        b = _as_dense(d2, rhs.shape)
        terms.append(ex.matmul(a, b))
    return DenseDelta(ex.add(*terms))


def _derive_scalar_product(e: ex.Scale, env: DeltaEnv, cache) -> DeltaRep:
    """Δ(λ·E) when the scalar λ itself changes: product rule on (1×1)·E.

    λ is (1,1) so Δλ is rank ≤ 1; the result stays factored if ΔE does.
    """
    dl = _derive(e.factor, env, cache)
    dE = _derive(e.operand, env, cache)
    lam = e.factor
    terms = []
    # Δλ · E  — dense rank equal to rank(E); represent dense
    if not dl.is_zero():
        dl_expr = _as_dense(dl, (1, 1))
        terms.append(ex.scale(dl_expr, e.operand))
        if not dE.is_zero():
            terms.append(ex.scale(dl_expr, _as_dense(dE, e.operand.shape)))
    if not dE.is_zero():
        terms.append(ex.scale(lam, _as_dense(dE, e.operand.shape)))
    if not terms:
        return LowRank.zero()
    return DenseDelta(ex.add(*terms))


# ---------------------------------------------------------------------------
# row-support closure analysis (sparsity-aware carriers, §3–§5)
# ---------------------------------------------------------------------------


def row_support_preserved(e: Expr, u_names) -> bool:
    """Whether ``e``'s row support is contained in the update's rows.

    ``e`` is a compiled trigger's left factor-block expression;
    ``u_names`` the set of factor Vars already known row-contained (the
    input's own ``dU_…`` plus any upstream view factor the compiler has
    proved preserving — containment composes down the chain).  The §4
    delta rules preserve row-locality under exactly these constructors:

      * the update factor itself (``ΔA`` rows ARE the affected rows);
      * ``Zero`` (empty support is contained in anything);
      * ``Scale`` — any scalar factor, row support untouched;
      * ``MatMul`` with a preserving *left* operand — right-
        multiplication mixes columns, never rows (this is the
        ``ΔE1 · E2`` term of the product rule and every capacitance
        chain hanging off it);
      * ``Add`` / ``HStack`` / ``ColSlice`` of preserving parts.

    Everything else widens: a ``Transpose`` moves the support to the
    columns, an ``Inverse`` (Woodbury capacitance) is dense in general,
    and any view/const/other-var leaf carries its own full support —
    that includes the ``E1 · ΔE2`` product-rule term, whose left operand
    is a base view.  Sound but conservative: a ``False`` only costs the
    dense sweep we run today.
    """
    if isinstance(u_names, str):
        u_names = {u_names}
    if isinstance(e, ex.Var):
        return e.name in u_names
    if isinstance(e, ex.Zero):
        return True
    if isinstance(e, ex.Scale):
        return row_support_preserved(e.operand, u_names)
    if isinstance(e, ex.MatMul):
        return row_support_preserved(e.lhs, u_names)
    if isinstance(e, ex.Add):
        return all(row_support_preserved(t, u_names) for t in e.terms)
    if isinstance(e, HStack):
        return all(row_support_preserved(b, u_names) for b in e.blocks)
    if isinstance(e, ColSlice):
        return row_support_preserved(e.operand, u_names)
    return False
