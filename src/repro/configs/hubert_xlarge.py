"""hubert-xlarge — encoder-only audio transformer (w2v2 architecture).

[arXiv:2106.07447; unverified]  48L d_model=1280 16H (kv=16, head_dim 80)
d_ff=5120 vocab=504 (masked-prediction cluster codebook).  The conv
feature extractor is a STUB: ``input_specs()`` provides precomputed frame
embeddings (batch, frames, 512) that a linear feature projection maps to
d_model.  HuBERT's conv relative positional embedding is replaced by RoPE
(TPU adaptation, noted in DESIGN.md).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    qkv_bias=True,
    mlp_gated=False,
    encoder_only=True,
    frontend_dim=512,
    source="arXiv:2106.07447",
)
