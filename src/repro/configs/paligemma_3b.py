"""paligemma-3b — SigLIP vision prefix + Gemma-2B decoder.

[arXiv:2407.07726; hf]  LM backbone: 18L d_model=2048 8H (MQA kv=1,
head_dim 256) d_ff=16384 (GeGLU) vocab=257216.  The SigLIP frontend is a
STUB: ``input_specs()`` provides precomputed patch embeddings
(batch, 256, 1152); a linear multimodal projector maps them to d_model.
Prefix-LM attention: bidirectional over the image prefix, causal after.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    mlp_gated=True,
    tie_embeddings=True,
    prefix_vision=True,
    n_patches=256,
    frontend_dim=1152,
    source="arXiv:2407.07726",
)
