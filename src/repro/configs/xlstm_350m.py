"""xlstm-350m — sLSTM + mLSTM blocks (xLSTM[7:1] pattern).

[arXiv:2405.04517; unverified]  24L d_model=1024 4H d_ff=0 vocab=50304.
Block pattern: 7 mLSTM blocks then 1 sLSTM block, repeated (24 = 3×8).
"""

from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4, mlstm_per_slstm=7,
                      chunk=128),
    source="arXiv:2405.04517",
)
