"""zamba2-1.2b — Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]  38 Mamba2 layers, d_model=2048; one *shared*
transformer block (32H full attention + d_ff=8192 MLP) applied every 6
Mamba2 blocks with the same weights each time; ssm_state=64.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    mlp_gated=True,
    attn_every=6,            # shared attn block cadence
    ssm=SSMConfig(state=64, headdim=64, expand=2, conv_kernel=4, chunk=128),
    source="arXiv:2411.15242",
)
