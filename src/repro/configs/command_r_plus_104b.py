"""command-r-plus-104b — dense GQA giant, no biases, tied embeddings.

[hf:CohereForAI/c4ai-command-r-plus; unverified]  64L d_model=12288 96H
(GQA kv=8, head_dim 128) d_ff=33792 vocab=256000.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    qkv_bias=False,
    mlp_gated=True,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-plus",
)
