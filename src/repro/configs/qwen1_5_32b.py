"""qwen1.5-32b — dense MHA (kv = heads) with QKV bias.

[hf:Qwen/Qwen1.5-32B; hf]  64L d_model=5120 40H (kv=40, head_dim 128)
d_ff=27392 vocab=152064.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    mlp_gated=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-32B",
)
