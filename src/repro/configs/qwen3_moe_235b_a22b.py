"""qwen3-moe-235b-a22b — 128 routed experts, top-8.

[hf:Qwen/Qwen3-30B-A3B family; hf]  94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    qkv_bias=False,
    mlp_gated=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=8,
        n_shared_experts=0,
        expert_d_ff=1536,
        capacity_factor=1.25,
    ),
    source="hf:Qwen/Qwen3-235B-A22B",
)
