"""Config system: model architecture + parallelism + run settings.

One ``<arch>.py`` per assigned architecture instantiates :class:`ModelConfig`
with the exact published numbers; ``reduced()`` derives the CPU smoke-test
variant (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    expert_d_ff: int = 0          # routed expert hidden size
    shared_d_ff: int = 0          # shared expert hidden size (total)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    state: int = 64              # N: SSM state size
    headdim: int = 64            # P: channels per SSD head
    expand: int = 2              # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 128             # SSD chunk length


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0     # mLSTM up-projection
    conv_kernel: int = 4
    mlstm_per_slstm: int = 7     # block pattern [m×7, s]×…
    chunk: int = 128
    slstm_proj_factor: float = 1.333
    slstm_unroll: int = 1        # time-scan unroll (wgrad RMW batching)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    mlp_gated: bool = True                  # SwiGLU vs plain GeLU MLP
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # SWA (h2o-danube)
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 0                     # zamba2: shared attn cadence
    encoder_only: bool = False              # hubert
    prefix_vision: bool = False             # paligemma: image-prefix LM
    n_patches: int = 256                    # vlm stub: patches per image
    frontend_dim: int = 0                   # audio/vlm stub input dim
    max_seq: int = 32768
    dtype: str = "bfloat16"
    fsdp: bool = True                       # shard params over data axis too
    remat: str = "block"                    # none | block | full
    # citation / provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab
        total = v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        hd = self.resolved_head_dim
        for _ in range(self.n_layers):
            # attention (per layer, where applicable)
            if self.family not in ("ssm",):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            if self.moe:
                e = self.moe
                total += e.n_experts * (3 if self.mlp_gated else 2) * d * e.expert_d_ff
                total += d * e.n_experts  # router
                if e.n_shared_experts:
                    total += (3 if self.mlp_gated else 2) * d * e.shared_d_ff
            elif self.d_ff > 0:
                total += (3 if self.mlp_gated else 2) * d * self.d_ff
            if self.ssm and self.family in ("ssm", "hybrid"):
                di = self.ssm.expand * d
                total += d * 2 * di + di * d  # in/out projections
                total += di * 2 * self.ssm.state  # B, C projections (approx)
            total += 2 * d  # norms
        if self.xlstm:
            per = self.xlstm.mlstm_per_slstm
            groups = self.n_layers // (per + 1)
            di = int(self.xlstm.proj_factor * d)
            hd = di // self.n_heads
            mlstm = (2 * d * di                       # up_l, up_r
                     + self.xlstm.conv_kernel * di    # conv
                     + 3 * self.n_heads * hd * hd     # headwise q,k,v
                     + 2 * di * self.n_heads          # gates
                     + di * d)                        # down
            d_up = int(self.xlstm.slstm_proj_factor * d)
            hd_s = d // self.n_heads
            slstm = (4 * d * d + 4 * self.n_heads * hd_s * hd_s
                     + 2 * d * d_up + d_up * d)
            total += groups * (per * mlstm + slstm)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        e = self.moe
        per_expert = (3 if self.mlp_gated else 2) * d * e.expert_d_ff
        inactive = self.n_layers * (e.n_experts - e.top_k) * per_expert
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: Dict = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else
                         max(2, self.attn_every + 1)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=256 if self.d_ff > 0 else 0,
            vocab=512,
            head_dim=32,
            max_seq=256,
            dtype="float32",
            fsdp=False,
            remat="none",
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(2, self.moe.top_k),
                expert_d_ff=128,
                shared_d_ff=128 if self.moe.n_shared_experts else 0)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, state=16, headdim=32,
                                            chunk=32)
        if self.xlstm:
            kw["xlstm"] = dataclasses.replace(self.xlstm, chunk=32,
                                              mlstm_per_slstm=3)
            kw["n_layers"] = 4
        if self.attn_every:
            kw["attn_every"] = 2
            kw["n_layers"] = 5
        if self.prefix_vision:
            kw["n_patches"] = 16
            kw["frontend_dim"] = 128
        if self.frontend_dim and not self.prefix_vision:
            kw["frontend_dim"] = 128
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# assigned input shapes (same 4 for every LM arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §6)."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.sliding_window is not None)
        if not sub_quadratic:
            return False, "pure full-attention arch: long_500k skipped"
    return True, ""
