"""Architecture registry: ``get_config("<arch-id>")`` for the 10 assigned
architectures (plus paper-native analytics configs live in repro.apps)."""

from typing import Dict, List

from .base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig, ShapeConfig, \
    SHAPES, shape_applicable

from .qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from .qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from .zamba2_1_2b import CONFIG as _zamba2
from .xlstm_350m import CONFIG as _xlstm
from .paligemma_3b import CONFIG as _paligemma
from .command_r_plus_104b import CONFIG as _command_r
from .h2o_danube_1_8b import CONFIG as _danube
from .starcoder2_7b import CONFIG as _starcoder2
from .qwen1_5_32b import CONFIG as _qwen15_32b
from .hubert_xlarge import CONFIG as _hubert

ARCHS: Dict[str, ModelConfig] = {
    "qwen2-moe-a2.7b": _qwen2_moe,
    "qwen3-moe-235b-a22b": _qwen3_moe,
    "zamba2-1.2b": _zamba2,
    "xlstm-350m": _xlstm,
    "paligemma-3b": _paligemma,
    "command-r-plus-104b": _command_r,
    "h2o-danube-1.8b": _danube,
    "starcoder2-7b": _starcoder2,
    "qwen1.5-32b": _qwen15_32b,
    "hubert-xlarge": _hubert,
}


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")


def list_archs() -> List[str]:
    return sorted(ARCHS)


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "XLSTMConfig",
           "ShapeConfig", "SHAPES", "shape_applicable", "ARCHS",
           "get_config", "list_archs"]
