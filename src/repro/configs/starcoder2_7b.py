"""starcoder2-7b — GQA + RoPE code model, non-gated GeLU MLP, biases.

[arXiv:2402.19173; hf]  32L d_model=4608 36H (GQA kv=4, head_dim 128)
d_ff=18432 vocab=49152.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    qkv_bias=True,
    mlp_gated=False,
    rope_theta=1_000_000.0,
    source="arXiv:2402.19173",
)
