"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 MoE.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (GQA kv=16)
d_ff=1408 (routed expert) vocab=151936, shared expert hidden 4×1408=5632.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    mlp_gated=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        n_shared_experts=4,
        expert_d_ff=1408,
        shared_d_ff=5632,
        capacity_factor=1.25,
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
