"""LM assembly: one :class:`LM` covering all 10 assigned architectures.

Families:
  dense / moe          — pre-norm transformer stack (scan over layers)
  hybrid (zamba2)      — Mamba2 backbone + ONE weight-shared attn+MLP block
                         applied every ``attn_every`` layers
  ssm (xlstm)          — [mLSTM × k, sLSTM] groups
  vlm (paligemma)      — patch-embedding prefix (stub frontend) + prefix-LM
  audio (hubert)       — encoder-only, frame-embedding stub + masked CE

Everything is scanned with stacked per-layer params so the 94-layer MoE
dry-run lowers to compact HLO, and blocks are jax.checkpoint'd according
to ``cfg.remat``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from . import attention, layers, moe as moe_lib, ssm, xlstm


Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack_init(init_fn, rng, n: int):
    """Initialize n copies of a sub-module with stacked leaves."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(init_fn)(rngs)


def _stack_axes(axes: Dict) -> Dict:
    """Prepend a layer axis (None — layers are never sharded) to every leaf."""
    return jax.tree.map(
        lambda t: (None,) + t,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


class LM:
    """Config-driven model; all methods are pure (params passed in)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dtype(cfg)

    # ------------------------------------------------------------------ init
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        dt = self.dtype
        r = jax.random.split(rng, 8)
        p: Params = {"embed": layers.init_embedding(cfg.vocab, cfg.d_model,
                                                    dt, r[0])}
        p["final_norm"] = layers.init_rmsnorm(cfg.d_model, dt)
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.init_embedding(cfg.vocab, cfg.d_model,
                                                 dt, r[1])
        if cfg.frontend_dim:
            p["frontend"] = layers.init_frontend_proj(cfg.frontend_dim,
                                                      cfg.d_model, dt, r[2])
        fam = cfg.family
        if fam in ("dense", "moe", "vlm", "audio"):
            p["blocks"] = _stack_init(
                lambda k: self._init_transformer_block(k), r[3], cfg.n_layers)
        elif fam == "hybrid":
            groups, tail = self._zamba_layout()
            p["mamba_groups"] = _stack_init(
                lambda k: _stack_init(
                    lambda k2: self._init_mamba_block(k2), k, cfg.attn_every),
                r[3], groups)
            if tail:
                p["mamba_tail"] = _stack_init(
                    lambda k: self._init_mamba_block(k), r[4], tail)
            p["shared_attn"] = self._init_transformer_block(r[5])
        elif fam == "ssm":
            n_groups, per = self._xlstm_layout()
            p["mlstm_groups"] = _stack_init(
                lambda k: _stack_init(
                    lambda k2: self._init_mlstm_block(k2), k, per), r[3],
                n_groups)
            p["slstm"] = _stack_init(
                lambda k: self._init_slstm_block(k), r[4], n_groups)
        else:
            raise ValueError(f"unknown family {fam}")
        return p

    def param_axes(self) -> Params:
        cfg = self.cfg
        p: Params = {"embed": layers.axes_embedding(),
                     "final_norm": layers.axes_rmsnorm()}
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.axes_embedding()
        if cfg.frontend_dim:
            p["frontend"] = layers.axes_frontend_proj()
        fam = cfg.family
        if fam in ("dense", "moe", "vlm", "audio"):
            p["blocks"] = _stack_axes(self._axes_transformer_block())
        elif fam == "hybrid":
            groups, tail = self._zamba_layout()
            p["mamba_groups"] = _stack_axes(_stack_axes(self._axes_mamba_block()))
            if tail:
                p["mamba_tail"] = _stack_axes(self._axes_mamba_block())
            p["shared_attn"] = self._axes_transformer_block()
        elif fam == "ssm":
            p["mlstm_groups"] = _stack_axes(_stack_axes(self._axes_mlstm_block()))
            p["slstm"] = _stack_axes(self._axes_slstm_block())
        return p

    # -- per-block init/axes --------------------------------------------------
    def _init_transformer_block(self, rng) -> Params:
        cfg, dt = self.cfg, self.dtype
        r = jax.random.split(rng, 3)
        p = {"ln1": layers.init_rmsnorm(cfg.d_model, dt),
             "attn": attention.init_attention(cfg, dt, r[0]),
             "ln2": layers.init_rmsnorm(cfg.d_model, dt)}
        if cfg.moe is not None and cfg.family == "moe":
            p["moe"] = moe_lib.init_moe(cfg, dt, r[1])
        elif cfg.d_ff > 0:
            p["mlp"] = layers.init_mlp(cfg.d_model, cfg.d_ff, cfg.mlp_gated,
                                       dt, r[1])
        return p

    def _axes_transformer_block(self) -> Params:
        cfg = self.cfg
        p = {"ln1": layers.axes_rmsnorm(),
             "attn": attention.axes_attention(cfg),
             "ln2": layers.axes_rmsnorm()}
        if cfg.moe is not None and cfg.family == "moe":
            p["moe"] = moe_lib.axes_moe(cfg)
        elif cfg.d_ff > 0:
            p["mlp"] = layers.axes_mlp(cfg.mlp_gated)
        return p

    def _init_mamba_block(self, rng) -> Params:
        cfg, dt = self.cfg, self.dtype
        return {"ln": layers.init_rmsnorm(cfg.d_model, dt),
                "mixer": ssm.init_mamba2(cfg, dt, rng)}

    def _axes_mamba_block(self) -> Params:
        return {"ln": layers.axes_rmsnorm(),
                "mixer": ssm.axes_mamba2(self.cfg)}

    def _init_mlstm_block(self, rng) -> Params:
        cfg, dt = self.cfg, self.dtype
        return {"ln": layers.init_rmsnorm(cfg.d_model, dt),
                "mixer": xlstm.init_mlstm(cfg, dt, rng)}

    def _axes_mlstm_block(self) -> Params:
        return {"ln": layers.axes_rmsnorm(),
                "mixer": xlstm.axes_mlstm(self.cfg)}

    def _init_slstm_block(self, rng) -> Params:
        cfg, dt = self.cfg, self.dtype
        return {"ln": layers.init_rmsnorm(cfg.d_model, dt),
                "cell": xlstm.init_slstm(cfg, dt, rng)}

    def _axes_slstm_block(self) -> Params:
        return {"ln": layers.axes_rmsnorm(),
                "cell": xlstm.axes_slstm(self.cfg)}

    # -- layouts ---------------------------------------------------------------
    def _zamba_layout(self) -> Tuple[int, int]:
        g = self.cfg.n_layers // self.cfg.attn_every
        tail = self.cfg.n_layers - g * self.cfg.attn_every
        return g, tail

    def _xlstm_layout(self) -> Tuple[int, int]:
        per = self.cfg.xlstm.mlstm_per_slstm
        n_groups = self.cfg.n_layers // (per + 1)
        return n_groups, per

    # ------------------------------------------------------------- forward
    def _maybe_remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "attn":
            # save attention outputs: the backward pass never re-runs the
            # (memory-heavy) blockwise attention — §Perf iteration 4
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out")
        elif self.cfg.remat == "full":
            policy = jax.checkpoint_policies.nothing_saveable
        else:
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)

    def backbone(self, params: Params, x: jax.Array, positions: jax.Array,
                 *, causal: bool = True, prefix_len: int = 0
                 ) -> Tuple[jax.Array, jax.Array]:
        """(B,S,D) → (B,S,D); returns (hidden, aux_loss)."""
        cfg = self.cfg
        fam = cfg.family
        aux0 = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe", "vlm", "audio"):
            def block(carry, bp):
                h, aux = carry
                # 'seq_sp' is () by default (no-op); the hillclimb enables
                # Megatron-style sequence parallelism by mapping it to the
                # model axis (norms/residual work sharded over seq).
                h = shard(h, "batch", "seq_sp", None)
                a = attention.attention_block(bp["attn"], cfg,
                                              layers.rmsnorm(bp["ln1"], h,
                                                             cfg.norm_eps),
                                              positions, causal=causal,
                                              prefix_len=prefix_len)
                a = _checkpoint_name(a, "attn_out")
                h = shard(h + a, "batch", "seq_sp", None)
                hn = layers.rmsnorm(bp["ln2"], h, cfg.norm_eps)
                if fam == "moe":
                    f, a_loss = moe_lib.moe_block(bp["moe"], cfg, hn,
                                                  return_aux=True)
                    aux = aux + a_loss
                else:
                    f = layers.mlp(bp["mlp"], hn, cfg.mlp_gated)
                return (h + f, aux), None

            (x, aux), _ = jax.lax.scan(self._maybe_remat(block), (x, aux0),
                                       params["blocks"])
            return x, aux

        if fam == "hybrid":
            def mamba(carry, bp):
                h = carry
                m = ssm.mamba2_block(bp["mixer"], cfg,
                                     layers.rmsnorm(bp["ln"], h, cfg.norm_eps))
                return h + m, None

            def shared_part(h, bp):
                # weight-shared attention block (same params every group)
                a = attention.attention_block(
                    bp["attn"], cfg,
                    layers.rmsnorm(bp["ln1"], h, cfg.norm_eps),
                    positions, causal=causal)
                h = h + a
                f = layers.mlp(bp["mlp"],
                               layers.rmsnorm(bp["ln2"], h, cfg.norm_eps),
                               cfg.mlp_gated)
                return h + f

            def group(h, gp):
                h, _ = jax.lax.scan(self._maybe_remat(mamba), h, gp)
                return self._maybe_remat(shared_part)(h, params["shared_attn"]), None

            x, _ = jax.lax.scan(group, x, params["mamba_groups"])
            if "mamba_tail" in params:
                x, _ = jax.lax.scan(self._maybe_remat(mamba), x,
                                    params["mamba_tail"])
            return x, aux0

        if fam == "ssm":
            def mblock(h, bp):
                m = xlstm.mlstm_block(bp["mixer"], cfg,
                                      layers.rmsnorm(bp["ln"], h, cfg.norm_eps))
                return h + m, None

            def slstm_part(h, sp):
                s = xlstm.slstm_block(sp["cell"], cfg,
                                      layers.rmsnorm(sp["ln"], h, cfg.norm_eps))
                return h + s

            def group(h, gp):
                mg, sp = gp
                h, _ = jax.lax.scan(self._maybe_remat(mblock), h, mg)
                return self._maybe_remat(slstm_part)(h, sp), None

            x, _ = jax.lax.scan(group, x,
                                (params["mlstm_groups"], params["slstm"]))
            return x, aux0

        raise ValueError(fam)

    def embed_inputs(self, params: Params, batch: Dict) -> Tuple[jax.Array,
                                                                 jax.Array, int]:
        """Batch dict → (embeddings (B,S,D), positions (S,), prefix_len)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            patches = layers.frontend_proj(params["frontend"],
                                           batch["patches"].astype(self.dtype))
            tok = layers.embed(params["embed"], batch["tokens"])
            if cfg.tie_embeddings:
                tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)
            x = jnp.concatenate([patches, tok], axis=1)
            prefix = patches.shape[1]
        elif cfg.family == "audio":
            x = layers.frontend_proj(params["frontend"],
                                     batch["frames"].astype(self.dtype))
            prefix = 0
        else:
            x = layers.embed(params["embed"], batch["tokens"])
            prefix = 0
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        return shard(x, "batch", None, None), positions, prefix

    def logits(self, params: Params, hidden: jax.Array) -> jax.Array:
        head = params["embed"] if self.cfg.tie_embeddings else params["lm_head"]
        return layers.unembed(head, hidden)

    def forward(self, params: Params, batch: Dict) -> Tuple[jax.Array,
                                                            jax.Array]:
        """Full-sequence forward → (logits, aux_loss)."""
        cfg = self.cfg
        x, positions, prefix = self.embed_inputs(params, batch)
        causal = not cfg.encoder_only
        h, aux = self.backbone(params, x, positions, causal=causal,
                               prefix_len=prefix)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self.logits(params, h), aux

    # ------------------------------------------------------------------ loss
    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.family == "audio":
            targets = batch["targets"]
            mask = batch["mask"].astype(jnp.float32)
            ce = _cross_entropy(logits, targets)
            loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        elif cfg.family == "vlm":
            text_logits = logits[:, cfg.n_patches:, :]
            tokens = batch["tokens"]
            ce = _cross_entropy(text_logits[:, :-1], tokens[:, 1:])
            loss = jnp.mean(ce)
        else:
            tokens = batch["tokens"]
            ce = _cross_entropy(logits[:, :-1], tokens[:, 1:])
            loss = jnp.mean(ce)
        total = loss + aux
        return total, {"ce": loss, "aux": aux}

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_seq: int,
                   long_context: bool = False) -> Params:
        cfg, dt = self.cfg, self.dtype
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            one = attention.init_kv_cache(cfg, batch, max_seq, dt)
            return {"kv": jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape),
                one)}
        if fam == "hybrid":
            groups, tail = self._zamba_layout()
            m_one = ssm.init_mamba2_state(cfg, batch, dt)
            kv_one = attention.init_kv_cache(cfg, batch, max_seq, dt)
            c = {"mamba": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None, None], (groups, cfg.attn_every) + x.shape), m_one),
                "kv": jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (groups,) + x.shape),
                    kv_one)}
            if tail:
                c["mamba_tail"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (tail,) + x.shape),
                    m_one)
            return c
        if fam == "ssm":
            n_groups, per = self._xlstm_layout()
            m_one = xlstm.init_mlstm_state(cfg, batch, dt)
            s_one = xlstm.init_slstm_state(cfg, batch)
            return {"mlstm": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None, None], (n_groups, per) + x.shape), m_one),
                "slstm": jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
                    s_one)}
        raise ValueError(f"no decode cache for family {fam}")

    def cache_axes(self, long_context: bool = False) -> Params:
        cfg = self.cfg
        fam = cfg.family
        kv_ax = _stack_axes(attention.axes_kv_cache(long_context))
        if fam in ("dense", "moe", "vlm"):
            return {"kv": kv_ax}
        if fam == "hybrid":
            groups, tail = self._zamba_layout()
            c = {"mamba": _stack_axes(_stack_axes(ssm.axes_mamba2_state())),
                 "kv": kv_ax}
            if tail:
                c["mamba_tail"] = _stack_axes(ssm.axes_mamba2_state())
            return c
        if fam == "ssm":
            return {"mlstm": _stack_axes(_stack_axes(xlstm.axes_mlstm_state())),
                    "slstm": _stack_axes(xlstm.axes_slstm_state())}
        raise ValueError(fam)

    def prefill(self, params: Params, batch: Dict, max_seq: int
                ) -> Tuple[jax.Array, Params]:
        """Batched prefill for transformer families: one full forward pass
        that also populates the decode cache (bidirectional over a VLM
        image prefix — which a token-by-token prefill cannot express).

        Returns (logits (B,S,V), cache ready for decode at pos = S).
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                f"batched prefill-with-cache for family {cfg.family} uses "
                f"the recurrent decode path instead")
        x, positions, prefix = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        cache = self.init_cache(b, max_seq)
        if cfg.sliding_window is not None and s > cache["kv"]["k"].shape[2]:
            raise NotImplementedError(
                "SWA ring-cache prefill beyond the window: decode the "
                "overflow stepwise")
        aux0 = jnp.zeros((), jnp.float32)

        def block(carry, bp):
            h, aux = carry
            a, (k, v) = attention.attention_block(
                bp["attn"], cfg,
                layers.rmsnorm(bp["ln1"], h, cfg.norm_eps), positions,
                causal=True, prefix_len=prefix, return_kv=True)
            h = h + a
            hn = layers.rmsnorm(bp["ln2"], h, cfg.norm_eps)
            if cfg.family == "moe":
                f = moe_lib.moe_block(bp["moe"], cfg, hn)
            else:
                f = layers.mlp(bp["mlp"], hn, cfg.mlp_gated)
            return (h + f, aux), (k, v)

        (x, _), (ks, vs) = jax.lax.scan(block, (x, aux0), params["blocks"])
        # write the rope'd K/V prefix into the cache (ring-aware for SWA)
        cache_len = cache["kv"]["k"].shape[2]
        take = min(s, cache_len)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["kv"]["k"], ks[:, :, s - take:s], 0, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["kv"]["v"], vs[:, :, s - take:s], 0, axis=2)
        cache = {"kv": {"k": new_k, "v": new_v}}
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self.logits(params, x), cache

    def decode_step(self, params: Params, cache: Params, token: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        """One decode step. token: (B,1) int32; pos: scalar int32.

        Returns (logits (B,1,V), new cache).
        """
        cfg = self.cfg
        x = layers.embed(params["embed"], token)
        if cfg.family == "vlm" and cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        fam = cfg.family

        if fam in ("dense", "moe", "vlm"):
            def block(h, xs):
                bp, kv = xs
                a, kv = attention.decode_attention(
                    bp["attn"], cfg,
                    layers.rmsnorm(bp["ln1"], h, cfg.norm_eps), kv, pos)
                h = h + a
                hn = layers.rmsnorm(bp["ln2"], h, cfg.norm_eps)
                if fam == "moe":
                    f = moe_lib.moe_block(bp["moe"], cfg, hn)
                else:
                    f = layers.mlp(bp["mlp"], hn, cfg.mlp_gated)
                return h + f, kv

            x, new_kv = jax.lax.scan(block, x,
                                     (params["blocks"], cache["kv"]))
            new_cache = {"kv": new_kv}
        elif fam == "hybrid":
            def mamba(h, xs):
                bp, st = xs
                m, st = ssm.mamba2_decode_step(
                    bp["mixer"], cfg,
                    layers.rmsnorm(bp["ln"], h, cfg.norm_eps), st)
                return h + m, st

            def group(h, xs):
                gp, m_st, kv = xs
                h, m_st = jax.lax.scan(mamba, h, (gp, m_st))
                bp = params["shared_attn"]
                a, kv = attention.decode_attention(
                    bp["attn"], cfg,
                    layers.rmsnorm(bp["ln1"], h, cfg.norm_eps), kv, pos)
                h = h + a
                f = layers.mlp(bp["mlp"],
                               layers.rmsnorm(bp["ln2"], h, cfg.norm_eps),
                               cfg.mlp_gated)
                return h + f, (m_st, kv)

            x, (new_mamba, new_kv) = jax.lax.scan(
                group, x, (params["mamba_groups"], cache["mamba"],
                           cache["kv"]))
            new_cache = {"mamba": new_mamba, "kv": new_kv}
            if "mamba_tail" in params:
                x, tail_st = jax.lax.scan(
                    mamba, x, (params["mamba_tail"], cache["mamba_tail"]))
                new_cache["mamba_tail"] = tail_st
        elif fam == "ssm":
            def mblock(h, xs):
                bp, st = xs
                m, st = xlstm.mlstm_decode_step(
                    bp["mixer"], cfg,
                    layers.rmsnorm(bp["ln"], h, cfg.norm_eps), st)
                return h + m, st

            def group(h, xs):
                (mg, sp), m_st, s_st = xs
                h, m_st = jax.lax.scan(mblock, h, (mg, m_st))
                s, s_st = xlstm.slstm_decode_step(
                    sp["cell"], cfg,
                    layers.rmsnorm(sp["ln"], h, cfg.norm_eps), s_st)
                return h + s, (m_st, s_st)

            x, (new_m, new_s) = jax.lax.scan(
                group, x, ((params["mlstm_groups"], params["slstm"]),
                           cache["mlstm"], cache["slstm"]))
            new_cache = {"mlstm": new_m, "slstm": new_s}
        else:
            raise ValueError(fam)

        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self.logits(params, x), new_cache


def _cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    return lse - true


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
