"""Mixture-of-Experts: token-choice top-k routing with static capacity.

Two execution paths, one routing semantics:

**Sharded path** (mesh active — the production configuration): a
shard_map over the full mesh.  Tokens arrive batch-sharded over
(pod, data) and replicated over model; expert weights are sharded over
the model axis.  Each chip routes its local tokens, serves only the
experts it owns (expert parallelism, qwen3: 128/16 = 8 per chip), and
the per-token combine is ONE psum over the model axis — the same
collective the TP attention block already pays, so MoE adds no new
collective class.  When the expert count doesn't divide the mesh
(qwen2: 60 experts), the same body falls back to tensor parallelism
*inside* every expert (d_ff sharded, contributions summed by the same
psum).  Dispatch is scatter-of-token-ids + gather, never a k-fold copy
of activations.

**Local path** (no mesh — CPU smoke tests): same math on one device.

Capacity semantics: positions are assigned per data shard
(C_local = T_local·k·cf/E), the standard practice for EP training; drops
are deterministic in token order.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import current_ctx, shard
from . import layers


def init_moe(cfg, dtype, rng) -> Dict:
    d = cfg.d_model
    e = cfg.moe
    ks = jax.random.split(rng, 5)
    sd_in = d ** -0.5
    sd_out = e.expert_d_ff ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e.n_experts), jnp.float32)
                   * sd_in).astype(jnp.float32),   # router stays f32
        "w_in": (jax.random.normal(ks[1], (e.n_experts, d, e.expert_d_ff),
                                   jnp.float32) * sd_in).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e.n_experts, d, e.expert_d_ff),
                                     jnp.float32) * sd_in).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e.n_experts, e.expert_d_ff, d),
                                    jnp.float32) * sd_out).astype(dtype),
    }
    if e.n_shared_experts:
        p["shared"] = layers.init_mlp(d, e.shared_d_ff, True, dtype, ks[4])
        p["shared_gate"] = jnp.zeros((d, 1), jnp.float32)
    return p


def axes_moe(cfg) -> Dict:
    p = {
        "router": (None, None),
        "w_in": ("experts", None, "ff"),
        "w_gate": ("experts", None, "ff"),
        "w_out": ("experts", "ff", None),
    }
    if cfg.moe.n_shared_experts:
        p["shared"] = layers.axes_mlp(True)
        p["shared_gate"] = (None, None)
    return p


def _capacity(n_tokens: int, cfg) -> int:
    e = cfg.moe
    if n_tokens * e.top_k <= 4096:
        # tiny token counts (decode steps, smoke tests): dense-safe capacity
        # — no drops even if every pair lands on one expert.
        return (n_tokens * e.top_k + 7) // 8 * 8
    c = int(n_tokens * e.top_k * e.capacity_factor / e.n_experts)
    return max(8, (c + 7) // 8 * 8)  # 8-align for TPU tiling


def _route(xt_f32: jax.Array, router: jax.Array, cfg):
    """→ (top_p (T,k), top_e (T,k), probs (T,E)) in f32."""
    e = cfg.moe
    logits = xt_f32 @ router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, e.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_e, probs


def _dispatch_compute_combine(xt, top_p, top_e, w_in, w_gate, w_out, cfg,
                              expert_offset: int, n_local_experts: int,
                              cap: int):
    """Serve ``n_local_experts`` experts starting at ``expert_offset`` for
    the local tokens.  Returns the (partial) output (T, D)."""
    t, d = xt.shape
    k = cfg.moe.top_k
    flat_e = top_e.reshape(-1)                                   # (T*k,)
    local_e = flat_e - expert_offset
    mine = (local_e >= 0) & (local_e < n_local_experts)
    local_e = jnp.where(mine, local_e, 0)

    onehot = jax.nn.one_hot(local_e, n_local_experts,
                            dtype=jnp.int32) * mine[:, None].astype(jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = mine & (pos < cap)
    slot = jnp.where(keep, local_e * cap + pos, n_local_experts * cap)

    # invert slot→(token, k-choice): scatter ids, then gather activations
    pair_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    tok_of_slot = jnp.full((n_local_experts * cap,), t, jnp.int32
                           ).at[slot].set(pair_tok, mode="drop")
    prob_of_slot = jnp.zeros((n_local_experts * cap,), jnp.float32
                             ).at[slot].set(top_p.reshape(-1), mode="drop")
    filled = jnp.zeros((n_local_experts * cap,), jnp.bool_
                       ).at[slot].set(True, mode="drop")

    gather_idx = jnp.minimum(tok_of_slot, t - 1)
    buf = xt[gather_idx] * filled[:, None].astype(xt.dtype)
    buf = buf.reshape(n_local_experts, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)
    out_buf = out_buf.reshape(n_local_experts * cap, d).astype(jnp.float32)
    out_buf = out_buf * prob_of_slot[:, None]

    out = jnp.zeros((t, d), jnp.float32
                    ).at[tok_of_slot].add(out_buf, mode="drop")
    return out


def _moe_body(xt, router, w_in, w_gate, w_out, shared, shared_gate, cfg,
              *, model_axis: Optional[str], ep: bool, return_aux: bool,
              batch_axes: Tuple[str, ...] = ()):
    """Per-chip MoE: xt (T_local, D); weights are local shards."""
    e = cfg.moe
    t = xt.shape[0]
    xt_f32 = xt.astype(jnp.float32)
    top_p, top_e, probs = _route(xt_f32, router, cfg)
    cap = _capacity(t, cfg)

    n_local = w_in.shape[0]
    if ep and model_axis is not None:
        offset = jax.lax.axis_index(model_axis) * n_local
    else:
        offset = 0
    out = _dispatch_compute_combine(xt, top_p, top_e, w_in, w_gate, w_out,
                                    cfg, offset, n_local, cap)

    if shared:
        # shared experts (w sharded over ff when on-mesh → partial, psum'd)
        h = xt @ shared["w_in"]
        g = xt @ shared["w_gate"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
        sh = (h @ shared["w_out"]).astype(jnp.float32)
        gate = jax.nn.sigmoid(xt_f32 @ shared_gate)
        out = out + sh * gate

    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)

    if not return_aux:
        return out.astype(xt.dtype), jnp.zeros((), jnp.float32)
    me = jnp.mean(jax.nn.one_hot(top_e, e.n_experts, dtype=jnp.float32),
                  axis=(0, 1))
    pe = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(me * pe) * e.router_aux_loss
    if batch_axes:
        # average the per-data-shard stats so the scalar is replicated
        aux = jax.lax.pmean(aux, batch_axes)
    return out.astype(xt.dtype), aux


def moe_block(params: Dict, cfg, x: jax.Array, return_aux: bool = False):
    """x: (B, S, D) → (B, S, D) [+ aux load-balancing loss]."""
    b, s, d = x.shape
    e = cfg.moe
    ctx = current_ctx()
    shared = params.get("shared")
    shared_gate = params.get("shared_gate")

    if ctx.mesh is None:
        xt = x.reshape(b * s, d)
        out, aux = _moe_body(xt, params["router"], params["w_in"],
                             params["w_gate"], params["w_out"], shared,
                             shared_gate, cfg, model_axis=None,
                             ep=False, return_aux=return_aux)
        out = out.reshape(b, s, d)
        return (out, aux) if return_aux else out

    mesh = ctx.mesh
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axes.get("model", 1)
    ep = e.n_experts % model_n == 0 and model_n > 1
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)

    w_spec = (P("model", None, None) if ep else P(None, None, "model"))
    w_out_spec = (P("model", None, None) if ep else P(None, "model", None))
    if shared:
        shared_specs = {"w_in": P(None, "model"), "w_gate": P(None, "model"),
                        "w_out": P("model", None)}
        shared_args = (shared, shared_gate)
        shared_in = (shared_specs, P())
    else:
        shared_args = ({}, jnp.zeros((d, 1), jnp.float32))
        shared_in = ({}, P())

    body = functools.partial(_moe_body, cfg=cfg, model_axis="model",
                             ep=ep, return_aux=return_aux,
                             batch_axes=batch_axes)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None), P(), w_spec, w_spec, w_out_spec)
        + shared_in,
        out_specs=(P(batch_axes, None), P()),
        check_rep=False)
    xt = x.reshape(b * s, d)
    out, aux = fn(xt, params["router"], params["w_in"], params["w_gate"],
                  params["w_out"], *shared_args)
    out = out.reshape(b, s, d)
    out = shard(out, "batch", None, None)
    if return_aux:
        # aux comes back identical on every shard (it's a psum-free scalar
        # computed from replicated routing stats); mean across shards is a
        # no-op numerically but keeps the value replicated for GSPMD.
        return out, aux
    return out
