"""Core layers: norms, embeddings, RoPE, MLPs.

Pure functions over explicit param dicts.  Every ``init_*`` has a matching
``axes_*`` returning the same pytree structure with logical-axis tuples
(consumed by repro.dist.sharding for pjit in/out shardings).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def axes_rmsnorm() -> Dict:
    return {"scale": (None,)}


def rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(vocab: int, d: int, dtype, rng) -> Dict:
    emb = jax.random.normal(rng, (vocab, d), dtype=jnp.float32) * (d ** -0.5)
    return {"table": emb.astype(dtype)}


def axes_embedding() -> Dict:
    return {"table": ("vocab", "fsdp")}


def embed(params: Dict, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return shard(out, "batch", None, None)


def unembed(params: Dict, x: jax.Array) -> jax.Array:
    """Logits: (B, S, D) @ (V, D)ᵀ → (B, S, V), f32 for the softmax."""
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"],
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 → (cos, sin) with shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / plain GeLU)
# ---------------------------------------------------------------------------


def init_mlp(d: int, d_ff: int, gated: bool, dtype, rng) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    sd_in = d ** -0.5
    sd_out = d_ff ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, d_ff), jnp.float32) * sd_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d), jnp.float32) * sd_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff), jnp.float32) * sd_in).astype(dtype)
    return p


def axes_mlp(gated: bool) -> Dict:
    p = {"w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp")}
    if gated:
        p["w_gate"] = ("fsdp", "ff")
    return p


def mlp(params: Dict, x: jax.Array, gated: bool) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    h = shard(h, "batch", None, "ff")
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_out"])
    return shard(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Linear frontend projectors (VLM patch / audio frame stubs)
# ---------------------------------------------------------------------------


def init_frontend_proj(in_dim: int, d: int, dtype, rng) -> Dict:
    w = jax.random.normal(rng, (in_dim, d), jnp.float32) * (in_dim ** -0.5)
    return {"w": w.astype(dtype), "b": jnp.zeros((d,), dtype)}


def axes_frontend_proj() -> Dict:
    return {"w": (None, "fsdp"), "b": (None,)}


def frontend_proj(params: Dict, x: jax.Array) -> jax.Array:
    return (jnp.einsum("bse,ed->bsd", x, params["w"]) +
            params["b"].astype(x.dtype))
