"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

LINVIEW connection (DESIGN.md §5): the mLSTM memory update

    C_t = f_t · C_{t-1} + i_t · v_t k_tᵀ

is a *rank-1 factored-delta update of a matrix view* — the paper's §4.2
representation is this architecture's native recurrence, and the decode
path applies it literally (a Sherman–Morrison-style O(d²) step instead of
any O(d³) recompute).

Training uses a chunkwise-parallel form with exact log-space
stabilization (the xLSTM m_t trick carried at chunk granularity): carry
(S̃, ñ, m̄) with true state S = S̃·exp(m̄); all within-chunk weights are
exponentiated relative to a per-query running max.  The sLSTM recurrence
mixes h_{t-1} into the gates, is not parallelizable (xLSTM paper §2.3),
and runs as a lax.scan over time — its GPU-fused-kernel trick has no TPU
analogue at the XLA level; see DESIGN.md hardware-adaptation notes.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from . import layers

NEG = -1e30


def _mlstm_dims(cfg):
    d_inner = int(cfg.xlstm.proj_factor * cfg.d_model)
    h = cfg.n_heads
    hd = d_inner // h
    return d_inner, h, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg, dtype, rng) -> Dict:
    d = cfg.d_model
    d_inner, h, hd = _mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    ks = jax.random.split(rng, 8)
    sd, sdi = d ** -0.5, d_inner ** -0.5
    return {
        "up_l": (jax.random.normal(ks[0], (d, d_inner), jnp.float32) * sd).astype(dtype),
        "up_r": (jax.random.normal(ks[1], (d, d_inner), jnp.float32) * sd).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (k, d_inner), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        # headwise (block-diagonal) q/k/v projections — the xLSTM paper's
        # LinearHeadwiseExpand; a dense d_inner² projection would overshoot
        # the 350M budget by ~3×.
        "wq": (jax.random.normal(ks[3], (h, hd, hd), jnp.float32) * hd ** -0.5).astype(dtype),
        "wk": (jax.random.normal(ks[4], (h, hd, hd), jnp.float32) * hd ** -0.5).astype(dtype),
        "wv": (jax.random.normal(ks[5], (h, hd, hd), jnp.float32) * hd ** -0.5).astype(dtype),
        "w_igate": jnp.zeros((d_inner, h), jnp.float32),
        "b_igate": jnp.full((h,), -3.0, jnp.float32),   # small input gate init
        "w_fgate": jnp.zeros((d_inner, h), jnp.float32),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),    # long-memory init
        "norm": layers.init_rmsnorm(d_inner, dtype),
        "down": (jax.random.normal(ks[6], (d_inner, d), jnp.float32) * sdi).astype(dtype),
    }


def axes_mlstm(cfg) -> Dict:
    return {
        "up_l": ("fsdp", "ff"), "up_r": ("fsdp", "ff"),
        "conv_w": (None, "ff"), "conv_b": ("ff",),
        "wq": ("heads", None, None), "wk": ("heads", None, None),
        "wv": ("heads", None, None),
        "w_igate": (None, "heads"), "b_igate": ("heads",),
        "w_fgate": (None, "heads"), "b_fgate": ("heads",),
        "norm": layers.axes_rmsnorm(),
        "down": ("ff", "fsdp"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)
                       ).astype(x.dtype)


def mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int):
    """Stabilized chunkwise mLSTM.

    q/k/v: (B,S,H,hd) f32; log_i/log_f: (B,S,H) f32.  Returns (B,S,H,hd).
    """
    b, s_orig, h, hd = q.shape
    chunk = min(chunk, s_orig) if s_orig % chunk else chunk
    pad = (-s_orig) % chunk
    if pad:  # causal: padded tail cannot affect earlier outputs (truncated)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, hd) * (hd ** -0.5)
    kc = k.reshape(b, nc, chunk, h, hd)
    vc = v.reshape(b, nc, chunk, h, hd)
    lic = log_i.reshape(b, nc, chunk, h)
    lfc = log_f.reshape(b, nc, chunk, h)
    cumf = jnp.cumsum(lfc, axis=2)                   # F_t within chunk
    f_end = cumf[:, :, -1, :]

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def per_chunk(carry, inp):
        s_t, n_t, m_bar = carry                      # (B,H,hd,hd),(B,H,hd),(B,H)
        qb, kb, vb, li, cf, fe = inp

        # log-weights
        lw_intra = (cf[:, :, None, :] - cf[:, None, :, :]
                    + li[:, None, :, :])             # (B,t,u,H)
        lw_intra = jnp.where(tri[None, :, :, None], lw_intra, NEG)
        lw_inter = cf + m_bar[:, None, :]            # (B,t,H)

        m_q = jnp.maximum(jnp.max(lw_intra, axis=2), lw_inter)  # (B,t,H)
        w_intra = jnp.exp(lw_intra - m_q[:, :, None, :])
        w_inter = jnp.exp(lw_inter - m_q)

        qk = jnp.einsum("bthd,buhd->btuh", qb, kb)   # (B,t,u,H)
        numer = jnp.einsum("btuh,btuh,buhd->bthd", qk, w_intra, vb)
        numer = numer + w_inter[..., None] * jnp.einsum(
            "bthd,bhde->bthe", qb, s_t)
        denom = jnp.einsum("btuh,btuh->bth", qk, w_intra)
        denom = denom + w_inter * jnp.einsum("bthd,bhd->bth", qb, n_t)
        hout = numer / jnp.maximum(jnp.abs(denom),
                                   jnp.exp(-m_q))[..., None]

        # state update (stabilized at new running max m_bar')
        lw_state = fe[:, None, :] - cf + li          # (B,u,H)
        m_new = jnp.maximum(m_bar + fe, jnp.max(lw_state, axis=1))
        w_old = jnp.exp(m_bar + fe - m_new)          # (B,H)
        w_add = jnp.exp(lw_state - m_new[:, None, :])
        s_new = (w_old[:, :, None, None] * s_t +
                 jnp.einsum("buh,buhd,buhe->bhde", w_add, kb, vb))
        n_new = (w_old[:, :, None] * n_t +
                 jnp.einsum("buh,buhd->bhd", w_add, kb))
        return (s_new, n_new, m_new), hout

    init = (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), 0.0, jnp.float32))
    inputs = tuple(jnp.moveaxis(x, 1, 0) for x in
                   (qc, kc, vc, lic, cumf, f_end))
    _, hs = jax.lax.scan(per_chunk, init, inputs)
    return jnp.moveaxis(hs, 0, 1).reshape(b, s, h, hd)[:, :s_orig]


def mlstm_block(params: Dict, cfg, x: jax.Array) -> jax.Array:
    """x: (B,S,D) → (B,S,D)."""
    b, s, d = x.shape
    d_inner, h, hd = _mlstm_dims(cfg)
    left = jnp.einsum("bsd,de->bse", x, params["up_l"])
    right = jnp.einsum("bsd,de->bse", x, params["up_r"])
    left = shard(left, "batch", None, "ff")
    c = _causal_conv(left, params["conv_w"], params["conv_b"])
    ch = c.reshape(b, s, h, hd)
    lh = left.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bshe", ch, params["wq"])
    k = jnp.einsum("bshd,hde->bshe", ch, params["wk"])
    v = jnp.einsum("bshd,hde->bshe", lh, params["wv"])
    cf = c.astype(jnp.float32)
    log_i = (jnp.einsum("bse,eh->bsh", cf, params["w_igate"])
             + params["b_igate"][None, None, :])
    log_f = -jax.nn.softplus(-(jnp.einsum("bse,eh->bsh", cf, params["w_fgate"])
                               + params["b_fgate"][None, None, :]))
    y = mlstm_chunkwise(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), log_i, log_f,
                        cfg.xlstm.chunk)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(right.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["down"])
    return shard(out, "batch", None, None)


def init_mlstm_state(cfg, batch: int, dtype) -> Dict:
    d_inner, h, hd = _mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    return {
        "conv": jnp.zeros((batch, k - 1, d_inner), dtype),
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def axes_mlstm_state() -> Dict:
    return {"conv": ("batch", None, "ff"),
            "s": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads")}


def mlstm_decode_step(params: Dict, cfg, x: jax.Array, state: Dict
                      ) -> Tuple[jax.Array, Dict]:
    """One-token mLSTM: the LINVIEW rank-1 view update in the flesh."""
    b = x.shape[0]
    d_inner, h, hd = _mlstm_dims(cfg)
    left = jnp.einsum("bsd,de->bse", x, params["up_l"])[:, 0]
    right = jnp.einsum("bsd,de->bse", x, params["up_r"])[:, 0]
    win = jnp.concatenate([state["conv"], left[:, None, :]], axis=1)
    c = jnp.einsum("bkc,kc->bc", win, params["conv_w"]) + params["conv_b"]
    c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)

    ch = c.reshape(b, h, hd)
    lh = left.reshape(b, h, hd)
    q = (jnp.einsum("bhd,hde->bhe", ch, params["wq"])
         * hd ** -0.5).astype(jnp.float32)
    k = jnp.einsum("bhd,hde->bhe", ch, params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bhd,hde->bhe", lh, params["wv"]).astype(jnp.float32)
    cf = c.astype(jnp.float32)
    log_i = jnp.einsum("be,eh->bh", cf, params["w_igate"]) + params["b_igate"]
    log_f = -jax.nn.softplus(-(jnp.einsum("be,eh->bh", cf, params["w_fgate"])
                               + params["b_fgate"]))

    m_new = jnp.maximum(log_f + state["m"], log_i)
    w_old = jnp.exp(log_f + state["m"] - m_new)
    w_new = jnp.exp(log_i - m_new)
    # rank-1 factored update of the matrix view C̃ (paper §4.2)
    s_new = (w_old[:, :, None, None] * state["s"] +
             jnp.einsum("bh,bhd,bhe->bhde", w_new, k, v))
    n_new = w_old[:, :, None] * state["n"] + w_new[:, :, None] * k
    numer = jnp.einsum("bhd,bhde->bhe", q, s_new)
    denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    y = numer / jnp.maximum(denom, jnp.exp(-m_new))[..., None]

    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(right.astype(jnp.float32)).astype(y.dtype)[:, None, :]
    out = jnp.einsum("bse,ed->bsd", y, params["down"])
    return out, {"conv": win[:, 1:], "s": s_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg, dtype, rng) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    d_up = int(cfg.xlstm.slstm_proj_factor * d)
    ks = jax.random.split(rng, 4)
    sd = d ** -0.5
    return {
        "w_gates": (jax.random.normal(ks[0], (d, 4 * d), jnp.float32) * sd
                    ).astype(jnp.float32),             # i,f,z,o from x
        "r_gates": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
                    * hd ** -0.5).astype(jnp.float32),  # block-diag recurrent
        "b_gates": jnp.concatenate([
            jnp.full((d,), -3.0), jnp.full((d,), 3.0),
            jnp.zeros((d,)), jnp.zeros((d,))]).astype(jnp.float32),
        "norm": layers.init_rmsnorm(d, dtype),
        "up_l": (jax.random.normal(ks[2], (d, d_up), jnp.float32) * sd).astype(dtype),
        "up_r": (jax.random.normal(ks[2], (d, d_up), jnp.float32) * sd).astype(dtype),
        "down": (jax.random.normal(ks[3], (d_up, d), jnp.float32)
                 * d_up ** -0.5).astype(dtype),
    }


def axes_slstm(cfg) -> Dict:
    # gate weights stay replicated: the recurrence consumes the full h_{t-1}
    # every step, so sharding them would insert a collective per timestep
    # (measured in the dry-run baseline — see EXPERIMENTS.md §Perf).
    return {
        "w_gates": (None, None), "r_gates": ("heads", None, None),
        "b_gates": (None,), "norm": layers.axes_rmsnorm(),
        "up_l": ("fsdp", "ff"), "up_r": ("fsdp", "ff"),
        "down": ("ff", "fsdp"),
    }


def _slstm_cell(params, cfg, xw: jax.Array, carry):
    """One time step.  xw: (B, 4D) preprojected input; carry: (c,n,h,m)."""
    h_dim = cfg.n_heads
    d = cfg.d_model
    hd = d // h_dim
    c_t, n_t, h_t, m_t = carry
    hh = h_t.reshape(-1, h_dim, hd)
    rec = jnp.einsum("bhd,hde->bhe", hh, params["r_gates"]).reshape(-1, 4 * d)
    pre = xw + rec + params["b_gates"][None, :]
    i_r, f_r, z_r, o_r = jnp.split(pre, 4, axis=-1)
    log_i = i_r
    log_f = -jax.nn.softplus(-f_r)
    m_new = jnp.maximum(log_f + m_t, log_i)
    i_g = jnp.exp(log_i - m_new)
    f_g = jnp.exp(log_f + m_t - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c_new = f_g * c_t + i_g * z
    n_new = f_g * n_t + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(params: Dict, cfg, x: jax.Array) -> jax.Array:
    """Strictly sequential sLSTM over time (lax.scan). x: (B,S,D)."""
    b, s, d = x.shape
    xw = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_gates"])
    init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(4))
    (_, _, _, _), hs = jax.lax.scan(
        lambda c, xt: _slstm_cell(params, cfg, xt, c),
        init, jnp.moveaxis(xw, 1, 0),
        unroll=cfg.xlstm.slstm_unroll)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)       # (B,S,D)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", y, params["up_l"])
    gate = jnp.einsum("bsd,de->bse", y, params["up_r"])
    up = jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype) * gate
    out = jnp.einsum("bse,ed->bsd", up, params["down"])
    return shard(out, "batch", None, None)


def init_slstm_state(cfg, batch: int) -> Dict:
    d = cfg.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in "cnhm"}


def axes_slstm_state() -> Dict:
    return {k: ("batch", None) for k in "cnhm"}


def slstm_decode_step(params: Dict, cfg, x: jax.Array, state: Dict
                      ) -> Tuple[jax.Array, Dict]:
    xw = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                    params["w_gates"])[:, 0]
    carry = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), h_out = _slstm_cell(params, cfg, xw, carry)
    y = h_out[:, None, :].astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", y, params["up_l"])
    gate = jnp.einsum("bsd,de->bse", y, params["up_r"])
    up = jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype) * gate
    out = jnp.einsum("bse,ed->bsd", up, params["down"])
    return out, {"c": c, "n": n, "h": h, "m": m}
