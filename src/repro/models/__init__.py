"""LM substrate: layers, attention, MoE, SSM/xLSTM blocks, model assembly."""

from .model import LM, build_model

__all__ = ["LM", "build_model"]
