"""Mamba2 (SSD) block: chunkwise-parallel training, recurrent decode.

The SSD inter-chunk recurrence  ``S_c = a_c·S_{c-1} + X_c``  is exactly the
paper's general iterative form T_{i+1} = A·T_i + B (§5.3) with a scalar-
per-head A — DESIGN.md §5 discusses how LINVIEW's iterative-model analysis
transfers.  The chunkwise algorithm below is the standard quadratic-
intra / linear-inter split (Mamba2 paper, Alg. 1), TPU-shaped: all
intra-chunk work is batched einsums over (chunk × chunk) tiles that fit
VMEM, and the inter-chunk state passing is a lax.scan of rank-N updates.

Single B/C group (the assigned zamba2 config), heads share B/C.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from . import layers


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return d_inner, n_heads, s.headdim, s.state


def init_mamba2(cfg, dtype, rng) -> Dict:
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    k = cfg.ssm.conv_kernel
    ks = jax.random.split(rng, 4)
    sd = d ** -0.5
    proj_out = 2 * d_inner + 2 * n + h      # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out), jnp.float32)
                    * sd).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (k, d_inner + 2 * n), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * n,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": layers.init_rmsnorm(d_inner, dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d), jnp.float32)
                     * d_inner ** -0.5).astype(dtype),
    }


def axes_mamba2(cfg) -> Dict:
    return {
        "in_proj": ("fsdp", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "dt_bias": (None,),
        "a_log": (None,),
        "d_skip": (None,),
        "norm": layers.axes_rmsnorm(),
        "out_proj": ("ff", "fsdp"),
    }


def _split_proj(cfg, proj: jax.Array):
    d_inner, h, p, n = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)
                       ).astype(xbc.dtype)


def chunked_ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                bmat: jax.Array, cmat: jax.Array, chunk: int,
                init_state: jax.Array = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.  x: (B,S,H,P); dt: (B,S,H); bmat/cmat: (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    bsz, s_orig, h, p = x.shape
    n = bmat.shape[-1]
    f32 = jnp.float32
    # pad sequence to a chunk multiple (padded tail has dt=0 ⇒ no effect)
    chunk = min(chunk, s_orig) if s_orig % chunk else chunk
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk

    la = (-jnp.exp(a_log)[None, None, :] * dt).astype(f32)     # log a (B,S,H)
    xc = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    lac = la.reshape(bsz, nc, chunk, h)
    bc = bmat.reshape(bsz, nc, chunk, n).astype(f32)
    cc = cmat.reshape(bsz, nc, chunk, n).astype(f32)

    cum = jnp.cumsum(lac, axis=2)                              # LA (B,nc,L,H)
    la_end = cum[:, :, -1, :]                                  # (B,nc,H)

    # intra-chunk: scores[b,c,h,t,u] = (C_t·B_u)·exp(LA_t−LA_u)·dt_u, u ≤ t
    g = jnp.einsum("bctn,bcun->bctu", cc, bc)                  # (B,nc,L,L)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,t,u,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = g[..., None] * w * dtc[:, :, None, :, :]          # (B,nc,t,u,H)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", scores, xc)

    # chunk state contributions: Sc[b,c,h,n,p]
    wend = jnp.exp(la_end[:, :, None, :] - cum) * dtc          # (B,nc,L,H)
    s_chunk = jnp.einsum("bcuh,bcun,bcuhp->bchnp", wend, bc, xc)

    # inter-chunk scan: S ← exp(la_end)·S + s_chunk
    def step(state, inp):
        la_e, s_c = inp                                        # (B,H), (B,H,N,P)
        y_state = state                                        # carry in
        new = jnp.exp(la_e)[:, :, None, None] * y_state + s_c
        return new, y_state                                    # emit pre-update

    init = (jnp.zeros((bsz, h, n, p), f32) if init_state is None
            else init_state.astype(f32))
    final, s_prev = jax.lax.scan(
        step, init,
        (jnp.moveaxis(la_end, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                        # (B,nc,H,N,P)

    # inter-chunk outputs: y_inter[t] = exp(LA_t)·(C_t · S_prev)
    y_inter = jnp.einsum("bctn,bchnp->bcthp", cc, s_prev) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def mamba2_block(params: Dict, cfg, x: jax.Array,
                 state: Dict = None) -> jax.Array:
    """Full-sequence Mamba2 mixer. x: (B,S,D) → (B,S,D)."""
    b, s, d = x.shape
    d_inner, h, p, n = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    proj = shard(proj, "batch", None, "ff")
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_inner].reshape(b, s, h, p)
    bmat = xbc[..., d_inner:d_inner + n]
    cmat = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    y, _ = chunked_ssd(xs, dt, params["a_log"], bmat, cmat, cfg.ssm.chunk)
    y = y + (params["d_skip"][None, None, :, None] *
             xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return shard(out, "batch", None, None)


# ---------------------------------------------------------------------------
# decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


def init_mamba2_state(cfg, batch: int, dtype) -> Dict:
    d_inner, h, p, n = _dims(cfg)
    k = cfg.ssm.conv_kernel
    return {
        "conv": jnp.zeros((batch, k - 1, d_inner + 2 * n), dtype),
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
    }


def axes_mamba2_state() -> Dict:
    return {"conv": ("batch", None, "ff"),
            "ssm": ("batch", None, None, None)}


def mamba2_decode_step(params: Dict, cfg, x: jax.Array, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    """x: (B,1,D) → (B,1,D); state updated in O(d_inner·N) per token."""
    b = x.shape[0]
    d_inner, h, p, n = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)

    # conv ring: window = [conv_state, xbc_t]
    win = jnp.concatenate([state["conv"], xbc], axis=1)        # (B,K,C)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", win, w) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:, :]

    xs = conv_out[:, :d_inner].reshape(b, h, p)
    bvec = conv_out[:, d_inner:d_inner + n].astype(jnp.float32)
    cvec = conv_out[:, d_inner + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) +
                         params["dt_bias"][None, :])           # (B,H)
    a = jnp.exp(-jnp.exp(params["a_log"])[None, :] * dt)       # (B,H)

    s_new = (a[:, :, None, None] * state["ssm"] +
             jnp.einsum("bh,bn,bhp->bhnp", dt, bvec, xs.astype(jnp.float32)))
    y = jnp.einsum("bn,bhnp->bhp", cvec, s_new)
    y = y + params["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"conv": new_conv, "ssm": s_new}
