"""GQA attention: blockwise (flash-style) training path + cached decode.

The training/prefill path is a pure-JAX blockwise attention (lax.scan over
KV chunks with online softmax) so the S=32k prefill never materializes an
(S × S) logits tensor — the XLA analogue of the TPU flash kernel, chosen
so the dry-run lowers with memory-sane buffers while cost_analysis still
counts the true 4·B·H·S²·hd attention FLOPs.

Masking variants (all folded into one predicate):
  * causal          — decoder LMs
  * sliding window  — h2o-danube (SWA)
  * prefix-LM       — paligemma (bidirectional over the image prefix)
  * none            — hubert encoder
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from . import layers

NEG_INF = -1e30


def init_attention(cfg, dtype, rng) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    sd = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd), jnp.float32) * sd).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd), jnp.float32) * sd).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd), jnp.float32) * sd).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d), jnp.float32) *
               (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def axes_attention(cfg) -> Dict:
    p = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads",)
        p["bk"] = ("kv_heads",)
        p["bv"] = ("kv_heads",)
    return p


def _project_qkv(params: Dict, cfg, x: jax.Array):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _mask_block(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window: Optional[int], prefix_len: int) -> jax.Array:
    """(qc, kc) boolean keep-mask for a block of query/key positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if not causal:
        keep = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    else:
        keep = kp <= qp
        if prefix_len > 0:
            keep = keep | ((qp < prefix_len) & (kp < prefix_len))
    if window is not None:
        keep = keep & (kp > qp - window)
    return keep


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        prefix_len: int = 0, q_chunk: int = 512,
                        kv_chunk: int = 1024,
                        base_pos: int = 0) -> jax.Array:
    """Flash-style attention. q: (B,S,H,hd); k/v: (B,S,KV,hd) → (B,S,H,hd).

    GQA folded via reshape to (KV, group). Accumulation in f32.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, s)
    while s % q_chunk:
        q_chunk -= 1
    kv_chunk = min(kv_chunk, s)
    while s % kv_chunk:
        kv_chunk -= 1
    nq, nk = s // q_chunk, s // kv_chunk
    scale = hd ** -0.5

    # GQA: expand KV to the full query-head count BEFORE the attention
    # einsums.  This keeps the head dimension shardable at H-way TP even
    # when kv_heads < mesh width (command-r: 8 kv heads on model=16) —
    # reshaping q to (kvh, group) instead would force GSPMD to replicate
    # the whole attention (measured: the baseline sweep's worst cells).
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)

    qg = q.reshape(b, nq, q_chunk, h, hd)
    kc = k.reshape(b, nk, kv_chunk, h, hd)
    vc = v.reshape(b, nk, kv_chunk, h, hd)

    def one_q_chunk(qi, q_blk):
        # q_blk: (b, q_chunk, h, hd)
        q_pos = base_pos + qi * q_chunk + jnp.arange(q_chunk)

        def inner(carry, inputs):
            acc, m, l = carry
            ki, k_blk, v_blk = inputs
            k_pos = base_pos + ki * kv_chunk + jnp.arange(kv_chunk)
            # f32 accumulation WITHOUT materializing f32 copies of q/k/v:
            # the baseline's .astype(f32) on the chunks doubled attention
            # HBM traffic (measured — EXPERIMENTS.md §Perf iteration 1).
            logits = jnp.einsum("bqhd,bshd->bqhs", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            keep = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix_len)
            logits = jnp.where(keep[None, :, None, :], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhs,bshd->bqhd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)
        m0 = jnp.full((b, q_chunk, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, h), jnp.float32)
        ks_idx = jnp.arange(nk)
        (acc, m, l), _ = jax.lax.scan(
            inner, (acc0, m0, l0),
            (ks_idx, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out

    outs = jax.lax.map(lambda args: one_q_chunk(*args),
                       (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def attention_block(params: Dict, cfg, x: jax.Array, positions: jax.Array,
                    *, causal: bool = True, prefix_len: int = 0,
                    return_kv: bool = False):
    """Full-sequence attention (train/prefill): x (B,S,D) → (B,S,D).

    ``return_kv=True`` additionally returns the rope'd (k, v) pair so a
    batched prefill can populate the decode cache in one pass.
    """
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x)
    cos, sin = layers.rope_angles(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    out = blockwise_attention(q, k, v, causal=causal,
                              window=cfg.sliding_window,
                              prefix_len=prefix_len)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    out = shard(out, "batch", None, None)
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# decode path (one token, KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_seq: int, dtype) -> Dict:
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window
    cache_len = min(max_seq, window) if window else max_seq
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def axes_kv_cache(long_context: bool = False) -> Dict:
    # sequence-sharded cache (flash-decode SP over the model axis): the
    # kv-head count is too small to shard on wide meshes, and the
    # baseline showed GSPMD inventing full-cache gathers when heads led
    # the layout (EXPERIMENTS.md §Perf).  One spec covers decode_32k
    # (seq→model) and long_500k (batch=1 ⇒ seq→data+model).
    return {"k": ("batch", "cache_seq", None, None),
            "v": ("batch", "cache_seq", None, None)}


def decode_attention(params: Dict, cfg, x: jax.Array, cache: Dict,
                     pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B,1,D); cache k/v: (B,L,KV,hd); pos: scalar.

    Sliding-window archs store a ring buffer of window size; full-attention
    archs store the whole context.  Returns (out (B,1,D), new cache).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    q, k, v = _project_qkv(params, cfg, x)        # (B,1,H/KV,hd)
    cos, sin = layers.rope_angles(pos[None], hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)

    cache_len = cache["k"].shape[1]
    if cfg.sliding_window is not None:
        slot = pos % cache_len                   # ring buffer
        n_valid = jnp.minimum(pos + 1, cache_len)
    else:
        slot = pos
        n_valid = pos + 1
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    k_cache = shard(k_cache, "batch", "cache_seq", None, None)
    v_cache = shard(v_cache, "batch", "cache_seq", None, None)

    # Flash-decode over the sequence-sharded cache: each shard computes
    # partial logits/PV over its cache slice; GSPMD's softmax decomposition
    # inserts only tiny (B,H)-sized ARs per layer.  Two measured rules
    # (EXPERIMENTS.md §Perf iterations 1–3):
    #   * never cast the cache — an .astype(f32) materialized a full-cache
    #     f32 copy per step (50 GB on qwen3-moe decode_32k);
    #   * keep the GQA GROUPED einsum — heads are unsharded here (the
    #     model axis holds the sequence), so expanding KV to n_heads would
    #     materialize a g× cache copy for no parallelism gain.
    qf = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    idx = jnp.arange(cache_len)[None, None, None, :]
    logits = jnp.where(idx < n_valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return shard(out, "batch", None, None), {"k": k_cache, "v": v_cache}
