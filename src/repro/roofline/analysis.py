"""Roofline terms from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = Σ wire_bytes_per_chip(op) / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  Collective bytes are NOT in cost_analysis: we parse
``compiled.as_text()`` (post-GSPMD optimized HLO, per-device shapes) and
price each collective with ring formulas against its replica-group size.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hw import HardwareSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                      r"\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    wire_bytes: float
    line: str = ""


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0.0) + o.wire_bytes
        return out

    def top(self, n: int = 5) -> List[CollectiveOp]:
        return sorted(self.ops, key=lambda o: -o.wire_bytes)[:n]


def _wire_bytes(kind: str, result: int, operand: int, g: int) -> float:
    """Ring-algorithm wire bytes per chip."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return (g - 1) * operand            # operand = per-chip shard
    if kind == "reduce-scatter":
        return (g - 1) * result             # result = per-chip shard
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * operand
    if kind == "all-to-all":
        return (g - 1) / g * operand
    if kind == "collective-permute":
        return float(operand)
    return float(operand)


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Parse the optimized (post-partitioning) HLO for collective ops."""
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(2)
        # skip the -done halves of async pairs (priced at -start)
        if re.search(r"(all-reduce|all-gather|collective-permute|"
                     r"reduce-scatter|all-to-all)-done", stripped):
            continue
        result_part = stripped[:m.end(1)]
        operand_part = stripped[m.end(0) - 1:]
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _TYPE_RE.findall(result_part))
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _TYPE_RE.findall(operand_part))
        gm = _GROUPS_RE.search(stripped)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(stripped)
            g = int(gi.group(2)) if gi else 1
        # async -start results wrap (operand, result, …): prefer operands
        if operand_bytes == 0:
            operand_bytes = result_bytes
        summary.ops.append(CollectiveOp(
            kind=kind, result_bytes=result_bytes,
            operand_bytes=operand_bytes, group_size=g,
            wire_bytes=_wire_bytes(kind, result_bytes, operand_bytes, g),
            line=stripped[:160]))
    return summary


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float                 # analytic useful FLOPs (global)
    model_bytes: float = 0.0           # analytic minimal HBM traffic (global)
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9
    memory_per_chip: Dict[str, float] = field(default_factory=dict)
    collectives_by_kind: Dict[str, float] = field(default_factory=dict)
    top_collectives: List[str] = field(default_factory=list)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): recompute/redundancy waste."""
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful-compute time / step lower bound."""
        t_useful = self.model_flops / (self.chips * self.peak_flops)
        return t_useful / self.t_bound if self.t_bound else 0.0

    @property
    def bandwidth_fraction(self) -> float:
        """For memory-bound (decode) cells: useful-bytes time / bound.

        Useful bytes = the data the op *must* stream (params + caches once);
        1.0 means the step streams nothing it doesn't have to."""
        if not self.model_bytes:
            return 0.0
        t_useful = self.model_bytes / (self.chips * self.hbm_bw)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bandwidth_fraction": self.bandwidth_fraction,
            "model_bytes": self.model_bytes,
            "memory_per_chip": self.memory_per_chip,
            "collectives_by_kind": self.collectives_by_kind,
            "top_collectives": self.top_collectives,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float, model_bytes: float = 0.0,
                     bf16_model: bool = True,
                     hw: HardwareSpec = TPU_V5E) -> RooflineReport:
    from .hlo_walk import walk_hlo
    text = compiled.as_text()
    walked = walk_hlo(text, f32_collectives_as_bf16=bf16_model)
    #                         trip-count-aware (XLA's own cost_analysis
    #                           prices while bodies once — wrong for
    #                           scan-over-layers; see hlo_walk docstring)
    mem = compiled.memory_analysis()
    mem_dict = {
        "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": float(getattr(mem, "temp_size_in_bytes", 0)) +
        float(getattr(mem, "argument_size_in_bytes", 0)),
    }
    by_kind: Dict[str, float] = {}
    agg: Dict[tuple, List[float]] = {}
    for c in walked.collectives:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.wire_bytes * c.count
        key = (c.kind, c.group_size, round(c.wire_bytes))
        agg.setdefault(key, [0.0])[0] += c.count
    top = sorted(agg.items(), key=lambda kv: -kv[0][2] * kv[1][0])[:6]
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=walked.flops, hlo_bytes_per_chip=walked.bytes,
        collective_bytes_per_chip=sum(by_kind.values()),
        model_flops=model_flops, model_bytes=model_bytes,
        peak_flops=hw.peak_flops_bf16, hbm_bw=hw.hbm_bandwidth,
        ici_bw=hw.ici_link_bandwidth * hw.ici_links,
        memory_per_chip=mem_dict,
        collectives_by_kind=by_kind,
        top_collectives=[f"{k[0]} g={k[1]} {k[2]/1e6:.1f}MB ×{int(v[0])}"
                         for k, v in top],
    )


def model_flops_estimate(cfg, shape) -> float:
    """Analytic useful FLOPs (global, per step) — 6·N_active·D for train,
    2·N_active·tokens (+ attention/cache terms) for decode."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        base = 6.0 * n_active * tokens
        # attention: fwd 4·S²·H·hd per layer per seq (QK^T + PV), ×3 for bwd
        if cfg.family not in ("ssm",):
            window = cfg.sliding_window or shape.seq_len
            eff = min(window, shape.seq_len)
            attn = (12.0 * cfg.n_layers * cfg.n_heads * hd *
                    shape.seq_len * eff * shape.global_batch)
            if cfg.family == "hybrid":
                attn *= (cfg.n_layers // cfg.attn_every) / cfg.n_layers
            base += attn
        return base
    if shape.kind == "prefill":
        base = 2.0 * n_active * tokens
        if cfg.family not in ("ssm",):
            window = cfg.sliding_window or shape.seq_len
            eff = min(window, shape.seq_len)
            attn = (4.0 * cfg.n_layers * cfg.n_heads * hd *
                    shape.seq_len * eff * shape.global_batch)
            if cfg.family == "hybrid":
                attn *= (cfg.n_layers // cfg.attn_every) / cfg.n_layers
            base += attn
        return base
    # decode: one token over the whole batch
    base = 2.0 * n_active * shape.global_batch
    if cfg.family not in ("ssm",):
        ctx = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
        layers_with_attn = (cfg.n_layers // cfg.attn_every
                            if cfg.family == "hybrid" else cfg.n_layers)
        base += (4.0 * layers_with_attn * cfg.n_heads * hd * ctx *
                 shape.global_batch)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm:
        d_inner = cfg.ssm.expand * cfg.d_model
        base += 6.0 * cfg.n_layers * d_inner * cfg.ssm.state * \
            shape.global_batch
    return base


def model_bytes_estimate(cfg, shape) -> float:
    """Analytic minimal HBM traffic per step (global).

    Train: params read + grads written + opt state r/w (≈16 B/param) +
    activations written once forward (d_model stream per token).
    Decode: active params read once + KV/SSM cache read once.
    """
    elt = 2.0  # bf16
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        opt = 16.0 * n_total            # fp32 master/m/v read+write
        act = 2.0 * elt * tokens * cfg.d_model * max(cfg.n_layers, 1)
        return elt * (n_total + n_active) + opt + act
    if shape.kind == "prefill":
        act = 2.0 * elt * tokens * cfg.d_model * max(cfg.n_layers, 1)
        return elt * n_active + act
    # decode: stream params + cache once
    cache = 0.0
    if cfg.family not in ("ssm",):
        ctx = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
        layers_with_attn = (cfg.n_layers // cfg.attn_every
                            if cfg.family == "hybrid" else cfg.n_layers)
        cache += (2.0 * layers_with_attn * cfg.n_kv_heads * hd * ctx *
                  shape.global_batch * elt)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm:
        d_inner = cfg.ssm.expand * cfg.d_model
        cache += (4.0 * cfg.n_layers * d_inner * cfg.ssm.state *
                  shape.global_batch)  # f32 state read+write
    return elt * n_active + cache
