"""Roofline analysis from compiled dry-run artifacts."""

from .hw import TPU_V5E
from .analysis import RooflineReport, analyze_compiled, parse_collectives

__all__ = ["TPU_V5E", "RooflineReport", "analyze_compiled",
           "parse_collectives"]
