"""Trip-count-aware cost walker over optimized (post-partitioning) HLO text.

Why not ``compiled.cost_analysis()``: XLA prices a while-loop body ONCE,
but every model here scans over its layer stack, so flops/bytes would be
undercounted by ~n_layers (verified empirically — see EXPERIMENTS.md
§Dry-run).  This walker:

  * splits the module into named computations,
  * prices each op line (dot flops from shapes + contracting dims,
    elementwise/reduce flops, HBM bytes at fusion boundaries),
  * looks operand shapes up at their def sites (operand refs carry no
    types in optimized HLO),
  * multiplies while bodies by ``backend_config.known_trip_count`` and
    recurses through fusion/call sites (flops only — fusion interiors
    live in registers),
  * prices collectives with ring formulas using true operand bytes.

Costs are per-chip: the input is the SPMD-partitioned module.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|"
    r"f8e4m3fn|f8e5m2|token)\[([0-9,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")
_OP_RE = re.compile(r"[=\s)]([a-z][\w\-]*)\(")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# ~1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "not", "xor", "floor", "ceil",
    "round-nearest-afz", "sign", "cosine", "sine", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "clamp", "expm1", "log1p", "logistic", "cbrt", "erf",
}
_ZERO_BYTES_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclass
class CollectiveRecord:
    kind: str
    wire_bytes: float
    group_size: int
    count: float  # trip-weighted occurrences
    example: str = ""


@dataclass
class WalkCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire: float = 0.0
    collectives: List[CollectiveRecord] = field(default_factory=list)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    def add_bytes(self, op: str, b: float):
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b

    def scaled(self, k: float) -> "WalkCost":
        return WalkCost(
            self.flops * k, self.bytes * k, self.collective_wire * k,
            [CollectiveRecord(c.kind, c.wire_bytes, c.group_size,
                              c.count * k, c.example)
             for c in self.collectives],
            {op: b * k for op, b in self.bytes_by_op.items()})

    def __add__(self, o: "WalkCost") -> "WalkCost":
        merged = dict(self.bytes_by_op)
        for op, b in o.bytes_by_op.items():
            merged[op] = merged.get(op, 0.0) + b
        return WalkCost(self.flops + o.flops, self.bytes + o.bytes,
                        self.collective_wire + o.collective_wire,
                        self.collectives + o.collectives, merged)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)[^{]*\{\s*$",
                     line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            body = []
            comps[cur] = body
            if line.startswith("ENTRY"):
                comps["__entry__"] = body
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                body.append(line)
    return comps


def _ring_wire(kind: str, result_b: int, operand_b: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return float((g - 1) * operand_b)
    if kind == "reduce-scatter":
        return float((g - 1) * result_b)
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * operand_b
    if kind == "all-to-all":
        return (g - 1) / g * operand_b
    if kind == "collective-permute":
        return float(operand_b)
    return float(operand_b)


class HloWalker:
    def __init__(self, text: str, f32_collectives_as_bf16: bool = False):
        self.comps = _split_computations(text)
        self._memo: Dict[str, WalkCost] = {}
        self.f32_collectives_as_bf16 = f32_collectives_as_bf16

    def entry_cost(self) -> WalkCost:
        return self.comp_cost("__entry__")

    def comp_cost(self, name: str) -> WalkCost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = WalkCost()  # cycle guard
        lines = self.comps.get(name)
        if lines is None:
            return WalkCost()
        defs: Dict[str, int] = {}
        total = WalkCost()
        for line in lines:
            total = total + self._line_cost(line, defs)
        self._memo[name] = total
        return total

    # -- single op line ------------------------------------------------------
    def _line_cost(self, line: str, defs: Dict[str, int]) -> WalkCost:
        dm = _DEF_RE.match(line)
        if not dm:
            return WalkCost()
        name = dm.group(1)
        eq = line.index("=")
        rest = line[eq + 1:]
        om = _OP_RE.search(line)
        op = om.group(1) if om else ""
        # result type(s): between '=' and the op name
        result_part = rest[:rest.find(op + "(")] if op else rest
        result_bytes = _type_bytes(result_part)
        result_elems = _type_elems(result_part)
        defs[name] = result_bytes

        out = WalkCost()

        # operand bytes via def-site lookup
        open_paren = line.find(op + "(") + len(op) if op else -1
        operand_text = line[open_paren:line.find(")", open_paren)] \
            if op else ""
        operand_names = _OPERANDS_RE.findall(operand_text)
        operand_bytes = sum(defs.get(n, 0) for n in operand_names)

        # dtype promotion artifacts: XLA:CPU upconverts bf16 operands to
        # f32 (dots are f32-only on CPU); on the TPU target bf16 is native
        # and these converts don't exist.  Price a pure convert at zero
        # traffic and propagate the NARROW dtype's footprint to consumers.
        if op == "convert":
            defs[name] = min(result_bytes, operand_bytes or result_bytes)
            return out
        if op == "fusion" and self._is_pure_convert(line):
            defs[name] = min(result_bytes, operand_bytes or result_bytes)
            return out

        if op == "while":
            wm = _WHILE_RE.search(line)
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            if wm:
                cond = self.comp_cost(wm.group(1))
                body = self.comp_cost(wm.group(2))
                out = out + (cond + body).scaled(trip)
            return out

        if op in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                  "reduce-window", "select-and-scatter", "scatter",
                  "conditional"):
            callees = _CALLS_RE.findall(line) + _TO_APPLY_RE.findall(line)
            for cm in callees:
                sub = self.comp_cost(cm)
                # fusion interiors: flops only (bytes live at the boundary)
                out.flops += sub.flops
                out.collective_wire += sub.collective_wire
                out.collectives += sub.collectives
            # fusion traffic model: a kLoop fusion streams its OUTPUT once
            # and reads each input according to the interior access
            # pattern — full for reductions, slice-sized for interior
            # dynamic-slices, ≈result-sized for elementwise.
            op_byte_list = [defs.get(n, 0) for n in operand_names]
            biggest = max(op_byte_list, default=0)
            interior = [l for cm in callees for l in self.comps.get(cm, ())]
            has_dus = any("dynamic-update-slice(" in l for l in interior)
            has_reduce = any(re.search(r"[=\s]reduce(-window)?\(", l)
                             for l in interior)
            aliased = any(b == result_bytes for b in op_byte_list)
            inplace = op == "scatter" or (has_dus and aliased
                                          and result_bytes > 0)
            if inplace:
                upd = self._dus_update_bytes(callees) or max(
                    result_bytes // 64, 1)
                # write the slice + read each other operand at ≤ slice size
                reads = sum(min(b, upd) for b in op_byte_list) - \
                    min(result_bytes, upd)
                out.add_bytes(op + "(inplace)", 2.0 * upd + reads)
            elif op == "reduce" or has_reduce:
                out.add_bytes(op, result_bytes + operand_bytes)
            else:
                reads = sum(min(b, result_bytes) for b in op_byte_list)
                out.add_bytes(op, result_bytes + reads)
            if op == "reduce":
                out.flops += sum(op_byte_list) / 4.0
            return out

        if op == "dynamic-update-slice":
            # in-place: read+write the update slice only
            upd = defs.get(operand_names[1], 0) if len(operand_names) > 1 \
                else 0
            out.add_bytes(op, 2.0 * upd)
            return out

        if op in ("dynamic-slice", "slice", "gather", "concatenate",
                  "reshape", "transpose", "broadcast", "reverse", "copy"):
            out.add_bytes(op, 2.0 * result_bytes)
            return out

        if op == "dot":
            out.flops += self._dot_flops(line, result_elems, defs,
                                         operand_names)
            out.add_bytes(op, result_bytes + operand_bytes)
            return out

        if op == "convolution":
            # not used by the models; price like a dot on result elems
            out.flops += 2.0 * result_elems
            out.add_bytes(op, result_bytes + operand_bytes)
            return out

        if any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                return out
            kind = next(c for c in _COLLECTIVES if op.startswith(c))
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len(gm.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                g = int(gi.group(2)) if gi else 1
            ob = operand_bytes or result_bytes
            rb = result_bytes
            # XLA:CPU promotes bf16 collectives to f32; the TPU target
            # reduces bf16 natively.  When the module is a bf16 model,
            # price f32 collective payloads at bf16 width.
            if self.f32_collectives_as_bf16 and " f32[" in line[:120]:
                ob //= 2
                rb //= 2
            wire = _ring_wire(kind, rb, ob, g)
            out.collective_wire += wire
            out.add_bytes(op, rb + ob)
            out.collectives.append(CollectiveRecord(kind, wire, g, 1.0,
                                                    line.strip()[:140]))
            return out

        if op in _ZERO_BYTES_OPS:
            return out

        if op in _ELEMENTWISE:
            out.flops += result_elems
        out.add_bytes(op or "?", result_bytes + operand_bytes)
        return out

    _PURE_CONVERT_OPS = {"parameter", "convert", "bitcast", "copy",
                         "transpose", "reshape"}

    def _is_pure_convert(self, line: str) -> bool:
        """Fusion wrapping only a dtype conversion (+ layout ops)."""
        callees = _CALLS_RE.findall(line)
        if not callees:
            return False
        memo = getattr(self, "_pc_memo", None)
        if memo is None:
            memo = self._pc_memo = {}
        cm = callees[0]
        if cm in memo:
            return memo[cm]
        ok = True
        saw_convert = False
        for l in self.comps.get(cm, ()):
            om = _OP_RE.search(l)
            lop = om.group(1) if om else ""
            if not lop:
                continue
            if lop == "convert":
                saw_convert = True
            elif lop not in self._PURE_CONVERT_OPS:
                ok = False
                break
        memo[cm] = ok and saw_convert
        return memo[cm]

    def _dus_update_bytes(self, callees: List[str]) -> int:
        """Bytes of the update operand of an interior dynamic-update-slice."""
        for cm in callees:
            cached = getattr(self, "_dus_memo", {}).get(cm)
            if cached is not None:
                return cached
            local: Dict[str, int] = {}
            found = 0
            for l in self.comps.get(cm, ()):
                dm = _DEF_RE.match(l)
                if not dm:
                    continue
                om = _OP_RE.search(l)
                lop = om.group(1) if om else ""
                eq = l.index("=")
                rest = l[eq + 1:]
                rpart = rest[:rest.find(lop + "(")] if lop else rest
                local[dm.group(1)] = _type_bytes(rpart)
                if lop == "dynamic-update-slice":
                    open_p = l.find(lop + "(") + len(lop)
                    otext = l[open_p:l.find(")", open_p)]
                    onames = _OPERANDS_RE.findall(otext)
                    if len(onames) > 1:
                        found = max(found, local.get(onames[1], 0))
            if not hasattr(self, "_dus_memo"):
                self._dus_memo = {}
            self._dus_memo[cm] = found
            if found:
                return found
        return 0

    def _dot_flops(self, line: str, result_elems: int, defs, operand_names
                   ) -> float:
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        if not m:
            return 2.0 * result_elems
        cdims = [int(x) for x in m.group(1).split(",") if x]
        # lhs shape: first operand's def — re-parse dims from its type is
        # not stored; fall back to parsing the operand type if present in
        # the line, else estimate from bytes.  Optimized HLO keeps operand
        # types out of the line, so we track elem shapes separately.
        shp = self._shape_of.get(operand_names[0]) if hasattr(
            self, "_shape_of") else None
        if shp:
            contracted = 1
            for c in cdims:
                contracted *= shp[c]
            return 2.0 * result_elems * contracted
        return 2.0 * result_elems  # conservative


def walk_hlo(text: str, f32_collectives_as_bf16: bool = False) -> WalkCost:
    """Full-module per-chip cost with trip-count awareness."""
    walker = HloWalker(text, f32_collectives_as_bf16)
    _attach_shapes(walker)
    return walker.entry_cost()


def _attach_shapes(walker: HloWalker):
    """Second metadata pass: record full dim tuples per def for dot pricing."""
    shape_of: Dict[str, Tuple[int, ...]] = {}
    for lines in walker.comps.values():
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            om = _OP_RE.search(line)
            op = om.group(1) if om else ""
            eq = line.index("=")
            rest = line[eq + 1:]
            result_part = rest[:rest.find(op + "(")] if op else rest
            shapes = _SHAPE_RE.findall(result_part)
            if len(shapes) == 1:
                dims = tuple(int(x) for x in shapes[0][1].split(",")
                             if x) or ()
                shape_of[dm.group(1)] = dims
    walker._shape_of = shape_of
