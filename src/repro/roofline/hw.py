"""Hardware constants for the roofline model (assignment-specified)."""

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bandwidth: float        # B/s per chip
    ici_link_bandwidth: float   # B/s per link
    ici_links: int              # links per chip participating in a collective
    hbm_bytes: float            # capacity per chip


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=1,     # conservative single-link accounting (see DESIGN.md)
    hbm_bytes=16e9,
)
