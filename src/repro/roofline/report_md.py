"""Render results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.roofline.report_md [tag]
"""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(results_dir: str, tag: str = "baseline") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, f"*__{tag}.json"))):
        rows.append(json.load(open(f)))
    return rows


def render(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
           "bottleneck | MFU-bound | BW-frac | useful/HLO | mem/chip (GiB) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("mesh") != mesh and d.get("status") != "skipped":
            continue
        if d.get("status") == "skipped":
            if mesh == "16x16":
                out.append(f"| {d['arch']} | {d['shape']} | — | — | — | "
                           f"skipped: {d['reason']} | — | — | — | — |")
            continue
        r = d["roofline"]
        mem = d["memory_analysis"]
        peak = (mem["argument_bytes"] + mem["temp_bytes"]) / 2 ** 30
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['t_compute']*1e3:.0f} | "
            f"{r['t_memory']*1e3:.0f} | {r['t_collective']*1e3:.0f} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{r['bandwidth_fraction']:.3f} | {r['useful_flops_ratio']:.2f} | "
            f"{peak:.1f} |")
    return "\n".join(out)


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    results_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "results", "dryrun")
    rows = load(results_dir, tag)
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh} ({tag})\n")
        print(render(rows, mesh))


if __name__ == "__main__":
    main()
