"""Batched trigger pipeline: per-update cost vs batch size T (§6 batching).

For each program (OLS, matrix powers) and T ∈ {1, 4, 16, 64}, times a
stream of T rank-1 updates applied

  * sequentially — T trigger firings, each view swept T times, and
  * batched      — factors stacked to rank T, ONE trigger firing, each
                   view swept once (``IncrementalEngine.apply_updates``).

Per-update time for the batched path must fall as T grows (amortized
dispatch + single memory pass); results land in
``BENCH_trigger_pipeline.json`` so the perf trajectory is tracked across
PRs.  ``--quick`` runs a reduced sweep for the CI smoke budget.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ols import build_ols_program
from repro.core.iterative import matrix_powers
from repro.core.runtime import IncrementalEngine
from repro.data.updates import UpdateStream

try:  # runnable both as a module and as a script
    from .common import emit
except ImportError:  # pragma: no cover
    from common import emit


def _make_updates(n: int, m: int, count: int, seed: int
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    it = iter(UpdateStream(n=n, m=m, scale=0.01, seed=seed))
    return [next(it) for _ in range(count)]


def _time_best(fn, repeats: int, inner: int = 3) -> float:
    """Min over ``repeats`` of the mean over ``inner`` consecutive calls.

    The inner mean smooths single-call scheduler hiccups; the outer min
    drops whole bad windows — CPU containers are noisy and the CI gate
    asserts strict monotonicity in T.
    """
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def bench_program(name: str, build_program, inputs_fn, input_name: str,
                  n: int, m: int, batch_sizes, repeats: int
                  ) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for t_batch in batch_sizes:
        ups = _make_updates(n, m, t_batch, seed=13 + t_batch)

        eng_seq = IncrementalEngine(build_program())
        eng_seq.initialize(inputs_fn())
        eng_bat = IncrementalEngine(build_program())
        eng_bat.initialize(inputs_fn())

        def seq():
            for u, v in ups:
                eng_seq.apply_update(input_name, jnp.asarray(u),
                                     jnp.asarray(v))
            jax.block_until_ready(eng_seq.views)

        def bat():
            eng_bat.apply_updates(input_name, ups)
            jax.block_until_ready(eng_bat.views)

        seq()  # jit warmup (per-update trigger)
        bat()  # jit warmup (per-bucket trigger)
        t_seq = _time_best(seq, repeats) / t_batch
        t_bat = _time_best(bat, repeats) / t_batch
        out[str(t_batch)] = {
            "seq_us_per_update": t_seq * 1e6,
            "batched_us_per_update": t_bat * 1e6,
            "batch_speedup": t_seq / t_bat,
        }
        emit(f"trigger_pipeline_{name}_T{t_batch}", t_bat * 1e6,
             f"seq_us={t_seq*1e6:.1f};speedup={t_seq/t_bat:.2f}x")
    return out


def ols_inputs(m: int, n: int):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(m, n)).astype(np.float32)
    Y = rng.normal(size=(m, 1)).astype(np.float32)
    return {"X": jnp.asarray(X), "Y": jnp.asarray(Y)}


def powers_inputs(n: int):
    rng = np.random.default_rng(0)
    A = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n)).astype(np.float32)
    return {"A": jnp.asarray(A)}


def main(quick: bool = False):
    n = 96 if quick else 128
    batch_sizes = (1, 4, 16) if quick else (1, 4, 16, 64)
    repeats = 3 if quick else 6
    results = {
        "config": {"n": n, "batch_sizes": list(batch_sizes),
                   "repeats": repeats, "backend": jax.default_backend()},
        "ols": bench_program(
            "ols", lambda: build_ols_program(2 * n, n, 1),
            lambda: ols_inputs(2 * n, n), "X",
            2 * n, n, batch_sizes, repeats),
        "matrix_powers": bench_program(
            "matrix_powers",
            lambda: matrix_powers(k=8, n=n, model="exp"),
            lambda: powers_inputs(n), "A",
            n, n, batch_sizes, repeats),
    }
    with open("BENCH_trigger_pipeline.json", "w") as f:
        json.dump(results, f, indent=2)
    print("wrote BENCH_trigger_pipeline.json")
    return results


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
