"""Paper Table 4: batch updates with Zipf-distributed row frequency.

A batch of 1000 rank-1 row updates collapses to a rank-r update where r =
number of *distinct* rows touched; skewed (high Zipf factor) batches stay
low-rank and cheap, uniform batches approach full rank and INCR loses its
advantage — exactly the paper's observation.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import MatrixPowers
from repro.data.updates import UpdateStream
from .common import emit


def merge_batch_by_row(stream: UpdateStream, count: int):
    """Collapse ``count`` rank-1 row updates into one rank-r update with
    r = distinct rows (sum deltas per row) — the LINVIEW batching rule."""
    rng = np.random.default_rng(stream.seed)
    per_row = {}
    for _ in range(count):
        u, v = stream.next_update(rng)
        row = int(np.argmax(u[:, 0]))
        per_row[row] = per_row.get(row, 0) + v[:, 0]
    rows = sorted(per_row)
    u = np.zeros((stream.n, len(rows)), np.float32)
    v = np.zeros((stream.m, len(rows)), np.float32)
    for j, r in enumerate(rows):
        u[r, j] = 1.0
        v[:, j] = per_row[r]
    return u, v


def main(n: int = 256, k: int = 16, batch: int = 1000):
    for zipf in (5.0, 4.0, 3.0, 2.0, 1.2, 0.0):
        stream = UpdateStream(n=n, m=n, zipf=zipf or None, scale=0.01,
                              seed=11)
        u, v = merge_batch_by_row(stream, batch)
        rank = u.shape[1]
        app = MatrixPowers(n=n, k=k, model="exp", rank=rank)
        app.initialize(MatrixPowers.synthesize(n, seed=0))
        uj, vj = jnp.asarray(u), jnp.asarray(v)
        jax.block_until_ready(app.update(uj, vj))   # warm
        t0 = time.perf_counter()
        jax.block_until_ready(app.update(uj, vj))
        t_incr = time.perf_counter() - t0
        jax.block_until_ready(app.update_reeval(uj, vj))
        t0 = time.perf_counter()
        jax.block_until_ready(app.update_reeval(uj, vj))
        t_reeval = time.perf_counter() - t0
        emit(f"table4_zipf{zipf}", t_incr * 1e6,
             f"rank={rank};reeval_us={t_reeval*1e6:.1f};"
             f"speedup={t_reeval/t_incr:.2f}x")


if __name__ == "__main__":
    main()
