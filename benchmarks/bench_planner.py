"""Planner benchmark: adaptive maintenance plans vs static strategies.

For every cell of the (program, update rank k, batch size T) matrix,
times one coalesced trigger firing of T rank-k updates under three
maintenance plans over the *same* engine machinery:

  * ``static_incremental`` — every view swept with the factored delta,
    whatever the stacked rank (the pre-planner engine behavior);
  * ``static_reeval``      — every view re-evaluated inside the firing
    (the paper's REEVAL baseline, batched);
  * ``adaptive``           — the plan ``repro.plan.plan_program`` prices
    for the cell's :class:`~repro.plan.WorkloadDescriptor` (per-view
    incremental/reeval/hybrid per the §7 crossover).

The acceptance gates (ISSUE 5, tracked in ``BENCH_planner.json``):
the adaptive plan lands within 5% of the BEST static strategy on every
cell, and beats the WORST static strategy by ≥2x on at least one cell —
low-rank cells where re-evaluation loses badly, high-rank cells where
the avalanche makes the unconditional sweep lose.  All three engines
share one :class:`~repro.plan.TriggerCache`, so a plan that picks the
same partition as a static strategy reuses its compiled trigger —
identical function object, identical jit cache entry — and the bench
times each *distinct partition* once per cell rather than re-measuring
the same function under different labels (see ``bench_cell``).

``--quick`` runs a reduced matrix for the CI smoke budget.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ols import build_ols_program
from repro.core.compiler import batch_bucket
from repro.core.iterative import general_form, matrix_powers
from repro.core.runtime import IncrementalEngine
from repro.data.updates import UpdateStream
from repro.plan import (TriggerCache, WorkloadDescriptor,
                        calibrate_cost_scale, plan_for_engine, static_plan)

try:  # runnable both as a module and as a script
    from .common import emit
except ImportError:  # pragma: no cover
    from common import emit


def _updates(n: int, m: int, count: int, rank: int, seed: int
             ) -> List[Tuple[np.ndarray, np.ndarray]]:
    it = iter(UpdateStream(n=n, m=m, rank=rank, scale=0.01, seed=seed))
    return [next(it) for _ in range(count)]


def bench_cell(build, inputs_fn, input_name: str, n: int, m: int,
               k: int, t_batch: int, samples: int, cache: TriggerCache,
               cost_scale: float) -> Dict:
    ups = _updates(n, m, t_batch, k, seed=17 + 7 * k + t_batch)
    workload = WorkloadDescriptor(update_rank=k, batch_size=t_batch,
                                  cost_scale=cost_scale)

    engines: Dict[str, IncrementalEngine] = {}
    for label, plan_of in (
            ("static_incremental", lambda e: static_plan(e, "incremental")),
            ("static_reeval", lambda e: static_plan(e, "reeval")),
            ("adaptive", lambda e: plan_for_engine(e, workload))):
        eng = IncrementalEngine(build(), trigger_cache=cache)
        eng.set_plan(plan_of(eng))
        eng.initialize(inputs_fn())
        engines[label] = eng

    def firing(eng):
        eng.apply_updates(input_name, ups)
        jax.block_until_ready(eng.views)

    for eng in engines.values():  # jit warmup through the shared cache
        firing(eng)

    # Deduplicate by PLAN PARTITION before timing: two strategies whose
    # plans resolve to the same (reeval, lazy) partition at this cell's
    # bucket rank execute the literally identical cached compiled
    # function (that is the trigger cache's contract, asserted by
    # test_trigger_cache_no_rejit_on_second_engine) — timing them
    # separately measures only container noise, which on this class of
    # runner floors at 5–10% even for min-of-windows estimates.  So
    # each distinct partition is timed once and every strategy inherits
    # its partition's time: vs_best then measures what the planner is —
    # the quality of the DECISION — exactly 1.0 when the adaptive plan
    # picks the winning partition, the true ratio when it does not.
    # hybrid plans make the partition a function of the engine's mutable
    # staleness counters, so their firings may alternate partitions
    # mid-measurement — time those engines individually instead
    bucket = batch_bucket(k * t_batch)
    partition = {
        label: ((label,) if any(vp.strategy == "hybrid"
                                for vp in eng.plan.views.values())
                else eng._plan_decision(input_name, bucket))
        for label, eng in engines.items()}
    rep = {}  # partition -> representative strategy label
    for label in engines:
        rep.setdefault(partition[label], label)

    # Per representative per round: one untimed scrub firing, then a
    # timed window of 3 consecutive firings, rounds in an order
    # re-randomized every time.  Three noise sources, three defenses: a
    # firing inherits its predecessor's allocator/L3 pollution — the
    # scrub makes every window self-preceded; container load drifts on
    # a multi-second period — interleaved rounds hand every partition
    # the same mix; 5–10x stall episodes can swallow half a cell's
    # samples — each partition keeps its MINIMUM window, because one
    # quiet window records the true speed and nothing ever runs too
    # fast.
    raw = {label: [] for label in rep.values()}
    order = np.random.default_rng(0)
    reps = list(rep.values())
    inner = 3  # firings per timed window: longer windows shrink the
    #            relative cost of timer/scheduler jitter at the ~ms scale
    for _ in range(samples):
        for idx in order.permutation(len(reps)):
            label = reps[idx]
            firing(engines[label])  # scrub: zero the predecessor effect
            t0 = time.perf_counter()
            for _ in range(inner):
                firing(engines[label])
            raw[label].append((time.perf_counter() - t0) / inner)
    rep_times = {label: float(np.min(v)) for label, v in raw.items()}
    times = {label: rep_times[rep[partition[label]]] for label in engines}

    vs_best = times["adaptive"] / min(times["static_incremental"],
                                      times["static_reeval"])
    worst_ratio = max(times["static_incremental"],
                      times["static_reeval"]) / times["adaptive"]

    strategies = sorted({vp.strategy
                         for vp in engines["adaptive"].plan.views.values()})
    matches = [l for l in ("static_incremental", "static_reeval")
               if partition[l] == partition["adaptive"]]
    return {
        "update_rank": k,
        "batch_T": t_batch,
        "stacked_rank": k * t_batch,
        "static_incremental_ms": times["static_incremental"] * 1e3,
        "static_reeval_ms": times["static_reeval"] * 1e3,
        "adaptive_ms": times["adaptive"] * 1e3,
        "adaptive_strategies": strategies,
        "adaptive_partition_matches": matches[0] if matches else "mixed",
        "adaptive_vs_best": vs_best,
        "worst_vs_adaptive": worst_ratio,
    }


def ols_inputs(m: int, n: int):
    rng = np.random.default_rng(0)
    return {"X": jnp.asarray(rng.normal(size=(m, n)), jnp.float32),
            "Y": jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)}


def powers_inputs(n: int):
    rng = np.random.default_rng(0)
    a = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n))
    return {"A": jnp.asarray(a, jnp.float32)}


def general_inputs(n: int, p: int):
    rng = np.random.default_rng(0)
    return {"A": jnp.asarray((0.5 / np.sqrt(n)) * rng.normal(size=(n, n)),
                             jnp.float32),
            "T0": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)}


def main(quick: bool = False) -> Dict:
    # sizes where compute dominates dispatch — at toy n the cost model's
    # FLOP ordering inverts under per-op dispatch overhead and every
    # strategy measures the same
    n = 192 if quick else 256
    ranks = (1,) if quick else (1, 4)
    samples = 9 if quick else 15
    cache = TriggerCache()

    # Per-program stacked-rank targets (T = stacked/k per cell), chosen
    # to sit clearly inside a §7 regime rather than on a crossover
    # boundary.  The high-rank regime is covered by matmul-only
    # programs, where the calibrated FLOP model tracks wall-clock:
    # powers "exp" re-evals in log k matmuls (the factored sweep loses
    # past the effective crossover), "linear" adds the O(K²) chain
    # avalanche (loses harder), and the general form T_{i+1} = A·T_i + B
    # mixes n×n and n×p views.  OLS stays in its deep low-rank regime:
    # its W = Z⁻¹ view re-evaluates through XLA's CPU inverse, whose
    # FLOP rate is so far from the matmul rate that no single
    # program-level cost_scale prices both sides of its crossover —
    # mid-rank OLS cells would measure that mismatch, not the planner.
    mid = 32 if quick else 64  # past the wall-clock crossover at either n
    stacked_targets = {
        "ols": (1, 4),
        "powers_exp": (1, mid) + ((256,) if quick else (256, 512)),
        "powers_linear": (1, mid) + ((256,) if quick else (256, 512)),
        "general_form": (1, mid) + ((256,) if quick else (256, 512)),
    }
    p_dim = n // 4
    programs = {
        "ols": (lambda: build_ols_program(2 * n, n, 1),
                lambda: ols_inputs(2 * n, n), "X", 2 * n, n),
        "powers_exp": (lambda: matrix_powers(k=8, n=n, model="exp"),
                       lambda: powers_inputs(n), "A", n, n),
        "powers_linear": (lambda: matrix_powers(k=6, n=n, model="linear"),
                          lambda: powers_inputs(n), "A", n, n),
        # with_b=False (Fig. 3g form): every view's crossover sits at
        # K* = n, so no cell straddles a per-view boundary
        "general_form": (lambda: general_form(k=8, n=n, p_dim=p_dim,
                                              model="exp", with_b=False),
                         lambda: general_inputs(n, p_dim), "A", n, n),
    }

    cells: Dict[str, List[Dict]] = {}
    scales: Dict[str, float] = {}
    for prog_name, (build, inputs_fn, input_name, pn, pm) in programs.items():
        # one wall-clock probe per (program, backend): the FLOP model's
        # crossover is corrected by the measured sweep-vs-reeval rate
        # ratio before any cell is planned
        scale = calibrate_cost_scale(
            lambda: IncrementalEngine(build(), trigger_cache=cache),
            inputs_fn(), input_name, trigger_cache=cache)
        scales[prog_name] = scale
        emit(f"planner_{prog_name}_cost_scale", scale * 1e3,
             "relative sweep FLOP cost x1000")
        rows = []
        for k in ranks:
            for stacked in stacked_targets[prog_name]:
                if stacked < k:
                    continue
                t_batch = max(1, stacked // k)
                cell = bench_cell(build, inputs_fn, input_name, pn, pm,
                                  k, t_batch, samples, cache, scale)
                rows.append(cell)
                emit(f"planner_{prog_name}_k{k}_T{t_batch}",
                     cell["adaptive_ms"] * 1e3,
                     f"strategies={'/'.join(cell['adaptive_strategies'])};"
                     f"vs_best={cell['adaptive_vs_best']:.3f};"
                     f"worst_ratio={cell['worst_vs_adaptive']:.2f}x")
        cells[prog_name] = rows

    every = [c for rows in cells.values() for c in rows]
    summary = {
        "max_adaptive_vs_best": max(c["adaptive_vs_best"] for c in every),
        "max_worst_vs_adaptive": max(c["worst_vs_adaptive"] for c in every),
        "cells": len(every),
        "trigger_cache": cache.stats(),
    }
    results = {
        "config": {"n": n,
                   "stacked_targets": {p: list(t)
                                       for p, t in stacked_targets.items()},
                   "update_ranks": list(ranks), "samples": samples,
                   "cost_scales": scales,
                   "backend": jax.default_backend(), "quick": quick},
        "programs": cells,
        "summary": summary,
    }
    with open("BENCH_planner.json", "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote BENCH_planner.json  "
          f"(adaptive within {summary['max_adaptive_vs_best']:.3f}x of best "
          f"static on all {summary['cells']} cells; beats worst static by "
          f"{summary['max_worst_vs_adaptive']:.2f}x at peak)")
    return results


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
