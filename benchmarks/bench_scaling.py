"""Paper Fig. 3f: scalability with cluster size.

On this container the 'cluster' is the dry-run mesh: we report, from the
compiled artifacts, how the distributed-IVM trigger's collective bytes and
the re-evaluation matmul's collective bytes scale with mesh width — the
structural version of the paper's grid-size sweep (their finding: INCR is
far less sensitive to node count than REEVAL, because only O(nk) factors
move).  Executed numerically on an 8-device host mesh.
"""

from __future__ import annotations

import subprocess
import sys
import os
import textwrap

from .common import emit

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devs}"
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core import IncrementalEngine
from repro.core.iterative import matrix_powers
from repro.dist.ivm_shard import build_distributed_trigger, distributed_reeval_matmul
from repro.roofline.hlo_walk import walk_hlo

n, k = 512, 8
rng = np.random.default_rng(0)
A = jnp.asarray(rng.normal(size=(n, n)) / 22, jnp.float32)
u = jnp.asarray(rng.normal(size=(n, 1)) * .1, jnp.float32)
v = jnp.asarray(rng.normal(size=(n, 1)) * .1, jnp.float32)

prog = matrix_powers(k=k, n=n, model="exp")
eng = IncrementalEngine(prog, {{"A": 1}})
eng.initialize({{"A": A}})
mesh = jax.make_mesh(({devs},), ("rows",))
trig = eng.compiled.triggers["A"]
fn = build_distributed_trigger(trig, eng.program, mesh, jit=False)
lowered = jax.jit(fn).lower(dict(eng.views), u, v)
w = walk_hlo(lowered.compile().as_text())
# reeval: one distributed n×n matmul per statement
mm = distributed_reeval_matmul(mesh, jit=False)
lw2 = jax.jit(mm).lower(A, A)
w2 = walk_hlo(lw2.compile().as_text())
print(f"RESULT {{w.collective_wire:.0f}} {{w2.collective_wire * {nstat}:.0f}}")
"""


def main():
    nstat = 3  # P2, P4, P8 statements
    for devs in (2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "src")
        env.pop("XLA_FLAGS", None)
        res = subprocess.run(
            [sys.executable, "-c", SCRIPT.format(devs=devs, nstat=nstat)],
            env=env, capture_output=True, text=True, timeout=600)
        line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            emit(f"fig3f_mesh{devs}", -1.0, "FAILED:" + res.stderr[-200:])
            continue
        incr_bytes, reeval_bytes = map(float, line[0].split()[1:])
        emit(f"fig3f_mesh{devs}_incr_collective_KB", incr_bytes / 1e3,
             f"reeval_KB={reeval_bytes/1e3:.0f};"
             f"ratio={reeval_bytes/max(incr_bytes,1):.1f}")


if __name__ == "__main__":
    main()
