"""repro.fivm benchmark + smoke gates (``BENCH_fivm.json``).

Two measurements, both CI-gated under ``--quick`` (the ``fivm`` job):

  1. **Ring refresh vs retrain-from-scratch** — the ISSUE 10
     acceptance cell.  A ridge model over a maintained gram ring
     absorbs ``k`` pending insert/delete events *past the §7 solver
     crossover* (``k > n/6``, so the priced strategy is the honest
     ``n³/3`` refactor, not the flattering rank-one-update arm) and
     refreshes from the maintained ``G``/``XY``; retrain-from-scratch
     rebuilds ``XᵀX`` from the ``M`` live rows before factoring.  The
     maintained ring skips the ``O(M·n²)`` gram rebuild, so past-
     crossover refresh must be **≥5x** faster at ``M ≫ n`` or
     maintaining the ring is decorative.  Both sides are also checked
     against each other to 1e-5 (a fast wrong answer is not a win).

  2. **Decoupled-refresh serve sustain** — the serve contract
     (docs/fivm.md): an ``order=2`` ring banks every arriving example
     as a factored delta and pays the fold + re-solve at read time;
     the same ring shape runs as a guarded fleet tenant fed through
     admission.  Gates: every event admitted (no sheds/queue-full at
     the bench rate), zero pending after drain with staleness within
     the tenant SLO, and the read-time re-solve matching batch retrain
     to 1e-5 — sustained ingest with correct read-time models under
     the existing fleet SLO accounting.

Ratio gates use medians of per-round ratios (shared-runner noise).
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict

import numpy as np

import jax

from repro.core import solver_crossover_rank
from repro.data import labeled_stream
from repro.fivm import RidgeSolver, Ring, RingSpec
from repro.fivm.registry import RingRegistry, submit_event
from repro.fleet import FleetConfig, FleetScheduler


def retrain_f32(X: np.ndarray, Y: np.ndarray, lam: float) -> np.ndarray:
    """Retrain-from-scratch at the ring's own precision: gram rebuild
    from raw rows + Cholesky + solve (the timed baseline)."""
    G = X.T @ X + np.float32(lam) * np.eye(X.shape[1], dtype=np.float32)
    L = np.linalg.cholesky(G.astype(np.float64))
    z = np.linalg.solve(L, (X.T @ Y).astype(np.float64))
    return np.linalg.solve(L.T, z).astype(np.float32)


def refresh_vs_retrain(quick: bool) -> Dict[str, object]:
    n = 96 if quick else 128
    m = 49152 if quick else 65536
    rounds = 5 if quick else 10
    lam = 0.5
    k_past = 2 * solver_crossover_rank(n)      # past the n/6 crossover
    spec = RingSpec(features=n, targets=1, capacity=m)
    ring = Ring(spec)
    rng = np.random.default_rng(0)
    fill = int(0.9 * m)
    X0 = rng.normal(size=(fill, n)).astype(np.float32)
    Y0 = (X0 @ rng.normal(size=(n, 1)).astype(np.float32)
          + 0.01 * rng.normal(size=(fill, 1)).astype(np.float32))
    ring.bootstrap(X0, Y0)
    stream = labeled_stream(n, capacity=m, churn=0.0, seed=1)
    # align the stream's ledger with the bootstrapped slots
    stream._live = {i: (X0[i], Y0[i]) for i in range(fill)}
    stream._free = list(range(fill, m))
    stream.churn = 0.45
    solver = RidgeSolver(ring, lam=lam)
    solver.coefficients()                      # warm: compile + factor
    ratios, refresh_s, retrain_s = [], [], []
    strategies = []
    for _ in range(rounds):
        ring.apply_events(stream.events(k_past))
        # settle jax's async dispatch of the ingest firings: their cost
        # belongs to ingest, not to the refresh being timed
        jax.block_until_ready(ring.engine.views)
        t0 = time.perf_counter()
        B = solver.coefficients()
        dt_refresh = time.perf_counter() - t0
        Xl, Yl = ring.live_data()
        t0 = time.perf_counter()
        B_scratch = retrain_f32(Xl, Yl, lam)
        dt_retrain = time.perf_counter() - t0
        err = float(np.abs(B - B_scratch).max())
        # float32 gram accumulation error grows ~sqrt(M); the strict
        # 1e-5 criterion is enforced in tests/test_fivm.py at test scale
        tol = 1e-5 * max(1.0, float(np.sqrt(m / 8192.0)))
        assert err < tol, f"refresh diverged from retrain: {err:.2e}"
        ratios.append(dt_retrain / dt_refresh)
        refresh_s.append(dt_refresh)
        retrain_s.append(dt_retrain)
        strategies.append(solver.stats.strategy_log[-1])
    return {
        "n": n, "m_live": int(ring.count()), "pending_per_round": k_past,
        "crossover_rank": solver_crossover_rank(n),
        "rounds": rounds,
        "refresh_ms": float(np.median(refresh_s)) * 1e3,
        "retrain_ms": float(np.median(retrain_s)) * 1e3,
        "speedup": float(np.median(ratios)),
        "strategies": strategies,
    }


def decoupled_serve(quick: bool) -> Dict[str, object]:
    n = 16 if quick else 32
    cap = 128 if quick else 256
    bursts = 6 if quick else 10
    burst = 24 if quick else 48
    lam = 0.2
    spec = RingSpec(features=n, targets=1, capacity=cap, model_slots=1)

    # (a) local decoupled ring: bank on ingest, fold + re-solve on read
    ring = Ring(spec, order=2)
    stream = labeled_stream(n, capacity=cap, churn=0.3, seed=2)
    solver = RidgeSolver(ring, lam=lam)
    ring.apply_events(stream.events(8))
    solver.coefficients()                      # warm compile paths
    ingest_s, read_s, read_errs = [], [], []
    for _ in range(bursts):
        evs = stream.events(burst)
        t0 = time.perf_counter()
        ring.apply_events(evs)
        ingest_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        B = solver.coefficients()
        read_s.append(time.perf_counter() - t0)
        Xl, Yl = ring.live_data()
        read_errs.append(float(np.abs(B - retrain_f32(Xl, Yl, lam)).max()))
    events = bursts * burst

    # (b) fleet-hosted ring tenant: admission + lease-claimed refresh +
    # SLO staleness accounting (deterministic drive)
    fleet = FleetScheduler(FleetConfig(lease_ttl=0.5))
    reg = RingRegistry()
    reg.add_fleet_tenant(fleet, spec, "fivm-bench", slo_s=1.0)
    stream2 = labeled_stream(n, capacity=cap, churn=0.3, seed=3)
    t0 = time.perf_counter()
    decisions: Dict[str, int] = {}
    for _ in range(bursts):           # sustained drive: ingest bursts
        for ev in stream2.events(burst):   # drain between (workers
            for d in submit_event(fleet, "fivm-bench", cap, ev):   # keep
                decisions[d] = decisions.get(d, 0) + 1             # pace)
        fleet.run_until_idle()
    fleet_dt = time.perf_counter() - t0
    health = fleet.tenant_health()[0]
    return {
        "events": events, "bursts": bursts,
        "ingest_us_per_event": 1e6 * float(np.sum(ingest_s)) / events,
        "read_ms": float(np.median(read_s)) * 1e3,
        "read_err_max": max(read_errs),
        "folds": ring.stats.folds,
        "fleet_events_per_s": events / fleet_dt,
        "fleet_decisions": decisions,
        "fleet_pending": health["pending"],
        "fleet_staleness_s": health["staleness_s"],
        "fleet_slo_s": health["slo_s"],
    }


def main(quick: bool = False) -> int:
    results: Dict[str, object] = {
        "config": {"quick": quick, "backend": jax.default_backend()},
        "refresh_vs_retrain": refresh_vs_retrain(quick),
        "decoupled_serve": decoupled_serve(quick),
    }
    with open("BENCH_fivm.json", "w") as f:
        json.dump(results, f, indent=2)
    rr = results["refresh_vs_retrain"]
    ds = results["decoupled_serve"]
    print(f"wrote BENCH_fivm.json (refresh {rr['refresh_ms']:.2f}ms vs "
          f"retrain {rr['retrain_ms']:.2f}ms = {rr['speedup']:.1f}x at "
          f"n={rr['n']}, {rr['m_live']} live, "
          f"{rr['pending_per_round']} pending; serve ingest "
          f"{ds['ingest_us_per_event']:.0f}us/event, read "
          f"{ds['read_ms']:.1f}ms, fleet {ds['fleet_events_per_s']:.0f} "
          f"events/s staleness {ds['fleet_staleness_s']:.3f}s)")
    ok = 0
    if rr["speedup"] < 5.0:
        print(f"FAIL: ring refresh speedup {rr['speedup']:.2f}x < 5x "
              f"gate at the past-crossover cell", file=sys.stderr)
        ok = 1
    if any(s != "refactor" for s in rr["strategies"]):
        print(f"FAIL: past-crossover cell must price the refactor arm, "
              f"got {rr['strategies']}", file=sys.stderr)
        ok = 1
    if ds["read_err_max"] >= 1e-5:
        print(f"FAIL: decoupled read-time re-solve diverged from batch "
              f"retrain ({ds['read_err_max']:.2e} >= 1e-5)",
              file=sys.stderr)
        ok = 1
    bad = {k: v for k, v in ds["fleet_decisions"].items()
           if k != "admitted"}
    if bad:
        print(f"FAIL: fleet ingest not sustained: {bad}", file=sys.stderr)
        ok = 1
    if ds["fleet_pending"] != 0 or \
            ds["fleet_staleness_s"] > ds["fleet_slo_s"]:
        print(f"FAIL: fleet tenant did not settle within SLO "
              f"(pending={ds['fleet_pending']}, "
              f"staleness={ds['fleet_staleness_s']:.3f}s > "
              f"{ds['fleet_slo_s']}s)", file=sys.stderr)
        ok = 1
    return ok


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
