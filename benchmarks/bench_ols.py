"""Paper Fig. 3e: OLS incremental maintenance vs re-evaluation, scaling n.

The paper reports the REEVAL/INCR gap growing from 3.56× (n=4k) to 11.45×
(n=20k) on Octave; we reproduce the same asymptotic divergence at
container scale and report the analytic FLOP ratio alongside.
"""

from __future__ import annotations

from repro.apps import OLS
from .common import bench_app, emit


def main():
    for n in (64, 128, 256, 384):
        m = 2 * n
        app = OLS(m, n, p=1)
        inputs, _ = OLS.synthesize(m, n, 1, seed=0)
        app.initialize(inputs)
        r = bench_app(f"fig3e_ols_n{n}", app, m, n)
        emit(f"fig3e_ols_flops_ratio_n{n}",
             app.engine.reeval_flops() / app.engine.trigger_flops("X"),
             "analytic reeval/incr FLOP ratio")


if __name__ == "__main__":
    main()
