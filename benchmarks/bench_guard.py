"""Chaos acceptance benchmark for repro.guard (ISSUE 6 smoke gate).

Two measurements, emitted to ``BENCH_guard.json``:

  1. **Chaos convergence** — OLS and matrix-powers engines run ≥500
     zipf-skewed rank-1 firings under ``ChaosConfig(poison_p=0.01,
     trigger_raise_p=0.005)`` with the full guard stack (validation +
     transactional firings + drift sentinel).  The run asserts the
     acceptance criteria directly: the store never goes non-finite,
     every injected fault is either quarantined or rolled back, and
     the final views match a from-scratch re-evaluation within the
     sentinel tolerance (``max_abs_diff`` / relative Frobenius both
     reported).

  2. **Clean-path overhead** — guarded vs unguarded engines on a
     fault-free stream through the *batched serving pipeline*
     (``apply_updates``, rank-64T firings — the production path from
     the PR 1 trigger pipeline) at serving-scale views.  The guard's
     fused finite-check + select-commit must cost <10% of per-firing
     wall clock there.  The check reads every written view once per
     firing, a fixed cost the batch amortises across its T updates —
     which is why the gate lives on the batched path: a *rank-1*
     firing on CPU is itself memory-bound at roughly the check's own
     traffic, so per-update firings see 20–40% overhead no matter how
     the guard is engineered (measured and documented in
     docs/robustness.md, not gated).

``--quick`` shrinks chaos sizes and overhead windows for the CI smoke
budget while keeping the ≥500-firing chaos criterion and the overhead
gate's serving-scale sizes intact.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.matrix_powers import build_powers_program
from repro.apps.ols import build_ols_program
from repro.core.codegen import evaluate
from repro.core.runtime import IncrementalEngine
from repro.data.updates import UpdateStream
from repro.guard import ChaosConfig, GuardConfig, SentinelConfig

try:  # runnable both as a module and as a script
    from .common import emit
except ImportError:  # pragma: no cover
    from common import emit

CHAOS = ChaosConfig(seed=0, poison_p=0.01, poison_kind="nan",
                    trigger_raise_p=0.005)


def _program(family: str, quick: bool):
    if family == "ols":
        m, n = (96, 12) if quick else (256, 32)
        prog = build_ols_program(m, n, 2)
        rng = np.random.default_rng(0)
        inputs = {"X": rng.standard_normal((m, n)).astype(np.float32),
                  "Y": rng.standard_normal((m, 2)).astype(np.float32)}
        return prog, inputs, "X", (m, n)
    n = 24 if quick else 64
    prog = build_powers_program(k=4, n=n, model="exp")
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)).astype(np.float32)
    a *= 0.9 / max(abs(np.linalg.eigvals(a)))
    return prog, {"A": a}, "A", (n, n)


def _reference_views(engine):
    env = {k: engine.views[k] for k in engine.program.inputs}
    for st in engine.program.statements:
        env[st.target.name] = evaluate(st.expr, env, engine.binding)
    return env


def chaos_run(family: str, firings: int, quick: bool) -> Dict[str, object]:
    prog, inputs, input_name, (rows, cols) = _program(family, quick)
    eng = IncrementalEngine(
        prog, guard=GuardConfig(sentinel=SentinelConfig(probe_every=100)),
        chaos=CHAOS)
    eng.initialize(inputs)
    stream = UpdateStream(n=rows, m=cols, scale=0.005, seed=11, zipf=1.5)
    it = iter(stream)
    t0 = time.perf_counter()
    for i in range(firings):
        u, v = next(it)
        eng.apply_update(input_name, u, v)
        assert all(bool(jnp.isfinite(a).all())
                   for a in eng.views.values()), \
            f"{family}: non-finite view served at firing {i}"
    jax.block_until_ready(eng.views)
    wall = time.perf_counter() - t0

    eng.guard.sync()
    g = eng.guard.stats
    assert eng.chaos.poisoned > 0 and eng.chaos.raises > 0, \
        f"{family}: chaos never fired — run is vacuous"
    assert g.quarantined == eng.chaos.poisoned
    assert g.rollbacks == eng.chaos.raises, \
        f"{family}: {eng.chaos.raises} faults but {g.rollbacks} rollbacks"

    ref = _reference_views(eng)
    tol = eng.guard.sentinel.config.tol
    max_abs = max_rel = 0.0
    for st in prog.statements:
        name = st.target.name
        r = np.asarray(ref[name], np.float64)
        c = np.asarray(eng.views[name], np.float64)
        max_abs = max(max_abs, float(np.max(np.abs(r - c))))
        rel = np.linalg.norm(r - c) / max(np.linalg.norm(r), 1e-30)
        max_rel = max(max_rel, float(rel))
        assert rel <= tol, \
            f"{family}/{name}: drift {rel:.2e} exceeds sentinel tol {tol}"

    emit(f"guard_chaos_{family}", wall / firings * 1e6,
         f"poisoned={eng.chaos.poisoned};raises={eng.chaos.raises};"
         f"rollbacks={g.rollbacks};max_rel_drift={max_rel:.2e}")
    return {
        "firings": firings,
        "us_per_firing": wall / firings * 1e6,
        "poisoned": eng.chaos.poisoned,
        "trigger_faults": eng.chaos.raises,
        "quarantined": g.quarantined,
        "rollbacks": g.rollbacks,
        "admitted": g.admitted,
        "sentinel_probes": g.probes,
        "drift_recoveries": g.drift_recoveries,
        "max_abs_diff_vs_reeval": max_abs,
        "max_rel_drift_vs_reeval": max_rel,
        "sentinel_tol": tol,
    }


def _serving_program(family: str):
    """Serving-scale programs for the overhead gate (bigger than the
    chaos sizes: the gate belongs where real per-firing work lives)."""
    rng = np.random.default_rng(0)
    if family == "ols":
        m, n = 1024, 96
        prog = build_ols_program(m, n, 2)
        inputs = {"X": rng.standard_normal((m, n)).astype(np.float32),
                  "Y": rng.standard_normal((m, 2)).astype(np.float32)}
        return prog, inputs, "X", (m, n)
    n = 192
    prog = build_powers_program(k=4, n=n, model="exp")
    a = rng.standard_normal((n, n)).astype(np.float32)
    a *= 0.9 / max(abs(np.linalg.eigvals(a)))
    return prog, {"A": a}, "A", (n, n)


def overhead_run(family: str, quick: bool) -> Dict[str, float]:
    """Guarded vs unguarded per-firing wall clock on a clean batched
    stream (T=64 updates per firing through ``apply_updates``).

    Every firing is blocked, so the metric includes the device work the
    guard adds (the fused finite-check + select-commit), not just host
    dispatch.  The two engines are timed in fully *interleaved*
    windows (best-of-N each) so slow container phases hit both paths
    instead of biasing one — the ±30% system noise between two
    back-to-back full runs would otherwise dwarf the guard's real
    cost.  ``--quick`` keeps the serving-scale sizes (smaller ones
    exaggerate the guard's fixed per-firing cost and would make the
    gate dishonest) and trims windows instead.
    """
    prog, inputs, input_name, (rows, cols) = _serving_program(family)

    def mk(guarded: bool):
        p, ins, _, _ = _serving_program(family)
        eng = IncrementalEngine(
            p, guard=GuardConfig() if guarded else None)
        eng.initialize(ins)
        return eng

    eng_plain, eng_guard = mk(False), mk(True)
    t_batch, n_batches, reps = 64, (8 if quick else 15), (6 if quick else 12)
    it = iter(UpdateStream(n=rows, m=cols, scale=0.005, seed=5))
    batches = [[next(it) for _ in range(t_batch)] for _ in range(n_batches)]

    def window(eng) -> float:
        t0 = time.perf_counter()
        for b in batches:
            eng.apply_updates(input_name, b, block=True)
        return (time.perf_counter() - t0) / n_batches

    window(eng_plain)  # warmup: trigger jit + fused-check jit
    window(eng_guard)
    t_plain = t_guard = float("inf")
    for _ in range(reps):
        t_plain = min(t_plain, window(eng_plain))
        t_guard = min(t_guard, window(eng_guard))
    overhead = t_guard / t_plain - 1.0
    emit(f"guard_overhead_{family}", t_guard * 1e6,
         f"plain_us={t_plain*1e6:.1f};overhead={overhead*100:.1f}%;"
         f"batch_T={t_batch}")
    return {"plain_us": t_plain * 1e6, "guarded_us": t_guard * 1e6,
            "batch_T": t_batch, "overhead_frac": overhead}


def main(quick: bool = False):
    firings = 500  # the acceptance criterion floor, quick or not
    results: Dict[str, object] = {
        "config": {"quick": quick, "firings": firings,
                   "chaos": {"seed": CHAOS.seed, "poison_p": CHAOS.poison_p,
                             "trigger_raise_p": CHAOS.trigger_raise_p},
                   "backend": jax.default_backend()},
    }
    for family in ("ols", "powers"):
        results[family] = {
            "chaos": chaos_run(family, firings, quick),
            "overhead": overhead_run(family, quick),
        }
    worst = max(results[f]["overhead"]["overhead_frac"]
                for f in ("ols", "powers"))
    results["worst_overhead_frac"] = worst
    with open("BENCH_guard.json", "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote BENCH_guard.json (worst clean-path overhead "
          f"{worst*100:.1f}%)")
    if worst >= 0.10:
        print(f"FAIL: guard overhead {worst*100:.1f}% >= 10% budget",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
