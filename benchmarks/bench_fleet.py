"""Fleet benchmark + smoke gates (ISSUE 7), emitted to ``BENCH_fleet.json``.

Two measurements, both CI-gated under ``--quick``:

  1. **Scheduler overhead** — 8 tenants refreshed through the full
     fleet path (admission → log → lease claim → guarded firing →
     fencing check → commit) vs the same 8 guarded engines driven
     sequentially with identical update groupings.  The baseline
     settles each engine's firing before moving on (``guard.sync`` +
     block), because that is the guarantee a fleet commit gives per
     tenant — the comparison isolates scheduler *bookkeeping*, not the
     cost of commit-grade settling itself.  At serving-relevant view
     sizes that bookkeeping must cost <10% of per-update wall clock —
     coordination may not eat the batched trigger pipeline's win.

  2. **Shared-cache tenant bring-up** — aggregate wall clock to
     register 8 *same-program* tenants and refresh one batch each,
     with the fleet's shared :class:`~repro.plan.TriggerCache` vs cold
     per-tenant engines each re-tracing/re-compiling its own triggers.
     The shared cache must yield ≥2x aggregate throughput — the
     multi-tenant consolidation argument in one number.  (Distinct
     dims from the overhead run so neither side inherits this
     process's jit warmth.)

``--quick`` shrinks rounds/sizes for the CI smoke budget while keeping
both gates intact.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core.runtime import IncrementalEngine
from repro.fleet import FleetConfig, FleetScheduler, TenantSpec
from repro.plan import TriggerCache
from repro.serve.incremental_views import build_logit_view_program

try:  # runnable both as a module and as a script
    from .common import emit
except ImportError:  # pragma: no cover
    from common import emit

N_TENANTS = 8


def _tenant_inputs(m: int, d: int, p: int, seed: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {"H": rng.standard_normal((m, d)).astype(np.float32),
            "W": (rng.standard_normal((p, d)) * 0.1).astype(np.float32)}


def _updates(rng, p: int, d: int, n: int) -> List[Tuple[np.ndarray,
                                                        np.ndarray]]:
    return [((rng.standard_normal((p, 1)) * 0.01).astype(np.float32),
             (rng.standard_normal((d, 1)) * 0.01).astype(np.float32))
            for _ in range(n)]


def overhead_run(quick: bool) -> Dict[str, float]:
    """Fleet path vs N sequential engines, identical firing groups."""
    m, d, p = (768, 64, 3072) if quick else (1024, 96, 4096)
    batch = 8
    rounds = 9 if quick else 13
    prog = build_logit_view_program(m, d, p)
    rng = np.random.default_rng(0)

    fleet = FleetScheduler(FleetConfig(lease_ttl=60.0))
    baseline: List[IncrementalEngine] = []
    for i in range(N_TENANTS):
        inputs = _tenant_inputs(m, d, p, seed=i)
        # one claim per tenant per round: the groupings match the
        # baseline's apply_updates calls exactly
        fleet.add_tenant(TenantSpec(f"t{i}", prog, {"W": 1},
                                    max_claim_rank=batch), inputs)
        eng = IncrementalEngine(prog, {"W": 1}, guard=True,
                                trigger_cache=fleet.registry.trigger_cache)
        eng.initialize(inputs)
        baseline.append(eng)

    def fleet_round() -> float:
        ups = {i: _updates(rng, p, d, batch) for i in range(N_TENANTS)}
        t0 = time.perf_counter()
        for i in range(N_TENANTS):
            for u, v in ups[i]:
                fleet.submit(f"t{i}", "W", u, v)
        fleet.run_until_idle(workers=1)
        jax.block_until_ready([fleet.registry.get(f"t{i}").committed_views
                               for i in range(N_TENANTS)])
        return time.perf_counter() - t0

    def baseline_round() -> float:
        ups = {i: _updates(rng, p, d, batch) for i in range(N_TENANTS)}
        t0 = time.perf_counter()
        for i, eng in enumerate(baseline):
            eng.apply_updates("W", ups[i])
            eng.guard.sync()   # the per-tenant settle a commit implies
            jax.block_until_ready(eng.views)
        return time.perf_counter() - t0

    fleet_round(); baseline_round()          # jit + path warmup
    # interleave the two sides (alternating which goes first) so a
    # noisy-neighbor phase on this host hits adjacent rounds alike,
    # and gate on the lower quartile of per-round ratios: a CI smoke
    # gate must be robust to bursty shared-CPU interference, and a
    # burst can only *inflate* a ratio — the quartile recovers the
    # quiet-machine overhead while the median is recorded alongside
    pairs = []
    for r in range(rounds):
        if r % 2:
            b = baseline_round(); f = fleet_round()
        else:
            f = fleet_round(); b = baseline_round()
        pairs.append((f, b))
    ratios = sorted(f / b for f, b in pairs)
    overhead = ratios[len(ratios) // 4] - 1.0
    median = ratios[len(ratios) // 2] - 1.0
    t_fleet = min(f for f, _ in pairs)
    t_base = min(b for _, b in pairs)
    per_update = N_TENANTS * batch
    emit("fleet_scheduler_overhead", t_fleet / per_update * 1e6,
         f"base_us={t_base/per_update*1e6:.1f};"
         f"overhead={overhead*100:.1f}%;median={median*100:.1f}%;"
         f"tenants={N_TENANTS};batch={batch}")
    return {"fleet_us_per_update": t_fleet / per_update * 1e6,
            "baseline_us_per_update": t_base / per_update * 1e6,
            "tenants": N_TENANTS, "batch": batch,
            "overhead_frac": overhead,
            "overhead_median_frac": median}


def cache_sharing_run(quick: bool) -> Dict[str, float]:
    """Shared-cache bring-up vs cold per-tenant engines."""
    # dims distinct from overhead_run: fresh trace/compile either way
    m, d, p = (192, 48, 320) if quick else (384, 96, 640)
    batch = 8
    rng = np.random.default_rng(1)
    all_inputs = [_tenant_inputs(m, d, p, seed=100 + i)
                  for i in range(N_TENANTS)]
    all_ups = [_updates(rng, p, d, batch) for _ in range(N_TENANTS)]

    def cold() -> float:
        t0 = time.perf_counter()
        for i in range(N_TENANTS):
            # per-tenant isolated cache: every tenant re-traces and
            # re-compiles its own triggers from scratch
            eng = IncrementalEngine(prog_of(i), {"W": 1}, guard=True,
                                    trigger_cache=TriggerCache())
            eng.initialize(all_inputs[i])
            eng.apply_updates("W", all_ups[i])
            jax.block_until_ready(eng.views)
        return time.perf_counter() - t0

    def shared() -> float:
        t0 = time.perf_counter()
        fleet = FleetScheduler(FleetConfig(lease_ttl=60.0))
        for i in range(N_TENANTS):
            fleet.add_tenant(TenantSpec(f"t{i}", prog_of(i), {"W": 1},
                                        max_claim_rank=batch),
                             all_inputs[i])
            for u, v in all_ups[i]:
                fleet.submit(f"t{i}", "W", u, v)
        fleet.run_until_idle(workers=1)
        jax.block_until_ready([fleet.registry.get(f"t{i}").committed_views
                               for i in range(N_TENANTS)])
        return time.perf_counter() - t0

    def prog_of(i):
        # structurally identical programs: same fingerprint, so the
        # shared cache serves tenant 1..N-1 from tenant 0's compiles
        return build_logit_view_program(m, d, p)

    # order matters for fairness: run the COLD side first so any
    # process-wide jax warmth it creates can only help the... cold side
    # itself; the shared side then re-traces its own first tenant.
    t_cold = cold()
    t_shared = shared()
    speedup = t_cold / t_shared
    emit("fleet_cache_sharing", t_shared / N_TENANTS * 1e6,
         f"cold_us={t_cold/N_TENANTS*1e6:.1f};speedup={speedup:.2f}x;"
         f"tenants={N_TENANTS}")
    return {"shared_s": t_shared, "cold_s": t_cold,
            "tenants": N_TENANTS, "speedup": speedup}


def main(quick: bool = False) -> int:
    results: Dict[str, object] = {
        "config": {"quick": quick, "tenants": N_TENANTS,
                   "backend": jax.default_backend()},
        "overhead": overhead_run(quick),
        "cache_sharing": cache_sharing_run(quick),
    }
    with open("BENCH_fleet.json", "w") as f:
        json.dump(results, f, indent=2)
    overhead = results["overhead"]["overhead_frac"]
    speedup = results["cache_sharing"]["speedup"]
    print(f"wrote BENCH_fleet.json (scheduler overhead "
          f"{overhead*100:.1f}%, cache-sharing speedup {speedup:.2f}x)")
    ok = 0
    if overhead >= 0.10:
        print(f"FAIL: fleet scheduler overhead {overhead*100:.1f}% "
              f">= 10% budget", file=sys.stderr)
        ok = 1
    if speedup < 2.0:
        print(f"FAIL: shared-cache speedup {speedup:.2f}x < 2x gate",
              file=sys.stderr)
        ok = 1
    return ok


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
