"""Higher-order (delta-of-delta) maintenance benchmark — ISSUE 8.

For matrix_powers / sums_powers / general_iterative cells sitting PAST
the §7 crossover (stacked firing rank high enough that per-firing
incremental sweeps lose to re-evaluation — the cells where PR 5's best
static strategy is ``static_reeval`` at cost R per firing), a depth-2
deferred cascade accumulates each firing's factors into a window and
folds once every ``fold_window`` firings: the per-firing price drops to
roughly R/W plus the (cheap, recompressed) accumulate — the DBToaster
"higher-order deltas make each level cheaper" win realized as wall
clock.

Measured per cell, same engine machinery throughout:

  * ``static_incremental`` / ``static_reeval`` — PR 5's static plans
    (per-firing maintenance, depth 1);
  * ``depth2`` — ``IncrementalEngine(order=2, fold_window=W)``; timed
    over whole W-firing cycles (the window's firings PLUS its fold) so
    the reported per-firing cost is the honest amortized price.

Acceptance gates (tracked in ``BENCH_higher_order.json``):

  * on the past-crossover powers_exp and general_form cells, depth-2 is
    ≥ 2x cheaper per update than the best depth-1 static strategy;
  * an :class:`~repro.plan.AdaptivePlanner` with ``max_order=2``
    observing each cell's firings (high stacked rank, no interleaved
    reads) re-plans to a depth ≥ 2 plan on its own.

``--quick`` runs a reduced matrix for the CI smoke budget.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iterative import general_form, matrix_powers, sums_of_powers
from repro.core.runtime import IncrementalEngine
from repro.data.updates import UpdateStream
from repro.plan import (AdaptivePlanner, TriggerCache, WorkloadDescriptor,
                        calibrate_cost_scale, static_plan)

try:  # runnable both as a module and as a script
    from .common import emit
except ImportError:  # pragma: no cover
    from common import emit

FOLD_WINDOW = 16
# a cell is *past* the §7 crossover only when re-evaluation beats the
# incremental sweep by a clear margin — at the crossover itself the two
# tie by definition and noise picks the argmin.  Near-crossover cells
# also cap the possible depth-2 win at ~1/(U/R + 1/W) regardless of
# depth (the shared per-firing input-update cost U is a comparable
# slice of the ~R best-static price), so the ≥2x gate is only a
# meaningful claim in the clearly-past regime.  The S = n/2 and S = n
# cells sit at margin ≥ 2 on CPU; the low-rank S = k context cell
# hovers at ~1.1-1.25 and stays ungated.
CROSSOVER_MARGIN = 1.5


def _updates(n: int, m: int, count: int, rank: int, seed: int
             ) -> List[Tuple[np.ndarray, np.ndarray]]:
    it = iter(UpdateStream(n=n, m=m, rank=rank, scale=0.005, seed=seed))
    return [next(it) for _ in range(count)]


def powers_inputs(n: int):
    rng = np.random.default_rng(0)
    a = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n))
    return {"A": jnp.asarray(a, jnp.float32)}


def general_inputs(n: int, p: int):
    rng = np.random.default_rng(0)
    return {"A": jnp.asarray((0.5 / np.sqrt(n)) * rng.normal(size=(n, n)),
                             jnp.float32),
            "T0": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)}


def bench_cell(build, inputs_fn, input_name: str, n: int, m: int,
               k: int, t_batch: int, samples: int, cache: TriggerCache
               ) -> Dict:
    """One (program, k, T) cell: amortized per-firing seconds for the
    two PR 5 static strategies and the depth-2 deferred cascade."""
    w = FOLD_WINDOW
    ups = _updates(n, m, t_batch * w * (samples + 2), k,
                   seed=11 + 7 * k + t_batch)
    batches = [ups[i * t_batch:(i + 1) * t_batch]
               for i in range(w * (samples + 2))]

    engines: Dict[str, IncrementalEngine] = {}
    for label in ("static_incremental", "static_reeval"):
        eng = IncrementalEngine(build(), trigger_cache=cache)
        eng.set_plan(static_plan(eng, label.split("_", 1)[1]))
        eng.initialize(inputs_fn())
        engines[label] = eng
    # max_fold_rank=None: at these window ranks a bounded window would
    # host-recompress (QR/SVD) on every accumulate, costing more than
    # the fold it feeds.  Uncapped, accumulation is pointer appends and
    # the fold makes its per-view sweep-vs-reeval choice at the full
    # window rank — the configuration the depth-2 pricing assumes for
    # read-sparse streams.
    d2 = IncrementalEngine(build(), order=2, fold_window=w,
                           max_fold_rank=None, trigger_cache=cache)
    d2.initialize(inputs_fn())
    engines["depth2"] = d2

    def cycle(eng, start):
        # one fold window's worth of firings; for the depth-2 engine the
        # last firing of the cycle triggers the fold, so a timed cycle
        # always contains exactly one fold
        for i in range(w):
            eng.apply_updates(input_name, batches[start + i])
        jax.block_until_ready(eng.views)

    times: Dict[str, float] = {}
    for label, eng in engines.items():
        cycle(eng, 0)  # jit warmup (trigger + fold paths)
        best = float("inf")
        for s in range(samples):
            cycle(eng, w)  # scrub: zero the predecessor's cache effects
            t0 = time.perf_counter()
            cycle(eng, (s + 2) * w % (w * (samples + 1)))
            best = min(best, (time.perf_counter() - t0) / w)
        times[label] = best
    assert engines["depth2"].stats.folds >= samples + 1

    best_d1 = min(times["static_incremental"], times["static_reeval"])
    past_crossover = (times["static_reeval"] * CROSSOVER_MARGIN
                      < times["static_incremental"])
    return {
        "past_crossover": past_crossover,
        "update_rank": k,
        "batch_T": t_batch,
        "stacked_rank": k * t_batch,
        "fold_window": w,
        "static_incremental_ms": times["static_incremental"] * 1e3,
        "static_reeval_ms": times["static_reeval"] * 1e3,
        "depth2_ms": times["depth2"] * 1e3,
        "best_first_order": ("static_incremental"
                             if best_d1 == times["static_incremental"]
                             else "static_reeval"),
        "depth2_speedup_vs_best_first_order": best_d1 / times["depth2"],
    }


def adaptive_selects_depth(build, inputs_fn, input_name: str, n: int,
                           m: int, k: int, t_batch: int,
                           cost_scale: float, cache: TriggerCache) -> int:
    """Drive an adaptive engine with the cell's firing stream (no
    interleaved reads) and report the deepest order its re-planned plan
    assigns — the ISSUE gate wants ≥ 2 from observed firings alone."""
    wl = WorkloadDescriptor(update_rank=1, max_order=2,
                            fold_window=FOLD_WINDOW,
                            cost_scale=cost_scale)
    eng = IncrementalEngine(
        build(), {input_name: k},
        plan=AdaptivePlanner(wl, replan_every=FOLD_WINDOW, drift_tol=0.2),
        fold_window=FOLD_WINDOW, trigger_cache=cache)
    eng.initialize(inputs_fn())
    ups = _updates(n, m, t_batch * 3 * FOLD_WINDOW, k, seed=3)
    for i in range(3 * FOLD_WINDOW):
        eng.apply_updates(input_name, ups[i * t_batch:(i + 1) * t_batch])
    return max(eng._view_orders.values(), default=1)


def main(quick: bool = False) -> Dict:
    # n must be large enough that a view re-evaluation (~n³) dwarfs the
    # shared per-firing input-update cost (~S·n² plus host dispatch) —
    # at toy n every strategy pays mostly the input update and the
    # amortization ratio flattens toward 1x regardless of depth
    n = 256
    samples = 3 if quick else 7
    k = 8
    p_dim = n // 2
    cache = TriggerCache()

    # powers uses a deeper chain (A^2 … A^32, five chained GEMMs): with
    # only three matmuls the re-evaluation R is so small on CPU that
    # the shared per-firing input-update cost U caps any depth's win at
    # ~(U+R)/U ≈ 2 — the deeper chain is the regime the gate is about
    programs = {
        "powers_exp": (lambda: matrix_powers(k=32, n=n, model="exp"),
                       lambda: powers_inputs(n), "A", n, n, True),
        "sums_powers": (lambda: sums_of_powers(k=8, n=n, model="exp"),
                        lambda: powers_inputs(n), "A", n, n, False),
        "general_form": (lambda: general_form(k=8, n=n, p_dim=p_dim,
                                              model="exp", with_b=False),
                         lambda: general_inputs(n, p_dim), "A", n, n, True),
    }

    cells: Dict[str, List[Dict]] = {}
    gated: List[Dict] = []
    adaptive_depth: Dict[str, int] = {}
    scales: Dict[str, float] = {}
    for name, (build, inputs_fn, input_name, pn, pm, gate) in \
            programs.items():
        scale = calibrate_cost_scale(
            lambda: IncrementalEngine(build(), trigger_cache=cache),
            inputs_fn(), input_name, trigger_cache=cache)
        scales[name] = scale
        # the effective §7 crossover sits at K*/cost_scale; S = n/4 and
        # S = n/2 both land clearly past it on CPU (scale > 1), which is
        # exactly the regime the depth-2 gate is about.  One low-rank
        # context cell rides along, ungated.  S beyond n/2 is NOT in the
        # matrix: a stacked rank approaching n is a dense rewrite of the
        # base table, where applying the update itself dominates every
        # strategy and factored IVM stops paying at all (§4) — it stops
        # being a view-maintenance measurement.
        stacked = (k,) + ((pn // 2,) if quick else (pn // 4, pn // 2))
        rows = []
        for s_target in stacked:
            t_batch = max(1, s_target // k)
            cell = bench_cell(build, inputs_fn, input_name, pn, pm, k,
                              t_batch, samples, cache)
            cell["gated"] = bool(gate and cell["past_crossover"])
            if cell["gated"]:
                gated.append(cell)
            rows.append(cell)
            emit(f"higher_order_{name}_S{k * t_batch}",
                 cell["depth2_ms"] * 1e3,
                 f"depth2 vs best d1 "
                 f"{cell['depth2_speedup_vs_best_first_order']:.2f}x;"
                 f"best_d1={cell['best_first_order']}")
        cells[name] = rows
        depth = adaptive_selects_depth(build, inputs_fn, input_name, pn,
                                       pm, k, max(1, pn // (2 * k)),
                                       scale, cache)
        adaptive_depth[name] = depth
        emit(f"higher_order_{name}_adaptive_depth", float(depth),
             "order the adaptive planner selected from observed firings")

    min_gated = min((c["depth2_speedup_vs_best_first_order"]
                     for c in gated), default=0.0)
    summary = {
        "gated_cells": len(gated),
        "min_depth2_speedup_on_gated_cells": min_gated,
        "pass_depth2_2x": bool(gated) and min_gated >= 2.0,
        "adaptive_selected_depth": adaptive_depth,
        "pass_adaptive_depth": all(d >= 2 for d in adaptive_depth.values()),
        "trigger_cache": cache.stats(),
    }
    results = {
        "config": {"n": n, "update_rank": k, "fold_window": FOLD_WINDOW,
                   "samples": samples, "cost_scales": scales,
                   "backend": jax.default_backend(), "quick": quick},
        "programs": cells,
        "summary": summary,
    }
    with open("BENCH_higher_order.json", "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote BENCH_higher_order.json  "
          f"(depth-2 ≥ {min_gated:.2f}x best first-order on "
          f"{len(gated)} past-crossover cells; adaptive depth: "
          f"{adaptive_depth})")
    assert summary["pass_depth2_2x"], \
        "gate failed: depth-2 must be ≥2x cheaper on past-crossover cells"
    assert summary["pass_adaptive_depth"], \
        "gate failed: the adaptive planner must select depth ≥ 2"
    return results


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
