# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure:
  Fig 3a/3b/3c  bench_matrix_powers   (strategies, n-scaling, k-scaling)
  Fig 3d        bench_sums_powers
  Fig 3e        bench_ols
  Fig 3f        bench_scaling          (mesh-width collective scaling)
  Fig 3g/3h     bench_general_form     (hybrid study, BGD)
  Table 3       bench_memory           (memory vs speedup)
  Table 4       bench_batch_updates    (Zipf batches)
Pass suite names to run a subset, e.g. ``-m benchmarks.run ols``.
"""

import sys
import time


def main() -> None:
    from . import (bench_batch_updates, bench_general_form,
                   bench_matrix_powers, bench_memory, bench_ols,
                   bench_scaling, bench_sums_powers)
    suites = {
        "matrix_powers": bench_matrix_powers.main,
        "sums_powers": bench_sums_powers.main,
        "ols": bench_ols.main,
        "general_form": bench_general_form.main,
        "memory": bench_memory.main,
        "batch_updates": bench_batch_updates.main,
        "scaling": bench_scaling.main,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in want:
        fn = suites.get(name)
        if fn is None:
            print(f"# unknown suite {name}; have {sorted(suites)}")
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
