"""Paper Fig. 3d: sums of matrix powers S_k, INCR-EXP vs REEVAL-EXP."""

from __future__ import annotations

from repro.apps import SumsOfPowers
from .common import bench_app


def main():
    for n in (128, 256, 512):
        app = SumsOfPowers(n=n, k=16, model="exp")
        app.initialize(SumsOfPowers.synthesize(n, seed=0))
        bench_app(f"fig3d_sums_exp_n{n}", app, n)


if __name__ == "__main__":
    main()
