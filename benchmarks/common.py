"""Benchmark harness shared plumbing.

Every bench measures *average view-refresh time per update* (the paper's
metric, §7) for REEVAL / INCR / HYBRID over a stream of rank-1 row
updates, and prints ``name,us_per_call,derived`` CSV rows.  Sizes are
scaled to the CPU container; the trends (not the absolute numbers) are
what reproduce the paper's figures — EXPERIMENTS.md compares them.

Batch-size sweep: ``bench_trigger_pipeline.py`` extends the per-update
metric across batched trigger firings, sweeping T ∈ {1, 4, 16, 64}
coalesced updates per firing for the OLS and matrix-powers programs
(sequential vs ``IncrementalEngine.apply_updates``).  Per-update time
must fall monotonically with T — each maintained view is swept once per
*batch* instead of once per *update* — and the run emits
``BENCH_trigger_pipeline.json`` so CI can track the perf trajectory.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.updates import UpdateStream

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_updates(apply_fn: Callable, stream: Iterable, n_updates: int = 5,
                 warmup: int = 1) -> float:
    """Average seconds per update (jit-warmed, blocked)."""
    it = iter(stream)
    for _ in range(warmup):
        u, v = next(it)
        jax.block_until_ready(apply_fn(jnp.asarray(u), jnp.asarray(v)))
    t0 = time.perf_counter()
    for _ in range(n_updates):
        u, v = next(it)
        jax.block_until_ready(apply_fn(jnp.asarray(u), jnp.asarray(v)))
    return (time.perf_counter() - t0) / n_updates


def bench_app(name: str, app, n: int, m: Optional[int] = None,
              n_updates: int = 5, scale: float = 0.05,
              extra: str = "") -> Dict[str, float]:
    """Times INCR and REEVAL paths of an App; returns seconds per update."""
    m = m if m is not None else n
    # fresh same-seed streams per path: UpdateStream's shared generator
    # advances on every draw, and the comparison needs both paths to
    # see the identical update sequence
    t_incr = time_updates(app.update,
                          UpdateStream(n=n, m=m, scale=scale, seed=7),
                          n_updates)
    t_reeval = time_updates(app.update_reeval,
                            UpdateStream(n=n, m=m, scale=scale, seed=7),
                            n_updates)
    speedup = t_reeval / t_incr
    emit(f"{name}_incr", t_incr * 1e6, f"speedup={speedup:.2f}x{extra}")
    emit(f"{name}_reeval", t_reeval * 1e6, extra.lstrip(";"))
    return {"incr": t_incr, "reeval": t_reeval, "speedup": speedup}
