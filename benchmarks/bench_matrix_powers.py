"""Paper Fig. 3a (strategies × models), Fig. 3b (scaling n), Fig. 3c
(scaling k): matrix powers A^k under rank-1 row updates."""

from __future__ import annotations

from repro.apps import MatrixPowers
from .common import bench_app, emit


def fig3a(n: int = 384, k: int = 16):
    """All evaluation strategies at fixed n, k (paper: n=10k/30k, k=16)."""
    for model in ("linear", "exp", "skip"):
        app = MatrixPowers(n=n, k=k, model=model, s=4)
        app.initialize(MatrixPowers.synthesize(n, seed=0))
        bench_app(f"fig3a_powers_{model}_n{n}_k{k}", app, n,
                  extra=f";model={model}")


def fig3b(k: int = 16):
    """Scaling with n (paper: n up to 90k on Spark)."""
    for n in (128, 256, 512, 768):
        app = MatrixPowers(n=n, k=k, model="exp")
        app.initialize(MatrixPowers.synthesize(n, seed=0))
        r = bench_app(f"fig3b_powers_exp_n{n}", app, n)
        emit(f"fig3b_flops_ratio_n{n}",
             app.engine.reeval_flops() / app.engine.trigger_flops("A"),
             "analytic reeval/incr FLOP ratio")


def fig3c(n: int = 256):
    """Scaling with iterations k (paper: k up to 256)."""
    for k in (4, 16, 64):
        app = MatrixPowers(n=n, k=k, model="exp")
        app.initialize(MatrixPowers.synthesize(n, seed=0))
        bench_app(f"fig3c_powers_exp_k{k}", app, n, extra=f";k={k}")


def main():
    fig3a()
    fig3b()
    fig3c()


if __name__ == "__main__":
    main()
