"""Sparse-delta carrier benchmark + smoke gates (``BENCH_sparse.json``).

Three measurements on the left-chain program ``Y1 = X·W1; Y2 = Y1·W2``
(both views row-local-closed), all CI-gated under ``--quick``
(``sparse-containment`` job):

  1. **Row-local containment** — a stream of `RowLocalCarrier` updates
     touching ≤1% of the input's rows, fired through the row-local
     carrier path, vs the *same* deltas widened to dense factors
     through the ordinary rank-k sweep.  On CPU the carrier path runs
     the compact in-place apply (``rowlocal_apply="auto"``): the factor
     chain is evaluated on the ``(r, k)`` row block and each view's
     touched rows are mutated in place, so the firing does ``O(r·(k+m))``
     work while the dense path pays the full ``n·m`` sweep *plus* the
     jit copy floor (XLA on CPU ignores buffer donation, so every
     written view is rewritten per firing — see the one-time donation
     warning and docs/sparse_deltas.md).  At 1% affected rows the
     carrier path must be ≥5x cheaper per update or the whole carrier
     thread is decorative.

  2. **No-op short-circuit** — a stream that is ≥95% `NoOpCarrier`
     (declared-zero deltas) vs the dense path fed the same stream as
     explicit zero factor pairs (which it cannot prove are zero and
     must fire).  Gates: ≥10x cheaper per update, and the engine's
     ``noop_skips`` accounting must cover every declared no-op.

  3. **Dense-path overhead** — raw ``(u, v)`` pairs through
     ``apply_update`` (which now routes via the carrier dispatch) vs
     the cached trigger fn invoked directly on the same arrays.  The
     dispatch layer must cost <5% — the carrier refactor may not tax
     users who never construct a carrier.

``--quick`` shrinks sizes/rounds for the CI budget; gates are
identical.  Ratio gates use the median of per-round ratios so a bursty
shared-CPU neighbor cannot flip a pass.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict

import jax
import numpy as np

from repro.core import IncrementalEngine, NoOpCarrier, Program, dim, matmul
from repro.data import row_local_stream


def _chain_prog(n: int, m: int, k: int) -> Program:
    p = Program(name="bench_chain")
    X = p.input("X", (dim("N"), dim("M")))
    W1 = p.input("W1", (dim("M"), dim("K")))
    W2 = p.input("W2", (dim("K"), dim("K")))
    Y1 = p.let("Y1", matmul(X, W1))
    p.let("Y2", matmul(Y1, W2))
    p.outputs = ["Y1", "Y2"]
    return p.bind_dims(N=n, M=m, K=k)


def _inputs(n: int, m: int, k: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {"X": rng.standard_normal((n, m)).astype(np.float32),
            "W1": rng.standard_normal((m, k)).astype(np.float32),
            "W2": rng.standard_normal((k, k)).astype(np.float32)}


def _engine(n: int, m: int, k: int, rank: int) -> IncrementalEngine:
    eng = IncrementalEngine(_chain_prog(n, m, k), {"X": rank})
    eng.initialize(_inputs(n, m, k))
    return eng


def _settle(eng: IncrementalEngine) -> None:
    jax.block_until_ready(eng.views["Y2"])


def _median_ratio(base_times, fast_times):
    return float(np.median(np.asarray(base_times)
                           / np.maximum(np.asarray(fast_times), 1e-12)))


def rowlocal_run(quick: bool) -> Dict[str, float]:
    n, m, k = (8192, 256, 256) if quick else (16384, 384, 256)
    rank, rounds, per_round = 8, (5 if quick else 9), (4 if quick else 6)
    rows_touched = max(1, n // 100)          # ≤1% affected rows
    carrier_eng = _engine(n, m, k, rank)
    dense_eng = _engine(n, m, k, rank)
    # one stream, deltas drawn up-front: both paths see identical
    # updates and the RNG never runs inside a timed region
    stream = row_local_stream(n, rows_touched, m=m, rank=rank, seed=1)
    draws = [stream.next_carrier() for _ in range(1 + rounds * per_round)]
    # warm both jit paths
    carrier_eng.apply_update("X", draws[0])
    _settle(carrier_eng)
    dense_eng.apply_update("X", *draws[0].factors())
    _settle(dense_eng)
    pairs = [c.factors() for c in draws]
    t_slab, t_dense = [], []
    for i in range(rounds):
        batch = draws[1 + i * per_round: 1 + (i + 1) * per_round]
        t0 = time.perf_counter()
        for c in batch:
            carrier_eng.apply_update("X", c)
        _settle(carrier_eng)
        t_slab.append((time.perf_counter() - t0) / per_round)
        t0 = time.perf_counter()
        for P, Q in pairs[1 + i * per_round: 1 + (i + 1) * per_round]:
            dense_eng.apply_update("X", P, Q)
        _settle(dense_eng)
        t_dense.append((time.perf_counter() - t0) / per_round)
    assert carrier_eng.stats.rowlocal_firings > 0
    assert carrier_eng.stats.widened_carriers == 0
    err = float(np.max(np.abs(np.asarray(carrier_eng.views["Y2"])
                              - np.asarray(dense_eng.views["Y2"]))))
    scale = float(np.abs(np.asarray(dense_eng.views["Y2"])).max())
    return {"n": n, "m": m, "rows_touched": rows_touched,
            "affected_fraction": rows_touched / n,
            "us_rowlocal": float(np.median(t_slab)) * 1e6,
            "us_dense": float(np.median(t_dense)) * 1e6,
            "speedup": _median_ratio(t_dense, t_slab),
            "rel_err": err / max(scale, 1.0)}


def noop_run(quick: bool) -> Dict[str, float]:
    n, m, k = (4096, 256, 128) if quick else (8192, 256, 256)
    rank, total = 1, (100 if quick else 200)
    live_every = 20                          # 5% live → 95% no-ops
    carrier_eng = _engine(n, m, k, rank)
    dense_eng = _engine(n, m, k, rank)
    live = row_local_stream(n, 4, m=m, rank=rank, seed=2)
    live_d = row_local_stream(n, 4, m=m, rank=rank, seed=2)
    zero_u = np.zeros((n, rank), dtype=np.float32)
    zero_v = np.zeros((m, rank), dtype=np.float32)
    # warm
    carrier_eng.apply_update("X", live.next_carrier())
    _settle(carrier_eng)
    dense_eng.apply_update("X", *live_d.next_carrier().factors())
    _settle(dense_eng)
    t0 = time.perf_counter()
    for i in range(total):
        if i % live_every == 0:
            carrier_eng.apply_update("X", live.next_carrier())
        else:
            carrier_eng.apply_update("X", NoOpCarrier(n, m))
    _settle(carrier_eng)
    t_carrier = (time.perf_counter() - t0) / total
    t0 = time.perf_counter()
    for i in range(total):
        if i % live_every == 0:
            dense_eng.apply_update("X", *live_d.next_carrier().factors())
        else:
            # the dense path cannot prove a zero pair is a no-op
            dense_eng.apply_update("X", zero_u, zero_v)
    _settle(dense_eng)
    t_dense = (time.perf_counter() - t0) / total
    declared = total - (total + live_every - 1) // live_every
    skip_frac = carrier_eng.stats.noop_skips / total
    assert carrier_eng.stats.noop_skips == declared
    err = float(np.max(np.abs(np.asarray(carrier_eng.views["Y2"])
                              - np.asarray(dense_eng.views["Y2"]))))
    scale = float(np.abs(np.asarray(dense_eng.views["Y2"])).max())
    return {"n": n, "updates": total,
            "us_carrier": t_carrier * 1e6, "us_dense": t_dense * 1e6,
            "speedup": t_dense / max(t_carrier, 1e-12),
            "noop_skip_fraction": skip_frac,
            "rel_err": err / max(scale, 1.0)}


def dense_overhead_run(quick: bool) -> Dict[str, float]:
    n, m, k = (4096, 256, 128) if quick else (8192, 256, 256)
    rank, rounds, per_round = 1, (7 if quick else 11), (8 if quick else 12)
    eng = _engine(n, m, k, rank)
    rng = np.random.default_rng(3)
    mk = lambda: ((0.01 * rng.standard_normal((n, rank))).astype(np.float32),
                  (0.01 * rng.standard_normal((m, rank))).astype(np.float32))
    u, v = mk()
    eng.apply_update("X", u, v)              # warm the dispatch path
    _settle(eng)
    trig_fn = eng._trigger_fns["X"]          # the staged dense trigger
    eng.views = trig_fn(eng.views, u, v)
    _settle(eng)
    t_api, t_raw = [], []
    for _ in range(rounds):
        pairs = [mk() for _ in range(per_round)]
        t0 = time.perf_counter()
        for u, v in pairs:
            eng.apply_update("X", u, v)
        _settle(eng)
        t_api.append((time.perf_counter() - t0) / per_round)
        t0 = time.perf_counter()
        for u, v in pairs:
            eng.views = trig_fn(eng.views, u, v)
        _settle(eng)
        t_raw.append((time.perf_counter() - t0) / per_round)
    overhead = _median_ratio(t_api, t_raw) - 1.0
    return {"n": n, "us_api": float(np.median(t_api)) * 1e6,
            "us_raw": float(np.median(t_raw)) * 1e6,
            "overhead_frac": overhead}


def main(quick: bool = False) -> int:
    results: Dict[str, object] = {
        "config": {"quick": quick, "backend": jax.default_backend()},
        "rowlocal": rowlocal_run(quick),
        "noop": noop_run(quick),
        "dense_overhead": dense_overhead_run(quick),
    }
    with open("BENCH_sparse.json", "w") as f:
        json.dump(results, f, indent=2)
    rl = results["rowlocal"]
    no = results["noop"]
    ov = results["dense_overhead"]
    print(f"wrote BENCH_sparse.json (row-local {rl['speedup']:.2f}x at "
          f"{rl['affected_fraction']*100:.2f}% rows, no-op stream "
          f"{no['speedup']:.2f}x with {no['noop_skip_fraction']*100:.0f}% "
          f"skips, dense dispatch overhead {ov['overhead_frac']*100:.1f}%)")
    ok = 0
    if rl["speedup"] < 5.0:
        print(f"FAIL: row-local speedup {rl['speedup']:.2f}x < 5x gate "
              f"at {rl['affected_fraction']*100:.2f}% affected rows",
              file=sys.stderr)
        ok = 1
    if rl["rel_err"] > 1e-3:
        print(f"FAIL: row-local path diverged from dense "
              f"(rel err {rl['rel_err']:.2e})", file=sys.stderr)
        ok = 1
    if no["speedup"] < 10.0:
        print(f"FAIL: no-op stream speedup {no['speedup']:.2f}x < 10x "
              f"gate", file=sys.stderr)
        ok = 1
    if no["noop_skip_fraction"] < 0.95:
        print(f"FAIL: no-op skip fraction "
              f"{no['noop_skip_fraction']*100:.0f}% < 95%",
              file=sys.stderr)
        ok = 1
    if ov["overhead_frac"] >= 0.05:
        print(f"FAIL: dense dispatch overhead "
              f"{ov['overhead_frac']*100:.1f}% >= 5% budget",
              file=sys.stderr)
        ok = 1
    return ok


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
