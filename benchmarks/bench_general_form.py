"""Paper Fig. 3g (B=0, varying p: REEVAL vs INCR vs HYBRID) and Fig. 3h
(B≠0: gradient-descent linear regression, all models)."""

from __future__ import annotations

from repro.apps import BatchGradientDescent, GeneralIterative
from .common import bench_app, emit


def fig3g(n: int = 256, k: int = 16):
    """T_{i+1} = A·T_i with p ∈ {1, 32, 128}: hybrid wins at p=1 (the
    paper's 16%-over-reeval observation), factored wins at large p."""
    for p in (1, 32, 128):
        for rep, tag in ((None, "auto"), ("lowrank", "incr"),
                         ("dense", "hybrid")):
            app = GeneralIterative(n=n, p=p, k=k, model="linear",
                                   with_b=False, force_rep=rep)
            app.initialize(GeneralIterative.synthesize(n, p, with_b=False))
            bench_app(f"fig3g_p{p}_{tag}", app, n, extra=f";p={p};rep={tag}")


def fig3h(n: int = 192, p: int = 32, k: int = 16):
    """BGD linear regression (paper: n=30k, p=1000, k=16, 36.7× gap)."""
    m = n
    for model in ("linear", "exp", "skip"):
        app = BatchGradientDescent(m=m, n=n, p=p, k=k, eta=1e-2, model=model)
        app.initialize(BatchGradientDescent.synthesize(m, n, p))
        bench_app(f"fig3h_bgd_{model}", app, m, n, extra=f";model={model}")


def main():
    fig3g()
    fig3h()


if __name__ == "__main__":
    main()
