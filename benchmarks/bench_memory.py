"""Paper Table 3: memory requirements vs speedup for A^16.

INCR materializes every intermediate P_i (the price of incrementality);
REEVAL keeps only the current value.  We measure actual view-store bytes
and the speedup per update, reporting the paper's speedup-vs-memory-cost
ratio for growing n.
"""

from __future__ import annotations

import jax

from repro.apps import MatrixPowers
from repro.data.updates import UpdateStream
from .common import emit, time_updates


def view_bytes(engine) -> int:
    return sum(v.size * v.dtype.itemsize for v in engine.views.values())


def main(k: int = 16):
    for n in (128, 256, 512):
        app = MatrixPowers(n=n, k=k, model="exp")
        app.initialize(MatrixPowers.synthesize(n, seed=0))
        # fresh same-seed streams: the shared generator advances per draw
        t_incr = time_updates(app.update,
                              UpdateStream(n=n, m=n, scale=0.02, seed=3))
        t_reeval = time_updates(app.update_reeval,
                                UpdateStream(n=n, m=n, scale=0.02, seed=3))
        mem_incr = view_bytes(app.engine)
        mem_reeval = view_bytes(app.reeval) * (2 / len(app.engine.views))
        # reeval only needs A and the running square (2 matrices)
        mem_reeval = 2 * n * n * 4
        speedup = t_reeval / t_incr
        overhead = mem_incr / mem_reeval
        emit(f"table3_n{n}", t_incr * 1e6,
             f"mem_incr_MB={mem_incr/2**20:.1f};"
             f"mem_reeval_MB={mem_reeval/2**20:.1f};"
             f"speedup={speedup:.2f}x;ratio={speedup/overhead:.2f}")


if __name__ == "__main__":
    main()
