"""End-to-end LM training example.

Container-scale run (finishes in minutes on CPU, loss visibly drops):

  PYTHONPATH=src python examples/train_lm.py --steps 200

The 100M configuration the framework targets on real hardware:

  PYTHONPATH=src python examples/train_lm.py --arch custom-100m \
      --steps 300 --batch 32 --seq 1024 --mesh local --model-parallel 4

Features exercised: deterministic shard-aware pipeline, AdamW with mixed
precision, checkpoint/resume (kill it mid-run and re-invoke — it resumes
from the newest checkpoint), optional LINVIEW low-rank gradient
compression (--compression-rank 8).
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "custom-10m"]
    if "--ckpt-dir" not in " ".join(sys.argv):
        sys.argv += ["--ckpt-dir", "/tmp/repro_train_lm"]
    main()
