"""Serving + incremental view maintenance (DESIGN.md §4.3):

1. serve a small LM with batched requests,
2. cache classifier logits over a "corpus" of prompts,
3. hot-swap a rank-1 head update (one token's output row retrained) and
   maintain the cached logits through the LINVIEW trigger instead of
   re-running the model over the corpus.

  PYTHONPATH=src python examples/serve_incremental.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.train import custom_10m
from repro.models import build_model
from repro.serve import IncrementalLogitView, ServeEngine


def main():
    cfg = custom_10m()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # --- 1. batched generation -------------------------------------------
    eng = ServeEngine(model, params, batch_size=4, max_seq=256)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(4, 12)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=12)
    print(f"generated {out.shape} tokens in {time.perf_counter()-t0:.2f}s")

    # --- 2. corpus logit cache --------------------------------------------
    corpus = rng.integers(1, cfg.vocab, size=(64, 24)).astype(np.int32)
    logits, _ = model.forward(params, {"tokens": jnp.asarray(corpus)})
    hidden_like = np.asarray(logits[:, -1, :])  # (64, vocab) cached scores
    # maintain final-layer view: H = last hidden states, W = lm head
    # (recompute H once with the frozen backbone)
    h, _ = model.backbone(params, *(model.embed_inputs(
        params, {"tokens": jnp.asarray(corpus)})[:2]),)
    from repro.models import layers as L
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)[:, -1, :]
    W = params["lm_head"]["table"]
    view = IncrementalLogitView(np.asarray(h, np.float32),
                                np.asarray(W, np.float32), rank=1)

    # --- 3. rank-1 adapter hot-swap ---------------------------------------
    tok = 1234
    u = np.zeros((cfg.vocab, 1), np.float32)
    u[tok] = 1.0
    v = (0.05 * rng.normal(size=(cfg.d_model, 1))).astype(np.float32)

    t0 = time.perf_counter()
    maintained = view.update_head(jnp.asarray(u), jnp.asarray(v))
    jax.block_until_ready(maintained)
    t_incr = time.perf_counter() - t0

    # ground truth: re-encode the corpus with the patched head
    t0 = time.perf_counter()
    W2 = W + jnp.asarray(u @ v.T, W.dtype)
    truth = np.asarray(h, np.float32) @ np.asarray(W2, np.float32).T
    t_reeval = time.perf_counter() - t0

    err = float(np.max(np.abs(np.asarray(maintained) - truth)))
    print(f"hot-swap: maintained 64×{cfg.vocab} logit view in "
          f"{t_incr*1e3:.2f} ms (recompute {t_reeval*1e3:.2f} ms), "
          f"max err {err:.2e}")
    print(f"analytic speedup for this view: {view.speedup_estimate():.1f}×")


if __name__ == "__main__":
    main()
