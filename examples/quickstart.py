"""Quickstart: LINVIEW in 60 lines.

Define a linear-algebra program, compile it into update triggers, and
maintain its views under a stream of rank-1 updates — comparing against
full re-evaluation.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (IncrementalEngine, Program, ReevalEngine, dim,
                        inverse, matmul, transpose)

# --- 1. write the program (paper §3): OLS  β* = (XᵀX)⁻¹ Xᵀ Y -------------
m, n = 512, 128
prog = Program(name="ols")
M, N = dim("m"), dim("n")
X = prog.input("X", (M, N))
Y = prog.input("Y", (M, 1))
Z = prog.let("Z", matmul(transpose(X), X))
W = prog.let("W", inverse(Z))
beta = prog.let("beta", matmul(W, matmul(transpose(X), Y)))
prog.bind_dims(m=m, n=n)
print(prog)

# --- 2. compile to triggers (paper Alg. 1) --------------------------------
engine = IncrementalEngine(prog, update_ranks={"X": 1})
print()
print(engine.compiled.triggers["X"])   # the generated trigger program

# --- 3. initialize the views ----------------------------------------------
rng = np.random.default_rng(0)
Xv = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
Yv = jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)
engine.initialize({"X": Xv, "Y": Yv})

baseline = ReevalEngine(prog)
baseline.initialize({"X": Xv, "Y": Yv})

# --- 4. stream updates: one row of X changes ------------------------------
for step in range(5):
    u = np.zeros((m, 1), np.float32)
    u[rng.integers(0, m)] = 1.0
    v = (rng.normal(size=(n, 1)) * 0.1).astype(np.float32)
    engine.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    baseline.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    err = float(jnp.max(jnp.abs(engine.output() - baseline.output())))
    print(f"update {step}: max|Δβ*| between INCR and REEVAL = {err:.2e}")

print(f"\nanalytic FLOPs: trigger {engine.trigger_flops('X'):.2e} vs "
      f"re-evaluation {engine.reeval_flops():.2e} "
      f"({engine.reeval_flops()/engine.trigger_flops('X'):.1f}× less work)")
