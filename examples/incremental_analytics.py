"""Streaming analytics demo (paper §5): PageRank and gradient-descent
regression maintained under live graph/data edits.

  PYTHONPATH=src python examples/incremental_analytics.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.apps import BatchGradientDescent, PageRank


def pagerank_demo():
    print("=== incremental PageRank (power method, §5.3) ===")
    n = 256
    pr = PageRank(n=n, k=16, model="linear")
    pr.initialize(PageRank.synthesize(n, avg_degree=12, seed=0))
    rng = np.random.default_rng(1)
    for step in range(5):
        page = int(rng.integers(0, n))
        col = (rng.random(n) < 12 / n).astype(np.float32)
        col[page] = 0.0
        col /= max(col.sum(), 1.0)
        u, v = pr.edge_update(page, col)

        t0 = time.perf_counter()
        r_incr = pr.update(u, v)
        jax.block_until_ready(r_incr)
        t_incr = time.perf_counter() - t0

        t0 = time.perf_counter()
        r_reeval = pr.update_reeval(u, v)
        jax.block_until_ready(r_reeval)
        t_reeval = time.perf_counter() - t0

        top = int(jnp.argmax(r_incr))
        err = float(jnp.max(jnp.abs(r_incr - r_reeval)))
        print(f"  relink page {page:3d}: top page {top:3d}, "
              f"incr {t_incr*1e3:6.1f} ms vs reeval {t_reeval*1e3:6.1f} ms, "
              f"max err {err:.1e}")


def regression_demo():
    print("=== incremental gradient-descent regression (Fig. 3h) ===")
    m, n, p = 256, 64, 8
    app = BatchGradientDescent(m, n, p, k=16, eta=5e-2, model="exp")
    app.initialize(BatchGradientDescent.synthesize(m, n, p, seed=2))
    rng = np.random.default_rng(3)
    for step in range(5):
        row = int(rng.integers(0, m))
        u, v = app.row_update(row, rng.normal(size=n) * 0.05)
        theta = app.update(u, v)
        ref = app.update_reeval(u, v)
        err = float(jnp.max(jnp.abs(theta - ref)))
        print(f"  sample {row:3d} edited: ‖Θ‖={float(jnp.linalg.norm(theta)):.3f}, "
              f"incr-vs-reeval err {err:.1e}")
    print(f"  analytic speedup: {app.speedup_estimate():.1f}×")


if __name__ == "__main__":
    pagerank_demo()
    regression_demo()
