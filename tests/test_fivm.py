"""repro.fivm — learning over evolving data (ISSUE 10).

Property + regression suite for models maintained as incremental
views:

  * ring exactness: (c, s, G, XY, YY) against numpy oracles under
    mixed insert/delete streams (hypothesis-driven, REPRO_CHAOS_SEEDS
    matrix), including delete-heavy churn;
  * the downdate regression: insert-then-delete of the same row
    restores the ring bit-near-identically (the carriers cancel in the
    factor algebra — float summation order is the only residual);
  * solvers: incrementally maintained ridge/OLS/k-means match batch
    retrain-from-scratch within 1e-5, through both Cholesky
    update/downdate and the planner-priced refactor arm, with the
    non-PD downdate fallback exercised;
  * gradients as maintained views: ``grad = G·B − XY (+ λB at read)``
    stays correct as data keeps arriving after a ``set_model`` push of
    ``grad_compression`` factors;
  * the pinned-view registry (one ring, many models; pin/evict), the
    fleet tenant face (bit-identical to a local ring), the deferred
    (order=2, decoupled-refresh) and guarded rings;
  * the labeled stream contract: deterministic replay, stored-payload
    deletes, the churn knob.
"""

import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import assert_close
from repro.core import (LowRankCarrier, NoOpCarrier, RowLocalCarrier,
                        row_delta_carrier, solver_crossover_rank)
from repro.data import LabeledStream, labeled_stream
from repro.fivm import (DowndateError, KMeansSolver, OLSSolver, Ring,
                        RingRegistry, RingSpec, RidgeSolver, batch_kmeans,
                        batch_ridge, chol_rank1_update, solve_cholesky)
from repro.fivm.registry import submit_event
from repro.plan import solver_resolve_strategy

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("REPRO_CHAOS_SEEDS", "0").split(",")]

SPEC = RingSpec(features=8, targets=2, capacity=48, model_slots=2)


def drive(ring, stream, count):
    ring.apply_events(stream.events(count))


def oracle_views(stream: LabeledStream, spec: RingSpec):
    """Dense-replay oracle: the ring aggregates recomputed from the
    stream's live set."""
    X = np.zeros((spec.capacity, spec.features), np.float64)
    Y = np.zeros((spec.capacity, spec.targets), np.float64)
    W = np.zeros((spec.capacity, 1), np.float64)
    for slot in stream.live_slots:
        x, y = stream._live[slot]
        X[slot], Y[slot], W[slot] = x, y, 1.0
    return {"G": X.T @ X, "XY": X.T @ Y, "s": X.T @ W, "c": W.T @ W,
            "YY": Y.T @ Y}


# ---------------------------------------------------------------------------
# carriers: negation / downdate algebra
# ---------------------------------------------------------------------------


def dense_of(carrier):
    P, Q = carrier.factors()
    return np.asarray(P) @ np.asarray(Q).T


def test_carrier_negation_cancels():
    rng = np.random.default_rng(0)
    rl = row_delta_carrier([3, 7], rng.normal(size=(5, 2)), 12)
    lr = LowRankCarrier(rng.normal(size=(6, 2)).astype(np.float32),
                        rng.normal(size=(4, 2)).astype(np.float32))
    for c in (rl, lr):
        assert np.abs(dense_of(c) + dense_of(c.negate())).max() == 0.0
    assert isinstance(rl.negate(), RowLocalCarrier)
    assert list(rl.negate().rows) == [3, 7]   # support preserved
    noop = NoOpCarrier(5, 4)
    assert noop.negate().is_noop()


def test_row_delta_carrier_insert_delete_shapes():
    x = np.arange(4, dtype=np.float32)
    ins = row_delta_carrier(2, x, 10, weight=1.0)
    dele = row_delta_carrier(2, x, 10, weight=-1.0)
    d = dense_of(ins)
    assert d.shape == (10, 4) and np.array_equal(d[2], x)
    assert np.array_equal(dense_of(dele), -d)
    with pytest.raises(Exception):
        row_delta_carrier([0, 1], np.ones((4, 3)), 10)  # cols != rows


# ---------------------------------------------------------------------------
# labeled stream contract
# ---------------------------------------------------------------------------


def test_labeled_stream_deterministic_replay():
    a = labeled_stream(6, targets=2, capacity=16, churn=0.5, seed=9)
    b = labeled_stream(6, targets=2, capacity=16, churn=0.5, seed=9)
    ea, eb = a.events(120), b.events(120)
    for x, y in zip(ea, eb):
        assert x.kind == y.kind and x.slot == y.slot
        assert np.array_equal(x.x, y.x) and np.array_equal(x.y, y.y)
    a.reset()
    for x, y in zip(ea, a.events(120)):
        assert x.kind == y.kind and x.slot == y.slot


def test_labeled_stream_deletes_replay_stored_payload():
    s = labeled_stream(5, capacity=8, churn=0.6, seed=2)
    live = {}
    for ev in s.events(200):
        if ev.kind == "insert":
            live[ev.slot] = ev
        else:
            prev = live.pop(ev.slot)
            assert np.array_equal(prev.x, ev.x)
            assert np.array_equal(prev.y, ev.y)
            assert ev.weight == -1.0


def test_labeled_stream_churn_knob():
    def delete_frac(churn):
        # capacity > events: no forced deletes from slot exhaustion
        s = labeled_stream(4, capacity=512, churn=churn, seed=3)
        evs = s.events(400)
        return sum(e.kind == "delete" for e in evs) / len(evs)
    assert delete_frac(0.0) == 0.0
    assert delete_frac(0.2) < delete_frac(0.8)
    with pytest.raises(ValueError):
        labeled_stream(4, churn=1.0)


# ---------------------------------------------------------------------------
# ring exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
@settings(max_examples=2, deadline=None)
@given(case=st.integers(min_value=0, max_value=2 ** 16),
       churn=st.sampled_from([0.0, 0.35, 0.8]))
def test_ring_views_match_oracle(seed, case, churn):
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=churn,
                       seed=seed * 65537 + case)
    drive(ring, s, 150)
    got = ring.read("G", "XY", "s", "c", "YY")
    want = oracle_views(s, SPEC)
    for name in want:
        assert_close(got[name], want[name], rtol=1e-4, atol=1e-4,
                     msg=f"view {name} diverged (churn={churn})")
    assert ring.count() == pytest.approx(s.live_count)


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_insert_then_delete_restores_ring(seed):
    """The satellite regression: after any prefix, inserting a row and
    deleting it again restores every ring view bit-near-identically."""
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.3, seed=seed)
    drive(ring, s, 60)
    before = ring.read("G", "XY", "s", "c", "YY")
    # force an insert (churn can't fire with no free slot bookkeeping
    # changes mid-pair: drive the pair by hand)
    rng = np.random.default_rng(seed + 100)
    x = rng.normal(size=SPEC.features).astype(np.float32)
    y = rng.normal(size=SPEC.targets).astype(np.float32)
    from repro.data import LabeledUpdate
    slot = next(i for i in range(SPEC.capacity)
                if i not in s.live_slots)
    ring.apply(LabeledUpdate("insert", slot, x, y))
    mid = ring.gram()
    assert np.abs(mid - before["G"]).max() > 1e-3   # it did move
    ring.apply(LabeledUpdate("delete", slot, x, y))
    after = ring.read("G", "XY", "s", "c", "YY")
    for name in before:
        scale = max(np.abs(before[name]).max(), 1.0)
        resid = np.abs(after[name] - before[name]).max() / scale
        assert resid < 1e-6, (name, resid)


def test_ring_projection_view_is_row_local():
    """With proj_dim set, XP = X·R is provably row-local: row carriers
    fire the row-slab path (containment), while the gram-side views
    widen — both stay exact."""
    spec = RingSpec(features=8, targets=1, capacity=64, model_slots=0,
                    proj_dim=3)
    ring = Ring(spec)
    verdicts = ring.engine.compiled.triggers["X"].carriers
    assert verdicts.get("XP") == "row_local"
    assert verdicts.get("G") != "row_local"
    s = labeled_stream(spec.features, capacity=spec.capacity, churn=0.3,
                       seed=1)
    drive(ring, s, 80)
    got = ring.read("XP", "G")
    ring.engine.output()
    X = np.asarray(ring.engine.views["X"])
    R = np.asarray(ring.engine.views["R"])
    assert_close(got["XP"], X @ R, rtol=1e-4, atol=1e-4)
    assert ring.stats.rowlocal_firings > 0


# ---------------------------------------------------------------------------
# Cholesky update/downdate + pricing
# ---------------------------------------------------------------------------


def test_chol_rank1_update_and_downdate():
    rng = np.random.default_rng(4)
    n = 12
    A = rng.normal(size=(n, 2 * n))
    A = A @ A.T + np.eye(n)
    L = np.linalg.cholesky(A)
    x = rng.normal(size=n)
    chol_rank1_update(L, x, sign=1.0)
    assert_close(L @ L.T, A + np.outer(x, x), rtol=1e-9, atol=1e-9)
    chol_rank1_update(L, x, sign=-1.0)
    assert_close(L @ L.T, A, rtol=1e-8, atol=1e-8)


def test_chol_downdate_nonpd_raises():
    L = np.linalg.cholesky(np.eye(3))
    with pytest.raises(DowndateError):
        chol_rank1_update(L, np.array([2.0, 0.0, 0.0]), sign=-1.0)


def test_solve_cholesky_matches_solve():
    rng = np.random.default_rng(5)
    n = 9
    A = rng.normal(size=(n, 2 * n))
    A = A @ A.T + np.eye(n)
    L = np.linalg.cholesky(A)
    rhs = rng.normal(size=(n, 2))
    assert_close(solve_cholesky(L, rhs), np.linalg.solve(A, rhs),
                 rtol=1e-8, atol=1e-8)


def test_solver_resolve_strategy_crossover():
    n = 60
    k_star = solver_crossover_rank(n)
    assert k_star == 10
    assert solver_resolve_strategy(n, 1) == "update"
    assert solver_resolve_strategy(n, k_star - 1) == "update"
    assert solver_resolve_strategy(n, 2 * k_star) == "refactor"
    assert solver_resolve_strategy(n, 0) == "update"
    # cost_scale shifts the crossover down
    assert solver_resolve_strategy(n, k_star - 1,
                                   cost_scale=4.0) == "refactor"


# ---------------------------------------------------------------------------
# solvers vs batch retrain (the acceptance property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
@settings(max_examples=2, deadline=None)
@given(case=st.integers(min_value=0, max_value=2 ** 16),
       lam=st.sampled_from([0.0, 0.3]))
def test_ridge_matches_batch_retrain(seed, case, lam):
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.0,
                       seed=seed * 131 + case)
    drive(ring, s, SPEC.capacity)      # warm fill (well-conditioned)
    solver = RidgeSolver(ring, lam=lam)
    s.churn = 0.45
    for _ in range(3):                 # interleave churn and refresh
        drive(ring, s, 25)
        B = solver.coefficients()
        Xl, Yl = ring.live_data()
        assert Xl.shape[0] > SPEC.features
        B_batch = batch_ridge(Xl, Yl, lam)
        assert np.abs(B - B_batch).max() < 1e-5, \
            (lam, solver.stats.strategy_log)
    assert solver.stats.refreshes == 3


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_ridge_after_delete_heavy_churn(seed):
    spec = RingSpec(features=6, targets=1, capacity=64)
    ring = Ring(spec)
    s = labeled_stream(spec.features, capacity=spec.capacity, churn=0.0,
                       seed=seed + 17)
    drive(ring, s, spec.capacity)      # fill
    solver = RidgeSolver(ring, lam=0.1)
    solver.coefficients()
    s.churn = 0.85                     # delete-heavy
    drive(ring, s, 50)
    B = solver.coefficients()
    Xl, Yl = ring.live_data()
    assert 0 < Xl.shape[0] < spec.capacity
    assert np.abs(B - batch_ridge(Xl, Yl, 0.1)).max() < 1e-5
    # recovery signal: with λ-damping the fit still tracks w_true
    assert np.abs(B - s.w_true).max() < 0.5


def test_downdate_fallback_refactors():
    """A downdate that drains a pivot must fall back to the refactor
    arm, not crash — engineered by deleting the only example that
    spans a direction."""
    spec = RingSpec(features=3, targets=1, capacity=8)
    ring = Ring(spec)
    from repro.data import LabeledUpdate
    e1 = np.array([1.0, 0, 0], np.float32)
    e2 = np.array([0, 1.0, 0], np.float32)
    e3 = np.array([0, 0, 1.0], np.float32)
    y = np.ones(1, np.float32)
    for slot, x in enumerate((e1, e2, e3)):
        ring.apply(LabeledUpdate("insert", slot, x, y))
    solver = RidgeSolver(ring, lam=1e-6)
    solver.coefficients()
    ring.apply(LabeledUpdate("delete", 2, e3, y))   # drains z-direction
    B = solver.coefficients()
    assert np.isfinite(B).all()
    assert solver.stats.downdate_fallbacks >= 1 or \
        "refactor" in solver.stats.strategy_log


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_kmeans_matches_batch_retrain(seed):
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.4, seed=seed + 3)
    drive(ring, s, 170)
    km = KMeansSolver(ring, 3, seed=seed)
    C = km.fit()
    Xl, _ = ring.live_data()
    C_batch, labels = batch_kmeans(Xl, 3, seed=seed)
    assert np.abs(C - C_batch).max() < 1e-5
    assert np.array_equal(km.assign(Xl), labels)


def test_gradient_stays_maintained_after_data_arrival():
    """set_model pushes grad_compression factors through the B trigger;
    the grad view then tracks new data without another push."""
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.0, seed=11)
    drive(ring, s, 40)
    solver = RidgeSolver(ring, lam=0.2)
    B = solver.coefficients()          # pushes B into the ring
    s.churn = 0.4
    drive(ring, s, 30)                 # more data, NO re-solve
    g = ring.gradient(solver.slot, 0.2)
    want = ring.gram() @ B - ring.xty() + 0.2 * B
    assert_close(g, want, rtol=1e-4, atol=1e-4)
    assert np.abs(g).max() > 1e-3      # stale model: gradient nonzero


def test_ols_solver_is_lam_zero():
    ring = Ring(SPEC)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.0, seed=21)
    drive(ring, s, SPEC.capacity)
    ols = OLSSolver(ring)
    assert ols.lam == 0.0
    Xl, Yl = ring.live_data()
    assert np.abs(ols.coefficients() - batch_ridge(Xl, Yl, 0.0)).max() \
        < 1e-5


# ---------------------------------------------------------------------------
# deferred (decoupled-refresh) + guarded rings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_deferred_ring_matches_first_order(seed):
    """order=2: ingest banks factored deltas, the read folds — same
    answers as the per-firing ring, with folds accounted."""
    s1 = labeled_stream(SPEC.features, targets=SPEC.targets,
                        capacity=SPEC.capacity, churn=0.35, seed=seed)
    s2 = labeled_stream(SPEC.features, targets=SPEC.targets,
                        capacity=SPEC.capacity, churn=0.35, seed=seed)
    eager = Ring(SPEC)
    lazy = Ring(SPEC, order=2, fold_window=4)
    drive(eager, s1, 120)
    drive(lazy, s2, 120)
    ge, gl = eager.read("G", "XY"), lazy.read("G", "XY")
    assert_close(gl["G"], ge["G"], rtol=1e-4, atol=1e-4)
    assert_close(gl["XY"], ge["XY"], rtol=1e-4, atol=1e-4)
    assert lazy.stats.folds > 0
    solver = RidgeSolver(lazy, lam=0.1)
    B = solver.coefficients()
    Xl, Yl = lazy.live_data()
    assert np.abs(B - batch_ridge(Xl, Yl, 0.1)).max() < 1e-5


def test_guarded_ring_stays_exact():
    ring = Ring(SPEC, guard=True)
    s = labeled_stream(SPEC.features, targets=SPEC.targets,
                       capacity=SPEC.capacity, churn=0.3, seed=6)
    drive(ring, s, 90)
    want = oracle_views(s, SPEC)
    got = ring.read("G", "XY", "c")
    for name in got:
        assert_close(got[name], want[name], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# registry: one ring, many models; fleet face
# ---------------------------------------------------------------------------


def test_registry_shares_one_ring_across_models():
    reg = RingRegistry()
    spec = RingSpec(features=6, targets=1, capacity=32, model_slots=3)
    r1, r2 = reg.acquire(spec), reg.acquire(spec)
    assert r1 is r2
    ridge = reg.model(spec, "ridge", "ridge", lam=0.2)
    ols = reg.model(spec, "ols", "ols")
    km = reg.model(spec, "km", "kmeans", k=2)
    assert reg.model(spec, "ridge") is ridge      # idempotent
    assert ridge.slot != ols.slot                  # distinct B slots
    s = labeled_stream(spec.features, capacity=spec.capacity, churn=0.2,
                       seed=8)
    drive(r1, s, 70)
    Xl, Yl = r1.live_data()
    assert np.abs(ridge.coefficients()
                  - batch_ridge(Xl, Yl, 0.2)).max() < 1e-5
    assert np.abs(ols.coefficients()
                  - batch_ridge(Xl, Yl, 0.0)).max() < 1e-5
    km.fit()
    stats = reg.stats()
    assert stats["rings"] == 1 and len(stats["models"]) == 1
    assert reg.release(spec) == 1
    assert reg.release(spec) == 0 and reg.evictions == 1
    with pytest.raises(KeyError):
        reg.get(spec)


def test_registry_slot_exhaustion():
    reg = RingRegistry()
    spec = RingSpec(features=4, capacity=8, model_slots=1)
    reg.acquire(spec)
    reg.model(spec, "a", "ridge")
    with pytest.raises(RuntimeError, match="model slots"):
        reg.model(spec, "b", "ols")


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fleet_ring_tenant_matches_local(seed):
    """The fleet face: the same labeled events submitted as carriers
    through admission/lease-claimed refresh produce a bit-identical
    ring (the log replays the same representation)."""
    from repro.fleet import FleetConfig, FleetScheduler
    spec = RingSpec(features=5, targets=1, capacity=24, model_slots=1)
    fleet = FleetScheduler(FleetConfig(lease_ttl=0.5))
    reg = RingRegistry()
    reg.add_fleet_tenant(fleet, spec, "ring-t", slo_s=0.5)
    s = labeled_stream(spec.features, capacity=spec.capacity, churn=0.4,
                       seed=seed + 29)
    events = s.events(60)
    for ev in events:
        decs = submit_event(fleet, "ring-t", spec.capacity, ev)
        assert set(decs) == {"admitted"}
    fleet.run_until_idle()
    local = Ring(spec)
    local.apply_events(events)
    for name in ("G", "XY", "c"):
        assert np.abs(np.asarray(fleet.read_views("ring-t")[name])
                      - local.view(name)).max() == 0.0
    health = fleet.tenant_health()[0]
    assert health["pending"] == 0 and health["quarantined"] == 0


# ---------------------------------------------------------------------------
# app discovery
# ---------------------------------------------------------------------------


def test_app_registry_enumerates_fivm():
    from repro.apps import available_apps, get_app
    apps = available_apps()
    assert "fivm_learning" in apps and "ols" in apps
    with pytest.raises(KeyError, match="available"):
        get_app("nope")


def test_fivm_app_end_to_end():
    from repro.apps import get_app
    app = get_app("fivm_learning")(features=6, capacity=32, order=2,
                                   churn=0.3, seed=4)
    out = app.serve_demo(bursts=4, burst_size=12, reads=2)
    assert out["events"] == 48
    assert out["folds"] > 0                     # banked, folded on read
    assert out["refreshes"] >= 1
    B = app.model.coefficients()
    Xl, Yl = app.ring.live_data()
    assert np.abs(B - batch_ridge(Xl, Yl, app.model.lam)).max() < 1e-5
