"""Serving: engine generation, incremental logit views (LINVIEW serving
integration), and gradient compression."""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import IncrementalLogitView, ServeEngine


def test_engine_generates():
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_seq=128)
    prompts = np.ones((2, 8), np.int32)
    out = eng.generate(prompts, max_new=6)
    assert out.shape == (2, 6)
    assert out.dtype == np.int32


def test_engine_greedy_matches_forward_argmax():
    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(model, params, batch_size=1, max_seq=64)
    prompts = np.asarray([[5, 9, 2, 7]], np.int32)
    last = eng.prefill(prompts)
    full, _ = model.forward(params, {"tokens": jnp.asarray(prompts)})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_incremental_logit_view_exact(rng):
    m, d, p = 200, 64, 32
    H = rng.normal(size=(m, d)).astype(np.float32)
    W = rng.normal(size=(p, d)).astype(np.float32)
    view = IncrementalLogitView(H, W, rank=1)
    np.testing.assert_allclose(np.asarray(view.logits), H @ W.T, rtol=1e-4,
                               atol=1e-4)
    # rank-1 head update (e.g. one class/token row retrained)
    u = np.zeros((p, 1), np.float32)
    u[3] = 1.0
    v = (rng.normal(size=(d, 1)) * 0.1).astype(np.float32)
    got = view.update_head(jnp.asarray(u), jnp.asarray(v))
    want = H @ (W + u @ v.T).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)
    assert view.speedup_estimate() > 1.0


def test_incremental_logit_view_corpus_side(rng):
    m, d, p = 128, 32, 16
    H = rng.normal(size=(m, d)).astype(np.float32)
    W = rng.normal(size=(p, d)).astype(np.float32)
    view = IncrementalLogitView(H, W)
    u = np.zeros((m, 1), np.float32)
    u[10] = 1.0
    v = rng.normal(size=(d, 1)).astype(np.float32)
    got = view.add_items(jnp.asarray(u), jnp.asarray(v))
    want = (H + u @ v.T) @ W.T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


def test_view_covers_classification():
    assert IncrementalLogitView.covers("params/lm_head/table")
    assert not IncrementalLogitView.covers("params/blocks/attn/wq")


def test_grad_compression_roundtrip(rng):
    from repro.train import grad_compression as gc
    params = {"w": jnp.zeros((256, 128)), "b": jnp.zeros((128,))}
    state = gc.init_compression(params, rank=8, min_dim=64)
    # a genuinely low-rank "gradient"
    u = rng.normal(size=(256, 4)).astype(np.float32)
    v = rng.normal(size=(128, 4)).astype(np.float32)
    grads = {"w": jnp.asarray(u @ v.T), "b": jnp.ones((128,))}
    compressed, state2 = gc.compress_tree(grads, state)
    approx = gc.decompress_tree(compressed)
    # power iteration at rank 8 captures a rank-4 matrix near-exactly
    np.testing.assert_allclose(np.asarray(approx["w"]),
                               np.asarray(grads["w"]), rtol=1e-2, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(approx["b"]), np.ones((128,)))
    assert gc.compression_ratio(compressed) < 0.25


def test_grad_compression_error_feedback(rng):
    """Error feedback: over repeated steps with the SAME full-rank grad,
    the accumulated applied update converges to the true direction."""
    from repro.train import grad_compression as gc
    g = rng.normal(size=(96, 96)).astype(np.float32)
    params = {"w": jnp.zeros((96, 96))}
    state = gc.init_compression(params, rank=4, min_dim=32)
    applied = np.zeros_like(g)
    for _ in range(30):
        compressed, state = gc.compress_tree({"w": jnp.asarray(g)}, state)
        applied += np.asarray(gc.decompress_tree(compressed)["w"])
    applied /= 30
    err = np.linalg.norm(applied - g) / np.linalg.norm(g)
    # single-shot rank-4 compression of a 96×96 gaussian captures only
    # ~4/96 of the energy (err ≈ 0.98); error feedback must do far better
    assert err < 0.5, err


def test_train_step_with_compression_runs():
    from repro.train import grad_compression as gc
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(2))
    comp = gc.init_compression(state.params, rank=4, min_dim=64)
    step = jax.jit(make_train_step(model, compression=comp))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_vlm_prefill_then_decode_consistency():
    """paligemma: batched prefill over (bidirectional image prefix +
    text), then stepwise decode; the decode logits must match a longer
    forward pass that saw the same continuation tokens."""
    cfg = get_config("paligemma-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(11))
    B, T, EXTRA = 1, 8, 4
    patches = jax.random.normal(jax.random.PRNGKey(12),
                                (B, cfg.n_patches, cfg.frontend_dim))
    all_toks = jax.random.randint(jax.random.PRNGKey(13), (B, T + EXTRA),
                                  0, cfg.vocab)
    toks = all_toks[:, :T]

    s0 = cfg.n_patches + T
    logits, cache = model.prefill(
        params, {"patches": patches, "tokens": toks}, max_seq=s0 + EXTRA)
    full, _ = model.forward(params, {"patches": patches, "tokens": toks})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               rtol=2e-4, atol=2e-4)

    # stepwise decode of EXTRA tokens vs a longer teacher-forced forward
    full_ext, _ = model.forward(params, {"patches": patches,
                                         "tokens": all_toks})
    worst = 0.0
    for i in range(EXTRA):
        step_logits, cache = model.decode_step(
            params, cache, all_toks[:, T + i:T + i + 1],
            jnp.asarray(s0 + i, jnp.int32))
        want = full_ext[:, s0 + i, :]
        worst = max(worst, float(jnp.max(jnp.abs(
            step_logits[:, 0, :] - want))))
    assert worst < 5e-4, worst


def test_batched_prefill_matches_stepwise_dense():
    """Dense family: the one-pass prefill cache equals the cache built by
    stepping every prompt token through decode."""
    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(14))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(15), (B, S), 0, cfg.vocab)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=32)
    step_cache = model.init_cache(B, 32)
    for t in range(S):
        last, step_cache = model.decode_step(
            params, step_cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, -1], np.float32),
                               np.asarray(last[:, 0], np.float32),
                               rtol=2e-4, atol=2e-4)
    for k in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(cache["kv"][k][:, :, :S], np.float32),
            np.asarray(step_cache["kv"][k][:, :, :S], np.float32),
            rtol=2e-4, atol=2e-4)
