"""Checkpoint manager: full + LINVIEW incremental-delta round trips,
garbage collection keeps incremental bases alive, restart determinism."""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.checkpoint import CheckpointManager


def _tree(rng, scale=1.0):
    return {
        "w1": jnp.asarray(rng.normal(size=(64, 48)) * scale, jnp.float32),
        "nested": {"b": jnp.asarray(rng.normal(size=(48,)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_full_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree(rng)
    mgr.save(10, t, blocking=True)
    restored = mgr.restore(t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_roundtrip_low_rank_delta(tmp_path, rng):
    """A genuinely low-rank change must round-trip near-exactly through
    the factored incremental checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            incremental_rank=4, full_every=100)
    t = _tree(rng)
    mgr.save(0, t, blocking=True)
    u = rng.normal(size=(64, 2)).astype(np.float32)
    v = rng.normal(size=(48, 2)).astype(np.float32)
    t2 = dict(t)
    t2["w1"] = t["w1"] + u @ v.T
    path = mgr.save(1, t2, blocking=True)
    # the step-1 file must be incremental (factored payload)
    import json
    with open(path + ".json") as f:
        assert json.load(f)["kind"] == "incremental"
    data = np.load(path + ".npz")
    assert any(k.startswith("lr_p::") for k in data)
    restored = mgr.restore(t2, step=1)
    np.testing.assert_allclose(np.asarray(restored["w1"]),
                               np.asarray(t2["w1"]), rtol=1e-4, atol=1e-4)


def test_incremental_falls_back_on_high_rank_delta(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            incremental_rank=2, full_every=100,
                            max_rel_err=0.05)
    t = _tree(rng)
    mgr.save(0, t, blocking=True)
    t2 = dict(t)
    t2["w1"] = t["w1"] + jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    path = mgr.save(1, t2, blocking=True)
    data = np.load(path + ".npz")
    # full-rank noise cannot be sketched at rank 2 → raw fallback
    assert any(k.startswith("raw::") for k in data)
    restored = mgr.restore(t2, step=1)
    np.testing.assert_allclose(np.asarray(restored["w1"]),
                               np.asarray(t2["w1"]), rtol=1e-5)


def test_chained_incrementals(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            incremental_rank=4, full_every=4, keep=10)
    t = _tree(rng)
    trees = [t]
    mgr.save(0, t, blocking=True)
    cur = t
    for step in range(1, 6):
        u = rng.normal(size=(64, 1)).astype(np.float32) * 0.1
        v = rng.normal(size=(48, 1)).astype(np.float32)
        cur = dict(cur)
        cur["w1"] = cur["w1"] + u @ v.T
        mgr.save(step, cur, blocking=True)
        trees.append(cur)
    for step in (0, 2, 5):
        restored = mgr.restore(trees[step], step=step)
        np.testing.assert_allclose(np.asarray(restored["w1"]),
                                   np.asarray(trees[step]["w1"]),
                                   rtol=1e-3, atol=1e-3)


def test_latest_step_and_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep=2,
                            full_every=1)
    t = _tree(rng)
    for s in range(6):
        mgr.save(s, t, blocking=True)
    assert mgr.latest_step() == 5
    assert len(mgr.all_steps()) <= 2


def test_async_save_snapshot_isolation(tmp_path, rng):
    """The caller-thread staging must own its buffers: mutating (or
    donating) the live tree right after save() returns cannot corrupt
    the checkpoint, even though the D2H gather happens later on the
    writer thread."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    w = rng.normal(size=(64, 48)).astype(np.float32)
    tree = {"w": jnp.asarray(w), "host": w.copy()}
    mgr.save(1, tree)
    # simulate the training loop reusing/donating the buffers immediately
    tree["host"][:] = -1.0
    tree["w"] = jax.jit(lambda x: x * 0.0, donate_argnums=(0,))(tree["w"])
    mgr.wait()
    restored = mgr.restore({"w": jnp.zeros((64, 48), jnp.float32),
                            "host": np.zeros((64, 48), np.float32)}, step=1)
    np.testing.assert_allclose(np.asarray(restored["w"]), w, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(restored["host"]), w, rtol=1e-6)


def test_async_incremental_chain_encodes_on_writer_thread(tmp_path, rng):
    """Incremental encoding (which diffs against the previous
    reconstructed base) still chains correctly when every save is
    staged async."""
    mgr = CheckpointManager(str(tmp_path), async_save=True,
                            incremental_rank=4, full_every=100)
    t = _tree(rng)
    mgr.save(0, t)
    u = rng.normal(size=(64, 2)).astype(np.float32)
    v = rng.normal(size=(48, 2)).astype(np.float32)
    t2 = dict(t)
    t2["w1"] = t["w1"] + u @ v.T
    path = mgr.save(1, t2)
    mgr.wait()
    import json
    with open(path + ".json") as f:
        assert json.load(f)["kind"] == "incremental"
    restored = mgr.restore(t2, step=1)
    np.testing.assert_allclose(np.asarray(restored["w1"]),
                               np.asarray(t2["w1"]), rtol=1e-4, atol=1e-4)


def test_train_state_roundtrip(tmp_path):
    """Whole TrainState (params + opt) through the manager."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.train.train_step import init_train_state
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, state, blocking=True)
    restored = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
