"""repro.guard: quarantine, transactional rollback, drift sentinel,
chaos harness, checkpoint checksums, and serve-path degradation.

The chaos suite runs under REPRO_CHAOS_SEEDS (comma-separated; default
"0" locally, a matrix in CI) so recovery paths are exercised under
several deterministic fault sequences.
"""

import dataclasses
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.apps.matrix_powers import build_powers_program
from repro.apps.ols import build_ols_program
from repro.core.codegen import evaluate
from repro.core.runtime import EngineStats, IncrementalEngine
from repro.data.updates import UpdateStream
from repro.guard import (ChaosConfig, ChaosError, CircuitBreaker,
                         DegradePolicy, GuardConfig, GuardedView,
                         SentinelConfig, ValidationPolicy, validate_update)

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("REPRO_CHAOS_SEEDS", "0").split(",")]


def _ols_inputs(m=96, n=12, p=2, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, n)).astype(np.float32)
    Y = rng.standard_normal((m, p)).astype(np.float32)
    return {"X": X, "Y": Y}


def _snapshot(engine):
    return {k: np.asarray(v) for k, v in engine.views.items()}


def _reference_views(engine):
    """Re-evaluate every statement from the engine's current inputs."""
    env = {k: engine.views[k] for k in engine.program.inputs}
    for st in engine.program.statements:
        env[st.target.name] = evaluate(st.expr, env, engine.binding)
    return env


# ---------------------------------------------------------------------------
# layer 1: validation + quarantine
# ---------------------------------------------------------------------------


def test_validate_update_reasons():
    pol = ValidationPolicy(max_update_rank=2, max_norm=10.0)
    ok_u = np.ones((4, 1), np.float32)
    ok_v = np.ones((3, 1), np.float32)
    assert validate_update("X", ok_u, ok_v, (4, 3), pol) is None
    assert "2-D" in validate_update("X", ok_u[:, 0], ok_v, (4, 3), pol)
    assert "rows" in validate_update("X", ok_u, ok_v, (5, 3), pol)
    assert "ranks disagree" in validate_update(
        "X", np.ones((4, 2), np.float32), ok_v, (4, 3), pol)
    assert "floating point" in validate_update(
        "X", ok_u.astype(np.int32), ok_v, (4, 3), pol)
    assert "exceeds budget" in validate_update(
        "X", np.ones((4, 3), np.float32), np.ones((3, 3), np.float32),
        (4, 3), pol)
    bad = ok_u.copy()
    bad[0] = np.nan
    assert "non-finite" in validate_update("X", bad, ok_v, (4, 3), pol)
    assert "norm bound" in validate_update(
        "X", 100 * ok_u, 100 * ok_v, (4, 3), pol)


def test_quarantine_never_corrupts_views():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig())
    eng.initialize(_ols_inputs())
    before = _snapshot(eng)
    rng = np.random.default_rng(1)
    for kind in (np.nan, np.inf, -np.inf):
        u = rng.standard_normal((96, 1)).astype(np.float32)
        u[5] = kind
        v = rng.standard_normal((12, 1)).astype(np.float32)
        eng.apply_update("X", u, v)
        assert eng.enqueue_update("X", u, v) is None
    eng.guard.sync()  # resolve the deferred (in-program) screens
    assert len(eng.guard.quarantine) == 6
    assert eng.guard.stats.quarantined == 6
    after = _snapshot(eng)
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    # quarantine is inspectable per input
    assert len(eng.guard.quarantine.by_input("X")) == 6
    assert eng.guard.quarantine.reasons() == {
        "non-finite entries in update factors": 6}


def test_quarantine_replay_after_repair():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig())
    eng.initialize(_ols_inputs())
    u = np.full((96, 1), np.nan, np.float32)
    v = np.ones((12, 1), np.float32) * 0.01
    eng.apply_update("X", u, v)
    eng.guard.sync()
    assert len(eng.guard.quarantine) == 1

    def repair(rec):
        return np.nan_to_num(rec.u), rec.v

    applied, requarantined = eng.guard.quarantine.replay(eng, repair=repair)
    assert (applied, requarantined) == (1, 0)
    assert len(eng.guard.quarantine) == 0
    # replay without repair goes straight back to quarantine, not a loop
    eng.apply_update("X", u, v)
    applied, requarantined = eng.guard.quarantine.replay(eng)
    assert (applied, requarantined) == (0, 1)
    assert len(eng.guard.quarantine) == 1


def test_quarantine_capacity_evicts_oldest():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig(quarantine_capacity=3))
    eng.initialize(_ols_inputs())
    u = np.full((96, 1), np.nan, np.float32)
    v = np.ones((12, 1), np.float32)
    for _ in range(5):
        eng.apply_update("X", u, v)
    eng.guard.sync()
    assert len(eng.guard.quarantine) == 3
    assert eng.guard.quarantine.evicted == 2


# ---------------------------------------------------------------------------
# layer 2: transactional firings
# ---------------------------------------------------------------------------


def test_injected_trigger_fault_rolls_back_bit_identically():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig(),
                            chaos=ChaosConfig(seed=0, trigger_raise_p=1.0))
    eng.initialize(_ols_inputs())
    before_views = dict(eng.views)  # references: must be THE same arrays
    before_stats = dataclasses.replace(eng.stats)
    rng = np.random.default_rng(2)
    u = rng.standard_normal((96, 1)).astype(np.float32) * 0.01
    v = rng.standard_normal((12, 1)).astype(np.float32) * 0.01
    out = eng.apply_update("X", u, v)
    for k, arr in before_views.items():
        assert out[k] is arr, f"{k}: rollback must restore the same buffer"
    assert eng.stats == before_stats
    assert eng.guard.stats.rollbacks == 1
    assert eng.guard.stats.aborted_firings == 1
    assert eng.chaos.raises == 1
    # the aborted factors are quarantined for inspection
    assert len(eng.guard.quarantine) == 1


def test_nonfinite_output_rolls_back():
    """A finite-but-huge update passes admission, overflows f32 in the
    firing, and is caught by output validation + rolled back."""
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig())
    eng.initialize(_ols_inputs())
    before = _snapshot(eng)
    u = np.full((96, 1), 1e38, np.float32)
    v = np.full((12, 1), 1.0, np.float32)
    assert validate_update("X", u, v, (96, 12),
                           ValidationPolicy()) is None  # admissible
    eng.apply_update("X", u, v)
    eng.guard.sync()  # settle the deferred in-program rollback accounting
    after = _snapshot(eng)
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    assert eng.guard.stats.rollbacks == 1
    assert all(np.isfinite(a).all() for a in after.values())
    reasons = list(eng.guard.quarantine)[0].reason
    assert "non-finite output" in reasons


def test_norm_budget_blocks_huge_updates_at_admission():
    pol = ValidationPolicy(max_norm=1e6)
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig(validation=pol))
    eng.initialize(_ols_inputs())
    eng.apply_update("X", np.full((96, 1), 1e38, np.float32),
                     np.ones((12, 1), np.float32))
    assert eng.guard.stats.quarantined == 1
    assert eng.guard.stats.rollbacks == 0  # never reached the trigger


def test_guard_refuses_donate():
    prog = build_ols_program(m=96, n=12, p=2)
    with pytest.raises(ValueError, match="donate"):
        IncrementalEngine(prog, guard=GuardConfig(), donate=True)


def test_batched_firing_quarantines_only_poisoned():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, guard=GuardConfig())
    eng.initialize(_ols_inputs())
    rng = np.random.default_rng(3)
    ups = [(rng.standard_normal((96, 1)).astype(np.float32) * 0.01,
            rng.standard_normal((12, 1)).astype(np.float32) * 0.01)
           for _ in range(6)]
    ups[2] = (np.full((96, 1), np.nan, np.float32), ups[2][1])
    eng.apply_updates("X", ups)
    assert eng.guard.stats.quarantined == 1
    assert eng.guard.stats.admitted == 5
    assert all(np.isfinite(np.asarray(a)).all() for a in eng.views.values())
    ref = _reference_views(eng)
    np.testing.assert_allclose(np.asarray(eng.views["beta"]),
                               np.asarray(ref["beta"]), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# layer 3: drift sentinel
# ---------------------------------------------------------------------------


def test_sentinel_detects_and_recovers_drift():
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(
        prog, guard=GuardConfig(sentinel=SentinelConfig(probe_every=1,
                                                        tol=1e-3)))
    eng.initialize(_ols_inputs())
    # inject artificial drift: perturb a maintained view directly
    eng.views["Z"] = eng.views["Z"] + 0.5
    rng = np.random.default_rng(4)
    u = rng.standard_normal((96, 1)).astype(np.float32) * 0.01
    v = rng.standard_normal((12, 1)).astype(np.float32) * 0.01
    eng.apply_update("X", u, v)  # probe fires, sees the drift, recovers
    sen = eng.guard.sentinel
    assert sen.probes >= 1
    assert sen.recoveries >= 1
    assert eng.guard.stats.drift_recoveries >= 1
    ref = _reference_views(eng)
    for name in ("Z", "W", "beta"):
        np.testing.assert_allclose(np.asarray(eng.views[name]),
                                   np.asarray(ref[name]),
                                   rtol=5e-3, atol=5e-3)
    # drift probes after recovery are back under tolerance
    drifts = sen.probe(eng)
    assert all(d <= sen.config.tol for d in drifts.values()), drifts


def test_sentinel_feeds_planner_note_drift():
    from repro.plan import AdaptivePlanner
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(
        prog, plan=AdaptivePlanner(),
        guard=GuardConfig(sentinel=SentinelConfig(probe_every=1, tol=1e-3)))
    eng.initialize(_ols_inputs())
    eng.views["Z"] = eng.views["Z"] + 0.5
    rng = np.random.default_rng(5)
    eng.apply_update("X",
                     rng.standard_normal((96, 1)).astype(np.float32) * 0.01,
                     rng.standard_normal((12, 1)).astype(np.float32) * 0.01)
    assert eng.planner.drift_counts.get("Z", 0) >= 1


# ---------------------------------------------------------------------------
# the acceptance chaos run: 500 firings with poison + trigger faults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
@pytest.mark.parametrize("family", ["ols", "powers"])
def test_chaos_500_firings_stays_finite_and_converges(family, seed):
    if family == "ols":
        prog = build_ols_program(m=64, n=8, p=2)
        inputs = _ols_inputs(m=64, n=8, p=2, seed=seed)
        input_name, (n_rows, n_cols) = "X", (64, 8)
    else:
        prog = build_powers_program(k=4, n=24, model="exp")
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((24, 24)).astype(np.float32)
        a *= 0.9 / max(abs(np.linalg.eigvals(a)))
        inputs = {"A": a}
        input_name, (n_rows, n_cols) = "A", (24, 24)

    chaos = ChaosConfig(seed=seed, poison_p=0.01, poison_kind="nan",
                        trigger_raise_p=0.005)
    eng = IncrementalEngine(
        prog, guard=GuardConfig(sentinel=SentinelConfig(probe_every=100)),
        chaos=chaos)
    eng.initialize(inputs)
    stream = UpdateStream(n=n_rows, m=n_cols, scale=0.005,
                          seed=seed, zipf=1.5)
    it = iter(stream)
    for i in range(500):
        u, v = next(it)
        eng.apply_update(input_name, u, v)
        if i % 100 == 99:  # the engine never serves a non-finite view
            assert all(bool(jnp.isfinite(a).all())
                       for a in eng.views.values()), f"firing {i}"

    eng.guard.sync()
    g = eng.guard.stats
    assert eng.chaos.poisoned > 0, "chaos never fired — test is vacuous"
    assert g.quarantined == eng.chaos.poisoned
    assert g.rollbacks == eng.chaos.raises
    assert g.admitted + g.quarantined == 500
    assert all(bool(jnp.isfinite(a).all()) for a in eng.views.values())
    # final views match re-evaluation from the maintained inputs within
    # the sentinel tolerance (relative Frobenius residual)
    ref = _reference_views(eng)
    tol = eng.guard.sentinel.config.tol
    for st in prog.statements:
        name = st.target.name
        r = np.asarray(ref[name], np.float64)
        c = np.asarray(eng.views[name], np.float64)
        drift = np.linalg.norm(r - c) / max(np.linalg.norm(r), 1e-30)
        assert drift <= tol, f"{name}: drift {drift:.2e} > {tol}"


# ---------------------------------------------------------------------------
# checkpoint checksums + chain fallback
# ---------------------------------------------------------------------------


def _ckpt_tree(step, rng):
    return {"w": (rng.standard_normal((32, 16)) * 0.1 + step
                  ).astype(np.float32),
            "b": np.full((16,), float(step), np.float32)}


def test_checkpoint_checksum_fallback(tmp_path):
    from repro.dist.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            incremental_rank=4, full_every=10)
    rng = np.random.default_rng(0)
    trees = {s: _ckpt_tree(s, rng) for s in range(4)}
    for s in range(4):
        mgr.save(s, trees[s])
    # corrupt the newest payload's array bytes (zip still opens)
    path = os.path.join(str(tmp_path), "ckpt_00000003.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size - 64)
        f.write(b"\xff" * 32)
    restored = mgr.restore(trees[3])
    assert mgr.last_restored_step == 2
    np.testing.assert_allclose(restored["w"], trees[2]["w"], atol=2e-3)


def test_checkpoint_all_corrupt_raises(tmp_path):
    from repro.dist.checkpoint import (CheckpointCorruptError,
                                       CheckpointManager)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    rng = np.random.default_rng(0)
    tree = _ckpt_tree(0, rng)
    mgr.save(0, tree)
    path = os.path.join(str(tmp_path), "ckpt_00000000.npz")
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) - 64)
        f.write(b"\xff" * 32)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(tree)


def test_chaos_corrupts_and_manager_falls_back(tmp_path):
    """The chaos corrupt-checkpoint hook + checksum fallback, end to
    end through the manager's own write path."""
    from repro.dist.checkpoint import CheckpointManager
    chaos = ChaosConfig(seed=3, corrupt_checkpoint_p=1.0).monkey()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    rng = np.random.default_rng(0)
    trees = {s: _ckpt_tree(s, rng) for s in range(2)}
    mgr.save(0, trees[0])          # intact
    mgr._chaos = chaos
    mgr.save(1, trees[1])          # corrupted on write
    assert chaos.corruptions == 1
    restored = mgr.restore(trees[1])
    assert mgr.last_restored_step == 0
    np.testing.assert_array_equal(restored["b"], trees[0]["b"])


# ---------------------------------------------------------------------------
# supervisor survives chaos: host kill + corrupt-checkpoint restore
# ---------------------------------------------------------------------------


def test_supervisor_survives_host_kill_and_corrupt_checkpoint(tmp_path):
    from repro.dist.checkpoint import CheckpointManager
    from repro.dist.fault_tolerance import (FaultToleranceConfig,
                                            FaultTolerantController,
                                            TrainingSupervisor)

    class FakeClock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = FakeClock()
    chaos = ChaosConfig(seed=7, corrupt_checkpoint_p=0.5,
                        kill_host_p=0.0).monkey()
    mgr = CheckpointManager(str(tmp_path), async_save=False, chaos=chaos)
    ctl = FaultTolerantController(
        4, FaultToleranceConfig(heartbeat_timeout=5.0, min_hosts=1),
        clock=clock, chaos=chaos)
    sup = TrainingSupervisor(ctl, save_every=4)
    state = {"step": -1, "restores": 0}

    def step_fn(t):
        clock.t += 1.0
        state["step"] = t
        if t == 9:
            chaos._killed.add(2)  # deterministic mid-step host kill
        return 0.1

    def reporting_fn(t):
        return range(4)  # every host reports; chaos swallows the dead one

    def save_fn(t):
        mgr.save(t, {"step": np.asarray([t], np.int64)})

    def restore_fn():
        from repro.dist.checkpoint import CheckpointCorruptError
        state["restores"] += 1
        if mgr.latest_step() is None:
            return 0
        try:
            mgr.restore({"step": np.asarray([0], np.int64)})
        except CheckpointCorruptError:
            return 0  # every checkpoint corrupt: restart from scratch
        return mgr.last_restored_step

    restarts = sup.run(30, step_fn, save_fn, restore_fn,
                       reporting_fn=reporting_fn)
    assert restarts >= 1            # the kill forced a restart
    assert state["restores"] >= 1
    assert 2 not in ctl.alive_hosts()
    assert state["step"] == 29      # and the run still finished
    assert chaos.corruptions >= 1   # restore path really saw corruption


# ---------------------------------------------------------------------------
# layer 5: serve-path degradation
# ---------------------------------------------------------------------------


class _FlakyView:
    """Duck-typed logit view whose flush fails until told otherwise."""

    def __init__(self):
        self.logits = np.zeros((4, 4), np.float32)
        self.failing = False
        self.flushes = 0
        self.pending_updates = 0

    def submit_head_update(self, u, v):
        self.flush()
        return True

    def flush(self):
        if self.failing:
            raise RuntimeError("backend down")
        self.flushes += 1
        self.logits = self.logits + 1.0
        return self.logits


def test_circuit_breaker_state_machine():
    clock = {"t": 0.0}
    br = CircuitBreaker(threshold=2, reset_timeout=10.0,
                        clock=lambda: clock["t"])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clock["t"] += 10.0
    assert br.state == "half_open" and br.allow()
    br.record_failure()             # failed probe re-opens from now
    assert br.state == "open"
    clock["t"] += 10.0
    br.record_success()
    assert br.state == "closed" and br.consecutive_failures == 0


def test_guarded_view_degrades_to_snapshot_and_recovers():
    clock = {"t": 0.0}
    view = _FlakyView()
    gv = GuardedView(view,
                     DegradePolicy(max_retries=1, backoff_base=0.0,
                                   breaker_threshold=2, breaker_reset=30.0),
                     clock=lambda: clock["t"], sleep=lambda s: None)
    assert gv.flush()               # healthy: fresh serving
    good = np.asarray(view.logits).copy()
    view.failing = True
    assert not gv.flush()
    assert not gv.flush()           # second exhausted refresh trips it
    assert gv.breaker.state == "open"
    clock["t"] += 3.0
    out = gv.read()                 # degraded read: last-good snapshot
    np.testing.assert_array_equal(out, good)
    h = gv.health()
    assert h["serving"] == "snapshot"
    assert h["staleness_s"] == pytest.approx(3.0)
    assert h["degraded_reads"] == 1
    assert h["refresh_failures"] == 2
    clock["t"] += 30.0              # breaker half-opens, probe succeeds
    view.failing = False
    assert gv.flush()
    assert gv.breaker.state == "closed"
    assert gv.health()["serving"] == "fresh"
    assert gv.staleness() == 0.0


def test_serve_engine_view_health(tmp_path):
    pytest.importorskip("repro.serve")
    from repro.serve.incremental_views import IncrementalLogitView

    rng = np.random.default_rng(0)
    hidden = rng.standard_normal((8, 6)).astype(np.float32)
    head = rng.standard_normal((5, 6)).astype(np.float32)
    view = IncrementalLogitView(hidden, head, flush_size=2)
    gv = GuardedView(view, DegradePolicy(max_retries=0))
    u = rng.standard_normal((5, 1)).astype(np.float32) * 0.01
    v = rng.standard_normal((6, 1)).astype(np.float32) * 0.01
    gv.submit(u, v)
    assert gv.flush()
    h = gv.health()
    assert h["breaker"] == "closed" and h["serving"] == "fresh"
    ref = (np.asarray(hidden) @ (np.asarray(head) + u @ v.T).T)
    np.testing.assert_allclose(np.asarray(gv.read()), ref, rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# satellite regressions: UpdateStream, planner op scales, refit
# ---------------------------------------------------------------------------


def test_update_stream_batch_advances():
    """Regression: batch() used to re-seed per call, replaying the same
    updates forever (and ignoring prior iteration draws)."""
    s = UpdateStream(n=16, m=4, seed=5)
    u1, v1 = s.batch(3)
    u2, v2 = s.batch(3)
    assert not (np.array_equal(u1, u2) and np.array_equal(v1, v2))
    s.reset()
    u3, v3 = s.batch(3)
    np.testing.assert_array_equal(u1, u3)
    np.testing.assert_array_equal(v1, v3)
    # iteration and batch() share one advancing stream
    s2 = UpdateStream(n=16, m=4, seed=5)
    next(iter(s2))
    u4, _ = s2.batch(3)
    assert not np.array_equal(u1, u4)
    # two same-seed streams replay identically (the benchmark contract)
    a = UpdateStream(n=16, m=4, seed=9)
    b = UpdateStream(n=16, m=4, seed=9)
    ua, va = a.batch(4)
    ub, vb = b.batch(4)
    np.testing.assert_array_equal(ua, ub)
    np.testing.assert_array_equal(va, vb)


def test_planner_op_cost_scales_move_inverse_crossover():
    from repro.plan import MaintenancePlan, WorkloadDescriptor, plan_program
    prog = build_ols_program(m=256, n=32, p=4)
    wl = WorkloadDescriptor(update_rank=1, rank_lo=1, rank_hi=40)
    plain = plan_program(prog, wl)
    scaled = plan_program(
        prog, dataclasses.replace(wl, op_cost_scales={"inverse": 8.0}))
    # W := (XᵀX)⁻¹ is inverse-dominated: its effective crossover rises
    assert scaled.views["W"].crossover_rank > plain.views["W"].crossover_rank
    # matmul-dominated views are unaffected
    assert scaled.views["Z"].crossover_rank == plain.views["Z"].crossover_rank
    # and the straddling cell flips strategy: hybrid → incremental
    assert plain.views["W"].strategy == "hybrid"
    assert scaled.views["W"].strategy == "incremental"
    # op scales survive plan serialization
    rt = MaintenancePlan.from_json(scaled.to_json())
    assert rt.workload.op_cost_scales == {"inverse": 8.0}


def test_calibrate_op_cost_scales_shape():
    from repro.plan import calibrate_op_cost_scales
    scales = calibrate_op_cost_scales(n=64, samples=1)
    assert set(scales) == {"matmul", "inverse", "other"}
    assert scales["matmul"] == 1.0
    assert all(s >= 1e-3 for s in scales.values())


def test_adaptive_planner_refits_cost_scale_from_stats():
    from repro.core.compiler import compile_program
    from repro.plan import AdaptivePlanner
    prog = build_ols_program(m=256, n=32, p=4)
    ap = AdaptivePlanner(drift_tol=0.5)
    ap.bind(compile_program(prog))
    stats = EngineStats()
    assert ap.refit_from_stats(stats) is None  # unmeasurable: no-op
    stats.trigger_seconds, stats.sweep_flops_timed = 0.1, 1e6
    stats.reeval_seconds, stats.reeval_flops_timed = 0.1, 1e8
    scale = ap.refit_from_stats(stats)
    assert scale == pytest.approx(100.0)
    assert ap.workload.cost_scale == pytest.approx(100.0)
    # the material change forces a replan regardless of cadence
    new = ap.maybe_replan()
    assert new is not None
    assert any(vp.strategy != "incremental" for vp in new.views.values())


def test_refit_through_engine_firing_path():
    """EngineStats timed-FLOP counters feed the planner's online refit
    via _observe_firing without any manual wiring."""
    from repro.plan import AdaptivePlanner
    prog = build_ols_program(m=96, n=12, p=2)
    eng = IncrementalEngine(prog, plan=AdaptivePlanner(replan_every=2))
    eng.initialize(_ols_inputs())
    rng = np.random.default_rng(6)
    for _ in range(3):
        eng.apply_update("X",
                         rng.standard_normal((96, 1)).astype(np.float32)
                         * 0.01,
                         rng.standard_normal((12, 1)).astype(np.float32)
                         * 0.01, block=True)
    eng.reevaluate(block=True)
    assert eng.stats.sweep_flops_timed > 0
    assert eng.stats.reeval_flops_timed > 0
    scale = eng.planner.refit_from_stats(eng.stats)
    assert scale is not None and scale > 0

# ---------------------------------------------------------------------------
# higher-order (deferred-cascade) engines under guard (ISSUE 8)
# ---------------------------------------------------------------------------


def test_deferred_engine_never_takes_guard_fast_path():
    """The fused-transaction fast path skips host-side snapshots; a
    deferred cascade carries host window state, so it must stay off."""
    prog = build_powers_program(k=4, n=12, model="exp")
    eng = IncrementalEngine(prog, order=2, fold_window=2,
                            guard=GuardConfig())
    assert not eng._guard_fast_path
    assert IncrementalEngine(prog, guard=GuardConfig())._guard_fast_path


def test_higher_order_fault_rolls_back_cascade_bit_identically():
    """An aborted firing on an order-2 engine must restore the views AND
    the cascade window (factors, bases, counters) — a half-accumulated
    window would silently double-apply at the next fold."""
    prog = build_powers_program(k=4, n=12, model="exp")
    rng = np.random.default_rng(2)
    a = rng.standard_normal((12, 12)).astype(np.float32) * 0.2
    eng = IncrementalEngine(prog, order=2, fold_window=4,
                            guard=GuardConfig(),
                            chaos=ChaosConfig(seed=0, trigger_raise_p=1.0))
    eng.initialize({"A": a})
    # seed the window with one admitted update (chaos counts firings
    # before raising; probability 1.0 raises on every guarded attempt)
    before_cascade = eng._cascade_snapshot()
    before_views = dict(eng.views)
    u = rng.standard_normal((12, 1)).astype(np.float32) * 0.01
    v = rng.standard_normal((12, 1)).astype(np.float32) * 0.01
    out = eng.apply_update("A", u, v)
    for k, arr in before_views.items():
        assert out[k] is arr, f"{k}: rollback must restore the same buffer"
    factors, base, firings = eng._cascade_snapshot()
    bf_factors, bf_base, bf_firings = before_cascade
    assert firings == bf_firings
    assert {o: {k: len(v) for k, v in fs.items()}
            for o, fs in factors.items()} == \
        {o: {k: len(v) for k, v in fs.items()}
         for o, fs in bf_factors.items()}
    assert eng.guard.stats.rollbacks == 1
    assert eng.stats.folds == 0


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_fold_abort_refolds_exactly(seed):
    """Chaos raised inside a fold rolls the fold back and re-folds via
    the chaos-free exact path; the stream must end exact regardless."""
    prog = build_powers_program(k=4, n=12, model="exp")
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((12, 12)).astype(np.float32)
    a *= 0.5 / max(abs(np.linalg.eigvals(a)))
    chaos = ChaosConfig(seed=seed, trigger_raise_p=0.35)
    eng = IncrementalEngine(prog, order=2, fold_window=2,
                            guard=GuardConfig(), chaos=chaos)
    eng.initialize({"A": a})
    stream = UpdateStream(n=12, m=12, scale=0.01, seed=seed)
    it = iter(stream)
    for _ in range(30):
        u, v = next(it)
        eng.apply_update("A", u, v)
    eng.flush()
    assert eng.chaos.raises > 0, "chaos never fired — test is vacuous"
    assert eng.stats.folds > 0
    assert all(bool(jnp.isfinite(x).all()) for x in eng.views.values())
    # the maintained inputs hold exactly the admitted updates, so
    # re-evaluating from them is the exactness oracle
    ref = _reference_views(eng)
    for st in prog.statements:
        name = st.target.name
        r = np.asarray(ref[name], np.float64)
        c = np.asarray(eng.views[name], np.float64)
        err = np.abs(r - c).max() / max(np.abs(r).max(), 1.0)
        assert err <= 1e-5, f"{name}: {err:.2e}"


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_higher_order_chaos_matches_first_order_replay(seed):
    """Differential: an order-2 guarded engine under poison + trigger
    chaos stays exactly-once — its final state matches an isolated
    clean FIRST-order engine replaying only the admitted updates."""
    prog = build_powers_program(k=4, n=16, model="exp")
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    a *= 0.5 / max(abs(np.linalg.eigvals(a)))
    chaos = ChaosConfig(seed=seed, poison_p=0.05, poison_kind="nan",
                        trigger_raise_p=0.05)
    eng = IncrementalEngine(prog, order=2, fold_window=3,
                            guard=GuardConfig(), chaos=chaos)
    eng.initialize({"A": a})
    stream = UpdateStream(n=16, m=16, scale=0.005, seed=seed)
    it = iter(stream)
    applied = []
    n_updates = 60
    for _ in range(n_updates):
        u, v = next(it)
        before = eng.guard.stats.admitted
        aborted = eng.guard.stats.aborted_firings
        eng.apply_update("A", u, v)
        # "admitted" is admission control (validation passed); a chaos
        # abort rolls an admitted firing back and drops it — committed
        # means admitted AND not aborted
        if (eng.guard.stats.admitted > before
                and eng.guard.stats.aborted_firings == aborted):
            applied.append((u, v))
    eng.flush()
    eng.guard.sync()
    g = eng.guard.stats
    assert eng.chaos.poisoned > 0, "chaos never fired — test is vacuous"
    assert g.admitted + g.quarantined == n_updates  # exactly-once
    assert len(applied) == g.admitted - g.aborted_firings
    replay = IncrementalEngine(prog)  # clean, first-order
    replay.initialize({"A": a})
    for u, v in applied:
        replay.apply_update("A", u, v)
    for st in prog.statements:
        name = st.target.name
        r = np.asarray(replay.views[name], np.float64)
        c = np.asarray(eng.views[name], np.float64)
        err = np.abs(r - c).max() / max(np.abs(r).max(), 1.0)
        assert err <= 1e-5, f"{name}: {err:.2e}"
    np.testing.assert_array_equal(np.asarray(eng.views["A"]),
                                  np.asarray(replay.views["A"]))
