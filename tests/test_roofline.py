"""Roofline machinery: HLO walker trip-count correctness, collective
parsing with ring formulas, report math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import RooflineReport
from repro.roofline.hlo_walk import walk_hlo, _ring_wire


def test_walker_counts_scan_trips():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.zeros((256, 256), jnp.float32)
    ws = jnp.zeros((12, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    w = walk_hlo(c.as_text())
    expect = 12 * 2 * 256 ** 3
    assert abs(w.flops - expect) / expect < 0.01
    # XLA's own analysis misses the trip count — that's why the walker exists
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device
        ca = ca[0]
    assert ca["flops"] < w.flops / 5


def test_walker_nested_scan():
    def nested(x, ws):
        def outer(c, wgrp):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wgrp)
            return c, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    x = jnp.zeros((128, 128), jnp.float32)
    ws = jnp.zeros((3, 4, 128, 128), jnp.float32)
    c = jax.jit(nested).lower(x, ws).compile()
    w = walk_hlo(c.as_text())
    expect = 12 * 2 * 128 ** 3
    assert abs(w.flops - expect) / expect < 0.02


def test_walker_bytes_reasonable_for_elementwise():
    def f(a, b):
        return a * 2.0 + b

    a = jnp.zeros((1024, 1024), jnp.float32)
    c = jax.jit(f).lower(a, a).compile()
    w = walk_hlo(c.as_text())
    # 2 reads + 1 write of 4MB each = 12MB, allow ~3× slack for copies
    assert 8e6 < w.bytes < 5e7


def test_ring_formulas():
    # all-gather:每 chip sends its shard to g-1 peers
    assert _ring_wire("all-gather", 0, 100, 4) == 300
    assert _ring_wire("all-reduce", 0, 100, 4) == pytest.approx(150)
    assert _ring_wire("reduce-scatter", 25, 100, 4) == 75
    assert _ring_wire("all-to-all", 0, 100, 4) == 75
    assert _ring_wire("collective-permute", 0, 100, 4) == 100
    assert _ring_wire("all-reduce", 0, 100, 1) == 0


def test_report_math():
    r = RooflineReport(
        arch="a", shape="s", mesh="16x16", chips=256,
        hlo_flops_per_chip=197e12 * 0.1,       # 100 ms compute
        hlo_bytes_per_chip=819e9 * 0.05,       # 50 ms memory
        collective_bytes_per_chip=50e9 * 0.2,  # 200 ms collective
        model_flops=256 * 197e12 * 0.08,       # 80 ms useful
        model_bytes=0.0)
    assert r.bottleneck == "collective"
    assert r.t_bound == pytest.approx(0.2)
    assert r.roofline_fraction == pytest.approx(0.4)
    assert r.useful_flops_ratio == pytest.approx(0.8)


def test_collective_parse_on_real_psum():
    """A jitted psum over 1 device lowers with no inter-chip collectives;
    the walker must not invent wire bytes (group size 1 → 0)."""
    def f(x):
        return x + 1

    c = jax.jit(f).lower(jnp.zeros((128,))).compile()
    w = walk_hlo(c.as_text())
    assert w.collective_wire == 0.0


def test_model_flops_estimates_positive():
    from repro.configs import ARCHS, SHAPES, shape_applicable
    from repro.roofline.analysis import (model_bytes_estimate,
                                         model_flops_estimate)
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            assert model_flops_estimate(cfg, shape) > 0, (arch, shape.name)
            assert model_bytes_estimate(cfg, shape) > 0, (arch, shape.name)
