"""Engine-level distributed integration + cost-model auto-flush.

The mesh plumbing (does the engine route firings through the row-sharded
apply, does the output stay exact) is checked here on a 1-device mesh so
it runs in-process; multi-device numerics of the same code path are
covered by tests/test_distributed.py in subprocesses.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.ols import build_ols_program
from repro.core import IncrementalEngine, ReevalEngine, max_abs_diff
from repro.core.cost import batched_strategy
from repro.core.iterative import matrix_powers
from repro.data.updates import UpdateStream

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _updates(n, m, count, seed=3, rank=1):
    it = iter(UpdateStream(n=n, m=m, rank=rank, scale=0.02, seed=seed))
    return [next(it) for _ in range(count)]


def _powers_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    a = (0.5 / np.sqrt(n)) * rng.normal(size=(n, n))
    return {"A": jnp.asarray(a, jnp.float32)}


def _ols_inputs(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return {"X": jnp.asarray(rng.normal(size=(m, n)), jnp.float32),
            "Y": jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)}


# -- engine mesh= path --------------------------------------------------------


def test_engine_mesh_path_matches_single_device():
    """IncrementalEngine(mesh=...) fires every trigger through the
    row-sharded apply and stays exact (1-device mesh in-process)."""
    mesh = jax.make_mesh((1,), ("rows",))
    prog = matrix_powers(k=8, n=48, model="exp")
    dist = IncrementalEngine(prog, mesh=mesh)
    ref = IncrementalEngine(matrix_powers(k=8, n=48, model="exp"))
    dist.initialize(_powers_inputs(48))
    ref.initialize(_powers_inputs(48))

    ups = _updates(48, 48, 6, seed=13)
    for u, v in ups[:3]:
        dist.apply_update("A", jnp.asarray(u), jnp.asarray(v))
        ref.apply_update("A", jnp.asarray(u), jnp.asarray(v))
    dist.apply_updates("A", ups[3:], block=True)
    ref.apply_updates("A", ups[3:], block=True)
    assert max_abs_diff(dist.views, ref.views) < 1e-4
    assert dist.stats.triggers_fired == ref.stats.triggers_fired == 4


def test_engine_mesh_path_multi_device_subprocess():
    """Same engine path on a real 8-way mesh: sharded views, exact
    results vs the paper's re-evaluation baseline."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import IncrementalEngine, ReevalEngine, max_abs_diff
        from repro.core.iterative import matrix_powers
        from repro.data.updates import UpdateStream

        n = 64
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.normal(size=(n, n)) / 9, jnp.float32)
        mesh = jax.make_mesh((8,), ("rows",))
        eng = IncrementalEngine(matrix_powers(k=8, n=n, model="exp"),
                                mesh=mesh)
        ree = ReevalEngine(matrix_powers(k=8, n=n, model="exp"))
        eng.initialize({"A": A})
        ree.initialize({"A": A})
        # views actually live row-sharded on the mesh
        sh = eng.views["P8"].sharding
        assert getattr(sh, "mesh", None) is not None and \\
            len(sh.device_set) == 8, sh
        it = iter(UpdateStream(n=n, m=n, scale=0.02, seed=1))
        ups = [next(it) for _ in range(8)]
        eng.apply_updates("A", ups, block=True)
        for u, v in ups:
            ree.apply_update("A", jnp.asarray(u), jnp.asarray(v))
        err = max_abs_diff(eng.views, ree.views,
                           tuple(eng.program.output_names()))
        assert err < 1e-3, err
        print("engine mesh OK", err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"


def test_planned_engine_multi_device_subprocess():
    """A maintenance plan executing on a real 8-way mesh: the planned
    firing (incremental + in-firing reeval partition) stays exact vs the
    re-evaluation baseline, and plans carry the mesh into the trigger
    cache key so a second engine re-jits nothing."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import IncrementalEngine, ReevalEngine, max_abs_diff
        from repro.core.iterative import matrix_powers
        from repro.data.updates import UpdateStream
        from repro.plan import TriggerCache, WorkloadDescriptor

        n = 64
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.normal(size=(n, n)) / 9, jnp.float32)
        mesh = jax.make_mesh((8,), ("rows",))
        cache = TriggerCache()
        wl = WorkloadDescriptor(batch_size=100000)  # all views reeval
        eng = IncrementalEngine(matrix_powers(k=8, n=n, model="exp"),
                                mesh=mesh, plan=wl, trigger_cache=cache)
        ree = ReevalEngine(matrix_powers(k=8, n=n, model="exp"))
        eng.initialize({"A": A})
        ree.initialize({"A": A})
        it = iter(UpdateStream(n=n, m=n, scale=0.02, seed=1))
        ups = [next(it) for _ in range(8)]
        eng.apply_updates("A", ups, block=True)
        assert eng.stats.plan_reevals > 0
        for u, v in ups:
            ree.apply_update("A", jnp.asarray(u), jnp.asarray(v))
        err = max_abs_diff(eng.views, ree.views,
                           tuple(eng.program.output_names()))
        assert err < 1e-3, err
        misses = cache.misses
        eng2 = IncrementalEngine(matrix_powers(k=8, n=n, model="exp"),
                                 mesh=mesh, plan=wl, trigger_cache=cache)
        eng2.initialize({"A": A})
        eng2.apply_updates("A", ups, block=True)
        assert cache.misses == misses, (cache.stats(), misses)
        err2 = max_abs_diff(eng2.views, eng.views)
        assert err2 < 1e-5, err2
        print("planned mesh OK", err, cache.stats())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"


# -- cost-model-driven auto-flush ---------------------------------------------


def test_cost_flush_rank_matches_cost_model():
    """The 'cost' policy's flush point is the first stacked rank where
    batched_strategy stops answering 'stacked' for some view."""
    eng = IncrementalEngine(build_ols_program(96, 48, 1),
                            flush_policy="cost", flush_age=1e9)
    eng.initialize(_ols_inputs(96, 48))
    k_star = eng.cost_flush_rank("X")
    assert k_star > 1
    costs = eng._lowrank_view_costs("X")
    assert costs, "OLS trigger maintains factored views"
    # one rank below: every view still prefers the stacked trigger
    assert all(batched_strategy(shape, k_star - 1, k_star - 1, re) ==
               "stacked" for shape, re in costs)
    # at k_star: some view's incremental sweep loses to re-evaluation
    assert any(batched_strategy(shape, k_star, k_star, re) != "stacked"
               for shape, re in costs)


def test_cost_policy_flushes_exactly_at_crossover():
    eng = IncrementalEngine(build_ols_program(96, 48, 1),
                            flush_policy="cost", flush_age=1e9)
    eng.initialize(_ols_inputs(96, 48))
    k_star = eng.cost_flush_rank("X")
    ups = _updates(96, 48, k_star, seed=29)
    for i, (u, v) in enumerate(ups):
        flushed = eng.enqueue_update("X", u, v)
        assert (flushed is not None) == (i == k_star - 1), (i, k_star)
    assert eng.pending_rank("X") == 0
    assert eng.stats.batches_applied == 1
    assert eng.stats.updates_applied == k_star

    ree = ReevalEngine(build_ols_program(96, 48, 1))
    ree.initialize(_ols_inputs(96, 48))
    for u, v in ups:
        ree.apply_update("X", jnp.asarray(u), jnp.asarray(v))
    assert max_abs_diff(eng.views, ree.views, ("beta",)) < 1e-3


def test_cost_policy_staleness_still_bounds_latency():
    eng = IncrementalEngine(build_ols_program(96, 48, 1),
                            flush_policy="cost", flush_age=0.0)
    eng.initialize(_ols_inputs(96, 48))
    (u, v), = _updates(96, 48, 1, seed=31)
    assert eng.enqueue_update("X", u, v) is not None


def test_flush_policy_validated():
    with pytest.raises(ValueError):
        IncrementalEngine(build_ols_program(96, 48, 1), flush_policy="vibes")


# -- serve checkpoint hooks ---------------------------------------------------


def test_serve_engine_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.dist.checkpoint import CheckpointManager
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=1, max_seq=64)
    prompts = np.asarray([[5, 9, 2, 7]], np.int32)
    want = eng.generate(prompts, max_new=4)

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    eng.save_checkpoint(mgr, step=1, blocking=True)
    # corrupt the live weights, then restore
    eng.params = jax.tree.map(lambda p: p * 0.0, eng.params)
    eng.restore_checkpoint(mgr, step=1)
    got = eng.generate(prompts, max_new=4)
    np.testing.assert_array_equal(got, want)
