"""Fault-tolerance control plane: failure detection, straggler eviction,
elastic mesh planning, and a full supervised run with injected failures."""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import numpy as np

from repro.dist.fault_tolerance import (FaultToleranceConfig,
                                        FaultTolerantController, RunPhase,
                                        TrainingSupervisor, plan_mesh)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _controller(n=8, **kw):
    clock = FakeClock()
    ctl = FaultTolerantController(
        n, FaultToleranceConfig(heartbeat_timeout=10.0, **kw), clock=clock)
    return ctl, clock


def test_heartbeat_failure_detection():
    ctl, clock = _controller()
    for _ in range(3):
        clock.advance(2.0)
        for h in range(8):
            ctl.heartbeat(h, 0.1)
        assert ctl.tick() == RunPhase.RUNNING
    # host 3 goes silent
    clock.advance(11.0)
    for h in range(8):
        if h != 3:
            ctl.heartbeat(h, 0.1)
    assert ctl.tick() == RunPhase.RESHAPING
    assert 3 not in ctl.alive_hosts()
    ctl.complete_reshape()
    assert ctl.phase == RunPhase.RUNNING


def test_straggler_eviction():
    ctl, clock = _controller(straggler_factor=1.5, straggler_patience=3)
    for step in range(6):
        clock.advance(1.0)
        for h in range(8):
            ctl.heartbeat(h, 1.0 if h != 5 else 2.5)
        ctl.tick()
    assert 5 not in ctl.alive_hosts()
    assert any("straggler" in e for e in ctl.events)


def test_min_hosts_halt():
    ctl, clock = _controller(min_hosts=8)
    clock.advance(11.0)
    ctl.heartbeat(0, 0.1)
    assert ctl.tick() == RunPhase.HALTED


def test_rejoin_triggers_reshape():
    ctl, clock = _controller()
    clock.advance(11.0)
    for h in range(7):
        ctl.heartbeat(h, 0.1)
    ctl.tick()
    ctl.complete_reshape()
    ctl.rejoin(7)
    assert ctl.phase == RunPhase.RESHAPING


def test_plan_mesh_shapes():
    assert plan_mesh(256, 16) == ((16, 16), ("data", "model"))
    assert plan_mesh(512, 16, multi_pod_size=256) == \
        ((2, 16, 16), ("pod", "data", "model"))
    # elastic downsize: 240 devices after 1 host of 16 died
    assert plan_mesh(240, 16) == ((15, 16), ("data", "model"))
    with pytest.raises(ValueError):
        plan_mesh(250, 16)


def test_supervised_run_with_injected_failure(tmp_path):
    """End-to-end: training loop restarts from checkpoint when a host
    dies mid-run, and finishes all steps."""
    ctl, clock = _controller()
    sup = TrainingSupervisor(ctl, save_every=5)
    state = {"step": 0, "restored": 0}
    saved = {}
    dead = set()

    def step_fn(step):
        clock.advance(1.0)
        state["step"] = step
        if step == 12:
            dead.add(2)  # host 2 stops heartbeating mid-run
        return 0.1

    def reporting_fn(step):
        return [h for h in range(8) if h not in dead]

    def save_fn(step):
        saved["step"] = step

    def restore_fn():
        state["restored"] += 1
        return saved.get("step", 0)

    restarts = sup.run(40, step_fn, save_fn, restore_fn,
                       reporting_fn=reporting_fn)
    assert restarts == 1
    assert state["restored"] == 1
    assert 2 not in ctl.alive_hosts()
    assert state["step"] == 39


def test_supervisor_run_start_step():
    """A resumed run enters the loop at start_step, not 0."""
    ctl, clock = _controller(n=2)
    sup = TrainingSupervisor(ctl, save_every=0)
    seen = []

    def step_fn(step):
        clock.advance(0.5)
        seen.append(step)
        return 0.1

    sup.run(8, step_fn, lambda s: None, lambda: 0, start_step=5)
    assert seen == [5, 6, 7]


def test_train_driver_runs_supervisor(tmp_path):
    """launch/train.py actually drives the restart/eviction controller:
    an injected mid-run failure causes a checkpoint restore and the run
    still finishes every step (ROADMAP open item)."""
    import dataclasses
    from repro.launch.train import custom_10m, train

    cfg = dataclasses.replace(custom_10m(), n_layers=1, d_model=32, d_ff=64,
                              vocab=128, n_heads=2, n_kv_heads=2, head_dim=16)

    clock = FakeClock()
    fired = {"done": False}
    steps_seen = []

    class InjectingController(FaultTolerantController):
        def tick(self):
            if len(steps_seen) == 4 and not fired["done"]:
                fired["done"] = True
                self._last_seen[1] -= 100.0  # heartbeat long expired
            return super().tick()

    ctl = InjectingController(
        2, FaultToleranceConfig(heartbeat_timeout=3.0), clock=clock)

    import repro.launch.train as train_mod
    orig_synth = train_mod.synth_batch

    def counting_synth(*a, **kw):
        steps_seen.append(kw.get("step"))
        clock.advance(0.1)
        return orig_synth(*a, **kw)

    train_mod.synth_batch = counting_synth
    try:
        result = train(cfg, steps=6, batch=2, seq=8,
                       ckpt_dir=str(tmp_path), save_every=2,
                       log_every=100, controller=ctl)
    finally:
        train_mod.synth_batch = orig_synth
    assert result["restarts"] == 1
    assert result["phase"] == "running"
    assert any("failed host 1" in e for e in result["ft_events"])
    # the run resumed from the last checkpoint and completed all steps
    assert max(steps_seen) == 5


def test_deterministic_data_after_restart():
    """Restart determinism: batch k is identical before/after restart."""
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import synth_batch
    cfg = get_config("starcoder2-7b").reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    a = synth_batch(cfg, shape, seed=5, step=17)
    b = synth_batch(cfg, shape, seed=5, step=17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(cfg, shape, seed=5, step=18)
    assert not np.array_equal(a["tokens"], c["tokens"])
