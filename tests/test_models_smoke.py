"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; decode == prefill consistency where applicable."""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data.pipeline import synth_batch
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.train.train_step import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, b=2, s=64, seed=0):
    shape = ShapeConfig("smoke", s, b, "train")
    batch = synth_batch(cfg, shape, seed=seed)
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = model.forward(params, batch)
    b = batch[next(iter(batch))].shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, lr=1e-3, warmup=1, total_steps=10))
    batch = _smoke_batch(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_decreases_over_few_steps(arch):
    """The substrate can actually learn: 8 steps on a fixed batch."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(model, lr=3e-3, warmup=1,
                                   total_steps=100))
    batch = _smoke_batch(cfg, seed=7)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


DECODE_ARCHS = [a for a in ALL_ARCHS if not ARCHS[a].encoder_only]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:  # avoid capacity-drop divergence in the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts from an image prefill (covered in "
                    "test_serve)")
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, 32)
    worst = 0.0
    for t in range(S):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                          jnp.asarray(t, jnp.int32))
        worst = max(worst, float(jnp.max(jnp.abs(
            logits[:, 0, :] - full[:, t, :]))))
    assert worst < 5e-4, worst


def test_sliding_window_masks_distant_tokens():
    """SWA (h2o-danube): logits at position t must not depend on tokens
    further back than the window."""
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                              sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    B, S = 1, 32
    t1 = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab)  # mutate a distant token
    l1, _ = model.forward(params, {"tokens": t1})
    l2, _ = model.forward(params, {"tokens": t2})
    # last position is > window away from position 0
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_prefix_lm_bidirectional_attention():
    """paligemma: a patch at the END of the prefix influences logits of
    positions before it (bidirectional prefix), unlike a causal model."""
    cfg = get_config("paligemma-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    B = 1
    patches = jax.random.normal(jax.random.PRNGKey(8),
                                (B, cfg.n_patches, cfg.frontend_dim))
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, 24), 0, cfg.vocab)
    l1, _ = model.forward(params, {"patches": patches, "tokens": toks})
    patches2 = patches.at[:, -1].add(3.0)
    l2, _ = model.forward(params, {"patches": patches2, "tokens": toks})
    # logits at the FIRST patch position must differ (bidirectional prefix)
    assert float(jnp.max(jnp.abs(l1[:, 0] - l2[:, 0]))) > 1e-6


def test_moe_router_load_balancing_aux():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(10))
    batch = _smoke_batch(cfg)
    _, aux = model.forward(params, batch)
    assert float(aux) > 0.0


def test_param_count_sanity():
    """Full configs match their nominal sizes (within naming tolerance)."""
    approx = {
        "qwen2-moe-a2.7b": (14.3e9, 0.25),
        "command-r-plus-104b": (104e9, 0.15),
        "starcoder2-7b": (7e9, 0.25),
        "qwen1.5-32b": (32e9, 0.25),
        "hubert-xlarge": (1e9, 0.5),
        "xlstm-350m": (0.35e9, 0.5),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)
