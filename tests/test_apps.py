"""App-level integration: every paper workload, incremental == reeval,
analytic speedups match Table 2's ordering."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (OLS, BatchGradientDescent, GeneralIterative,
                        MatrixPowers, PageRank, SumsOfPowers)
from repro.data.updates import UpdateStream

from conftest import assert_close


def _rel(a, b):
    ref = np.abs(np.asarray(b)).max() or 1.0
    return np.abs(np.asarray(a) - np.asarray(b)).max() / ref


def test_ols_stream_of_row_updates(rng):
    m, n, p = 96, 24, 3
    app = OLS(m, n, p)
    inputs, beta_true = OLS.synthesize(m, n, p, seed=1)
    app.initialize(inputs)
    stream = UpdateStream(n=m, m=n, scale=0.05, seed=2)
    it = iter(stream)
    for _ in range(5):
        u, v = next(it)
        a = app.update(jnp.asarray(u), jnp.asarray(v))
        b = app.update_reeval(jnp.asarray(u), jnp.asarray(v))
    assert _rel(a, b) < 1e-3
    # estimate should still be close-ish to the generating beta
    assert np.abs(np.asarray(a) - beta_true).mean() < 0.5


def test_ols_speedup_estimate_grows_with_n():
    s1 = OLS(256, 64).speedup_estimate()
    s2 = OLS(1024, 256).speedup_estimate()
    assert s2 > s1 > 1.0


@pytest.mark.parametrize("model", ["linear", "exp", "skip"])
def test_matrix_powers_models(model, rng):
    app = MatrixPowers(n=40, k=8, model=model)
    app.initialize(MatrixPowers.synthesize(40, seed=0))
    u, v = app.row_update(3, rng.normal(size=40) * 0.1)
    a = app.update(u, v)
    b = app.update_reeval(u, v)
    assert _rel(a, b) < 1e-3


def test_powers_exp_cheaper_than_linear():
    """Table 2: incremental exp O(n²k) beats linear O(n²k²)."""
    lin = MatrixPowers(n=64, k=16, model="linear")
    exp = MatrixPowers(n=64, k=16, model="exp")
    assert exp.engine.trigger_flops("A") < lin.engine.trigger_flops("A")


def test_incremental_beats_reeval_asymptotically():
    """Table 2: incr exp O(n²k) vs reeval exp O(n³ log k)."""
    app = MatrixPowers(n=256, k=16, model="exp")
    assert app.speedup_estimate() > 4.0


def test_sums_of_powers(rng):
    app = SumsOfPowers(n=32, k=8, model="exp")
    app.initialize(SumsOfPowers.synthesize(32))
    u, v = np.zeros((32, 1), np.float32), rng.normal(size=(32, 1)) * 0.1
    u[5] = 1.0
    a = app.update(jnp.asarray(u), jnp.asarray(v.astype(np.float32)))
    b = app.update_reeval(jnp.asarray(u), jnp.asarray(v.astype(np.float32)))
    assert _rel(a, b) < 1e-3


@pytest.mark.parametrize("p_dim,expect_dense", [(1, True), (48, False)])
def test_general_form_hybrid_choice(p_dim, expect_dense, rng):
    """§5.3: p=1 should choose the hybrid (dense) representation for the
    T-views; large p should stay factored."""
    app = GeneralIterative(n=48, p=p_dim, k=8, model="linear")
    reps = app.engine.compiled.triggers["A"].reps
    t_reps = {k: v for k, v in reps.items() if k.startswith("T")}
    if expect_dense:
        assert all(v == "dense" for v in t_reps.values())
    else:
        assert all(v == "lowrank" for v in t_reps.values())
    app.initialize(GeneralIterative.synthesize(48, p_dim))
    u = np.zeros((48, 1), np.float32)
    u[2] = 1.0
    v = (rng.normal(size=(48, 1)) * 0.1).astype(np.float32)
    a = app.update(jnp.asarray(u), jnp.asarray(v))
    b = app.update_reeval(jnp.asarray(u), jnp.asarray(v))
    assert _rel(a, b) < 1e-3


def test_pagerank_maintains_distribution(rng):
    app = PageRank(n=50, k=8, model="linear")
    app.initialize(PageRank.synthesize(50, seed=3))
    col = (rng.random(50) < 0.2).astype(np.float32)
    col[7] = 0
    col /= max(col.sum(), 1.0)
    u, v = app.edge_update(7, col)
    a = app.update(u, v)
    b = app.update_reeval(u, v)
    assert _rel(a, b) < 1e-3
    assert abs(float(jnp.sum(a)) - 1.0) < 1e-2  # still ~a distribution


def test_bgd_converges_and_matches(rng):
    m, n, p = 64, 16, 4
    app = BatchGradientDescent(m, n, p, k=16, eta=0.05, model="linear")
    inputs = BatchGradientDescent.synthesize(m, n, p)
    app.initialize(inputs)
    u, v = app.row_update(1, rng.normal(size=n) * 0.05)
    a = app.update(u, v)
    b = app.update_reeval(u, v)
    assert _rel(a, b) < 1e-3
    # after 16 GD steps the loss should be well below the zero-init loss
    X, Y = np.asarray(inputs["X"]), np.asarray(inputs["Y"])
    X = X + np.asarray(u) @ np.asarray(v).T
    loss = np.mean((X @ np.asarray(a) - Y) ** 2)
    assert loss < np.mean(Y ** 2) * 0.9


def test_batch_updates_rank_k(rng):
    """Table 4 setting: a batch of row updates applied as one rank-k
    trigger firing equals applying them via re-evaluation."""
    n = 40
    app = MatrixPowers(n=n, k=8, model="exp", rank=8)
    app.initialize(MatrixPowers.synthesize(n, seed=5))
    stream = UpdateStream(n=n, m=n, zipf=2.0, scale=0.05, seed=6)
    U, V = stream.batch(8)
    a = app.update(jnp.asarray(U), jnp.asarray(V))
    b = app.update_reeval(jnp.asarray(U), jnp.asarray(V))
    assert _rel(a, b) < 1e-3
