"""End-to-end behaviour tests for the whole system: the paper's pipeline
(program → compiler → triggers → maintained views) driving real analytics,
plus the LM substrate trained end-to-end with checkpoint/restart."""

import pytest

pytest.importorskip("repro.dist", reason="repro.dist is not built yet (see ROADMAP open items)")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import OLS, MatrixPowers
from repro.configs import get_config
from repro.core import IncrementalEngine
from repro.data.updates import UpdateStream
from repro.dist.checkpoint import CheckpointManager
from repro.models import build_model
from repro.train.train_step import init_train_state, make_train_step


def test_full_ivm_pipeline_sustained_stream():
    """The paper's headline scenario: a continuous update stream against a
    maintained analytical view; incremental stays in lockstep with
    re-evaluation over many updates (no drift)."""
    n = 48
    app = MatrixPowers(n=n, k=16, model="exp")
    app.initialize(MatrixPowers.synthesize(n, seed=0))
    stream = iter(UpdateStream(n=n, m=n, scale=0.02, seed=1))
    worst = 0.0
    for i in range(20):
        u, v = next(stream)
        a = app.update(jnp.asarray(u), jnp.asarray(v))
        b = app.update_reeval(jnp.asarray(u), jnp.asarray(v))
        ref = float(jnp.max(jnp.abs(b))) or 1.0
        worst = max(worst, float(jnp.max(jnp.abs(a - b))) / ref)
    assert worst < 5e-3, worst


def test_trigger_cost_tracks_table2():
    """The compiled trigger FLOP counts reproduce Table 2's asymptotic
    ordering across models and sizes."""
    f = {}
    for model in ("linear", "exp", "skip"):
        app = MatrixPowers(n=128, k=16, model=model)
        f[model] = app.engine.trigger_flops("A")
    assert f["exp"] < f["skip"] <= f["linear"]
    # incremental vs reeval gap grows with n (the paper's Fig. 3b trend)
    r1 = MatrixPowers(n=64, k=16, model="exp").speedup_estimate()
    r2 = MatrixPowers(n=512, k=16, model="exp").speedup_estimate()
    assert r2 > r1


def test_lm_train_checkpoint_restart_resume(tmp_path):
    """Train a reduced LM, checkpoint, 'crash', restore, and verify the
    resumed state matches the uninterrupted run (determinism of data +
    step)."""
    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    step = jax.jit(make_train_step(model, lr=1e-3))
    from repro.data.pipeline import synth_batch
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", 64, 4, "train")

    def batch_at(t):
        return {k: jnp.asarray(v) for k, v in
                synth_batch(cfg, shape, seed=3, step=t).items()}

    # uninterrupted run
    s_a = init_train_state(model, jax.random.PRNGKey(0))
    for t in range(6):
        s_a, _ = step(s_a, batch_at(t))

    # interrupted run with checkpoint at step 3
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    s_b = init_train_state(model, jax.random.PRNGKey(0))
    for t in range(3):
        s_b, _ = step(s_b, batch_at(t))
    mgr.save(3, s_b, blocking=True)
    s_b = mgr.restore(s_b)   # "crash + restore"
    for t in range(3, 6):
        s_b, _ = step(s_b, batch_at(t))

    for a, b in zip(jax.tree.leaves(s_a.params), jax.tree.leaves(s_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_ols_view_matches_fresh_solve():
    """After a stream of updates, the maintained β* equals solving the
    final system from scratch (numerical ground truth, not reeval engine)."""
    m, n = 80, 16
    app = OLS(m, n, 1)
    inputs, _ = OLS.synthesize(m, n, 1, seed=4)
    app.initialize(inputs)
    X = np.asarray(inputs["X"]).copy()
    Y = np.asarray(inputs["Y"])
    rng = np.random.default_rng(5)
    for _ in range(4):
        row = int(rng.integers(0, m))
        dv = (rng.normal(size=n) * 0.1).astype(np.float32)
        u, v = app.row_update(row, dv)
        beta = app.update(u, v)
        X[row] += dv
    fresh = np.linalg.solve(X.T @ X, X.T @ Y)
    np.testing.assert_allclose(np.asarray(beta), fresh, rtol=5e-2, atol=5e-2)


def test_data_pipeline_prefetch():
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    cfg = get_config("h2o-danube-1.8b").reduced()
    pipe = TokenPipeline(cfg, ShapeConfig("t", 32, 2, "train"), seed=0)
    b1 = next(pipe)
    b2 = next(pipe)
    assert b1["tokens"].shape == (2, 32)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    pipe.close()
