"""Delta-rule correctness (paper §4.1): symbolic deltas vs numeric
E(X+ΔX) − E(X) for every rule, including inverse (Woodbury + sequential
Sherman–Morrison) and multi-input simultaneous updates (Example 4.5)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeltaEnv, DenseDelta, LowRank, Program, add, const,
                        derive, dim, evaluate, inverse, matmul, scale, sub,
                        transpose, var)
from repro.core.compiler import extract_inverse_views

from conftest import assert_close


def _num(shape, rng, scale_=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale_, dtype=jnp.float32)


def _delta_value(d, env, binding):
    if isinstance(d, DenseDelta):
        return evaluate(d.value, env, binding)
    total = 0.0
    for l, r in zip(d.left, d.right):
        total = total + evaluate(l, env, binding) @ evaluate(r, env, binding).T
    return total


N = 24


@pytest.fixture
def setting(rng):
    A = var("A", (N, N))
    B = var("B", (N, N))
    env = {
        "A": _num((N, N), rng),
        "B": _num((N, N), rng),
        "dU_A": _num((N, 2), rng, 0.3),
        "dV_A": _num((N, 2), rng, 0.3),
        "dU_B": _num((N, 1), rng, 0.3),
        "dV_B": _num((N, 1), rng, 0.3),
    }
    denv = DeltaEnv()
    denv.deltas["A"] = LowRank.outer(var("dU_A", (N, 2)), var("dV_A", (N, 2)))
    denv.deltas["B"] = LowRank.outer(var("dU_B", (N, 1)), var("dV_B", (N, 1)))
    return A, B, env, denv


def _check_rule(expr, env, denv, rtol=5e-3):
    binding = {}
    d = derive(expr, denv)
    sym = _delta_value(d, env, binding)
    old = evaluate(expr, env, binding)
    new_env = dict(env)
    new_env["A"] = env["A"] + env["dU_A"] @ env["dV_A"].T
    new_env["B"] = env["B"] + env["dU_B"] @ env["dV_B"].T
    new = evaluate(expr, new_env, binding)
    assert_close(sym, new - old, rtol=rtol, atol=1e-2)
    return d


def test_product_rule(setting):
    A, B, env, denv = setting
    d = _check_rule(matmul(A, B), env, denv)
    assert isinstance(d, LowRank)
    assert d.rank == 3  # k_A + k_B after common-factor extraction


def test_sum_rule(setting):
    A, B, env, denv = setting
    _check_rule(add(A, B), env, denv)


def test_sub_and_scale(setting):
    A, B, env, denv = setting
    _check_rule(sub(scale(2.5, A), B), env, denv)


def test_transpose_rule(setting):
    A, B, env, denv = setting
    d = _check_rule(matmul(transpose(A), A), env, denv)
    assert isinstance(d, LowRank)


def test_static_expr_has_zero_delta(setting):
    A, B, env, denv = setting
    C = var("C", (N, N))
    d = derive(matmul(C, transpose(C)), denv)
    assert isinstance(d, LowRank) and d.is_zero()


def test_nested_squaring_rank_growth(setting):
    """Example 4.4/4.6: rank doubles (not triples) per squaring."""
    A, B, env, denv = setting
    AA = matmul(A, A)
    d1 = derive(AA, denv)
    assert d1.rank == 4  # 2·k for k=2 input
    # treat AA's delta as a view delta and square again
    denv2 = DeltaEnv()
    denv2.deltas["A"] = denv.deltas["A"]
    prog_like = matmul(AA, AA)
    d2 = derive(prog_like, denv2)
    assert d2.rank == 8


@pytest.mark.parametrize("sequential", [False, True])
def test_inverse_rule(setting, sequential, rng):
    A, B, env, denv = setting
    # well-conditioned operand: Z = AᵀA + 5I (materialized as a view)
    Z = var("Z", (N, N))
    Zexpr = inverse(Z)
    env = dict(env)
    base = np.asarray(env["A"])
    env["Z"] = jnp.asarray(base.T @ base + 5 * np.eye(N), dtype=jnp.float32)
    env["W"] = jnp.linalg.inv(env["Z"])
    denv2 = DeltaEnv(sequential_sm=sequential)
    denv2.deltas["Z"] = LowRank.outer(var("dU_A", (N, 2)), var("dV_A", (N, 2)))
    denv2.views[id(Zexpr)] = var("W", (N, N))
    d = derive(Zexpr, denv2)
    assert isinstance(d, LowRank)
    sym = _delta_value(d, env, {})
    new = jnp.linalg.inv(env["Z"] + env["dU_A"] @ env["dV_A"].T)
    assert_close(sym, new - env["W"], rtol=1e-2)


def test_multi_input_product(setting):
    """Example 4.5: simultaneous ΔA and ΔB through E = A·B."""
    A, B, env, denv = setting
    d = derive(matmul(A, B), denv)
    # exactness already checked in test_product_rule; here check that both
    # inputs contributed blocks
    names = set()
    for blk in d.left + d.right:
        names |= blk.free_vars()
    assert {"dU_A", "dV_A"} & names and {"dU_B", "dV_B"} & names


def test_inverse_requires_materialization(setting):
    A, B, env, denv = setting
    from repro.core import IncrementalInverseError
    with pytest.raises(IncrementalInverseError):
        derive(inverse(matmul(transpose(A), A)), denv)


def test_aux_view_extraction():
    p = Program(name="t")
    N_ = dim("n")
    X = p.input("X", (N_, N_))
    p.let("out", matmul(inverse(add(X, X)), X))
    p.bind_dims(n=8)
    p2 = extract_inverse_views(p)
    names = p2.view_names()
    assert any(n.startswith("__aux") for n in names)
    # the inverse node is now a top-level statement
    from repro.core import expr as ex
    aux_st = next(s for s in p2.statements if s.target.name.startswith("__aux"))
    assert isinstance(aux_st.expr, ex.Inverse)
