"""Delta-rule correctness (paper §4.1): symbolic deltas vs numeric
E(X+ΔX) − E(X) for every rule, including inverse (Woodbury + sequential
Sherman–Morrison) and multi-input simultaneous updates (Example 4.5)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeltaEnv, DenseDelta, LowRank, Program, add, const,
                        derive, dim, evaluate, inverse, matmul, scale, sub,
                        transpose, var)
from repro.core.compiler import extract_inverse_views

from conftest import assert_close


def _num(shape, rng, scale_=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale_, dtype=jnp.float32)


def _delta_value(d, env, binding):
    if isinstance(d, DenseDelta):
        return evaluate(d.value, env, binding)
    total = 0.0
    for l, r in zip(d.left, d.right):
        total = total + evaluate(l, env, binding) @ evaluate(r, env, binding).T
    return total


N = 24


@pytest.fixture
def setting(rng):
    A = var("A", (N, N))
    B = var("B", (N, N))
    env = {
        "A": _num((N, N), rng),
        "B": _num((N, N), rng),
        "dU_A": _num((N, 2), rng, 0.3),
        "dV_A": _num((N, 2), rng, 0.3),
        "dU_B": _num((N, 1), rng, 0.3),
        "dV_B": _num((N, 1), rng, 0.3),
    }
    denv = DeltaEnv()
    denv.deltas["A"] = LowRank.outer(var("dU_A", (N, 2)), var("dV_A", (N, 2)))
    denv.deltas["B"] = LowRank.outer(var("dU_B", (N, 1)), var("dV_B", (N, 1)))
    return A, B, env, denv


def _check_rule(expr, env, denv, rtol=5e-3):
    binding = {}
    d = derive(expr, denv)
    sym = _delta_value(d, env, binding)
    old = evaluate(expr, env, binding)
    new_env = dict(env)
    new_env["A"] = env["A"] + env["dU_A"] @ env["dV_A"].T
    new_env["B"] = env["B"] + env["dU_B"] @ env["dV_B"].T
    new = evaluate(expr, new_env, binding)
    assert_close(sym, new - old, rtol=rtol, atol=1e-2)
    return d


def test_product_rule(setting):
    A, B, env, denv = setting
    d = _check_rule(matmul(A, B), env, denv)
    assert isinstance(d, LowRank)
    assert d.rank == 3  # k_A + k_B after common-factor extraction


def test_sum_rule(setting):
    A, B, env, denv = setting
    _check_rule(add(A, B), env, denv)


def test_sub_and_scale(setting):
    A, B, env, denv = setting
    _check_rule(sub(scale(2.5, A), B), env, denv)


def test_transpose_rule(setting):
    A, B, env, denv = setting
    d = _check_rule(matmul(transpose(A), A), env, denv)
    assert isinstance(d, LowRank)


def test_static_expr_has_zero_delta(setting):
    A, B, env, denv = setting
    C = var("C", (N, N))
    d = derive(matmul(C, transpose(C)), denv)
    assert isinstance(d, LowRank) and d.is_zero()


def test_nested_squaring_rank_growth(setting):
    """Example 4.4/4.6: rank doubles (not triples) per squaring."""
    A, B, env, denv = setting
    AA = matmul(A, A)
    d1 = derive(AA, denv)
    assert d1.rank == 4  # 2·k for k=2 input
    # treat AA's delta as a view delta and square again
    denv2 = DeltaEnv()
    denv2.deltas["A"] = denv.deltas["A"]
    prog_like = matmul(AA, AA)
    d2 = derive(prog_like, denv2)
    assert d2.rank == 8


@pytest.mark.parametrize("sequential", [False, True])
def test_inverse_rule(setting, sequential, rng):
    A, B, env, denv = setting
    # well-conditioned operand: Z = AᵀA + 5I (materialized as a view)
    Z = var("Z", (N, N))
    Zexpr = inverse(Z)
    env = dict(env)
    base = np.asarray(env["A"])
    env["Z"] = jnp.asarray(base.T @ base + 5 * np.eye(N), dtype=jnp.float32)
    env["W"] = jnp.linalg.inv(env["Z"])
    denv2 = DeltaEnv(sequential_sm=sequential)
    denv2.deltas["Z"] = LowRank.outer(var("dU_A", (N, 2)), var("dV_A", (N, 2)))
    denv2.views[id(Zexpr)] = var("W", (N, N))
    d = derive(Zexpr, denv2)
    assert isinstance(d, LowRank)
    sym = _delta_value(d, env, {})
    new = jnp.linalg.inv(env["Z"] + env["dU_A"] @ env["dV_A"].T)
    assert_close(sym, new - env["W"], rtol=1e-2)


def test_multi_input_product(setting):
    """Example 4.5: simultaneous ΔA and ΔB through E = A·B."""
    A, B, env, denv = setting
    d = derive(matmul(A, B), denv)
    # exactness already checked in test_product_rule; here check that both
    # inputs contributed blocks
    names = set()
    for blk in d.left + d.right:
        names |= blk.free_vars()
    assert {"dU_A", "dV_A"} & names and {"dU_B", "dV_B"} & names


def test_inverse_requires_materialization(setting):
    A, B, env, denv = setting
    from repro.core import IncrementalInverseError
    with pytest.raises(IncrementalInverseError):
        derive(inverse(matmul(transpose(A), A)), denv)


def test_aux_view_extraction():
    p = Program(name="t")
    N_ = dim("n")
    X = p.input("X", (N_, N_))
    p.let("out", matmul(inverse(add(X, X)), X))
    p.bind_dims(n=8)
    p2 = extract_inverse_views(p)
    names = p2.view_names()
    assert any(n.startswith("__aux") for n in names)
    # the inverse node is now a top-level statement
    from repro.core import expr as ex
    aux_st = next(s for s in p2.statements if s.target.name.startswith("__aux"))
    assert isinstance(aux_st.expr, ex.Inverse)

# ---------------------------------------------------------------------------
# higher-order deltas (delta-of-delta, DBToaster arXiv 1207.0137)
# ---------------------------------------------------------------------------


def _step(env, times=1):
    """env with A and B advanced ``times`` identical (diagonal) steps."""
    out = dict(env)
    out["A"] = env["A"] + times * (env["dU_A"] @ env["dV_A"].T)
    out["B"] = env["B"] + times * (env["dU_B"] @ env["dV_B"].T)
    return out


def test_second_order_matmul_diagonal(setting):
    """Diagonal Δ²: Δ²E(·; d, d) = E(+2d) − 2·E(+d) + E for E = A·B."""
    A, B, env, denv = setting
    e = matmul(A, B)
    sym = _delta_value(derive(e, denv, order=2), env, {})
    E = lambda en: evaluate(e, en, {})
    want = E(_step(env, 2)) - 2 * E(_step(env, 1)) + E(env)
    assert_close(sym, want, rtol=5e-3, atol=1e-2)


def test_second_order_square_is_2dd(setting):
    """Δ²(A²; d, d) = 2·d·d exactly — no base-view reads left at depth 2."""
    A, B, env, denv = setting
    d2 = derive(matmul(A, A), denv, order=2)
    assert isinstance(d2, LowRank)
    d = env["dU_A"] @ env["dV_A"].T
    assert_close(_delta_value(d2, env, {}), 2 * d @ d, rtol=5e-3, atol=1e-2)
    # ...and none of its factor blocks reads A itself
    for blk in d2.left + d2.right:
        assert "A" not in blk.free_vars()


def test_second_order_distinct_steps(setting):
    """Mixed-update Δ² via ``steps``: Δ_{d₂}Δ_{d₁}E =
    E(+d₁+d₂) − E(+d₁) − E(+d₂) + E."""
    A, B, env, denv = setting
    env = dict(env)
    rng = np.random.default_rng(5)
    env["dU2_A"] = jnp.asarray(rng.normal(size=(N, 1)) * 0.3, jnp.float32)
    env["dV2_A"] = jnp.asarray(rng.normal(size=(N, 1)) * 0.3, jnp.float32)
    denv2 = DeltaEnv()
    denv2.deltas["A"] = LowRank.outer(var("dU2_A", (N, 1)),
                                      var("dV2_A", (N, 1)))
    e = matmul(A, A)
    sym = _delta_value(derive(e, denv, order=2, steps=[denv2]), env, {})
    d1 = env["dU_A"] @ env["dV_A"].T
    d2 = env["dU2_A"] @ env["dV2_A"].T
    E = lambda a: np.asarray(a) @ np.asarray(a)
    a = np.asarray(env["A"])
    want = E(a + d1 + d2) - E(a + d1) - E(a + d2) + E(a)
    assert_close(sym, want, rtol=5e-3, atol=1e-2)


def test_third_order_vanishes_on_quadratic(setting):
    """DBToaster termination: Δ³ ≡ 0 for any degree-2 expression."""
    A, B, env, denv = setting
    assert derive(matmul(A, B), denv, order=3).is_zero()
    assert derive(matmul(A, A), denv, order=3).is_zero()
    assert derive(add(matmul(A, B), scale(2.0, A)), denv, order=3).is_zero()


def test_third_order_cubic_diagonal(setting):
    """Δ³(A³; d, d, d) equals the numeric third difference (= 6·d³)."""
    A, B, env, denv = setting
    e = matmul(matmul(A, A), A)
    sym = _delta_value(derive(e, denv, order=3), env, {})
    E = lambda en: evaluate(e, en, {})
    want = (E(_step(env, 3)) - 3 * E(_step(env, 2))
            + 3 * E(_step(env, 1)) - E(env))
    assert_close(sym, want, rtol=5e-3, atol=5e-2)
    d = np.asarray(env["dU_A"] @ env["dV_A"].T, np.float64)
    assert_close(sym, 6 * d @ d @ d, rtol=5e-3, atol=5e-2)


def test_higher_order_scale_rule(setting):
    A, B, env, denv = setting
    e = scale(2.5, matmul(A, A))
    sym = _delta_value(derive(e, denv, order=2), env, {})
    E = lambda en: evaluate(e, en, {})
    want = E(_step(env, 2)) - 2 * E(_step(env, 1)) + E(env)
    assert_close(sym, want, rtol=5e-3, atol=1e-2)


def test_order_zero_and_one_match_classic(setting):
    """Regression pin: order ≤ 1 is bit-identical to the pre-existing
    first-order ``derive`` (same rep class, same rank, same blocks)."""
    A, B, env, denv = setting
    e = matmul(A, B)
    classic = derive(e, denv)
    for o in (0, 1):
        d = derive(e, denv, order=o)
        assert type(d) is type(classic)
        assert d.rank == classic.rank
        np.testing.assert_array_equal(
            np.asarray(_delta_value(d, env, {})),
            np.asarray(_delta_value(classic, env, {})))


def test_second_order_through_inverse_raises(setting):
    """The Woodbury rule stops at first order: Δ² through a materialized
    inverse raises instead of silently producing a wrong rep."""
    A, B, env, denv = setting
    from repro.core import IncrementalInverseError
    Z = var("Z", (N, N))
    Zexpr = inverse(Z)
    denv2 = DeltaEnv()
    denv2.deltas["Z"] = LowRank.outer(var("dU_A", (N, 2)),
                                      var("dV_A", (N, 2)))
    denv2.views[id(Zexpr)] = var("W", (N, N))
    d1 = derive(Zexpr, denv2)
    assert isinstance(d1, LowRank)  # depth 1 still fine
    # the compiler registers the view's own first-order delta before
    # recursing (W moves when Z does); with it in scope, the Woodbury
    # rep's block operands are no longer static and depth 2 must refuse
    denv2.deltas["W"] = d1
    with pytest.raises(IncrementalInverseError):
        derive(Zexpr, denv2, order=2)


def test_derive_order_validation(setting):
    A, B, env, denv = setting
    with pytest.raises(ValueError):
        derive(matmul(A, B), denv, order=-1)
    with pytest.raises(ValueError):
        derive(matmul(A, B), denv, order=3, steps=[denv])  # needs 2 envs
